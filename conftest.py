"""Repo-root pytest config: make ``pytest -q`` work without PYTHONPATH=src.

Also hosts the cross-family serving conformance fixture ``fam``: one
representative reduced arch per family where ``models.model.supports_paged``
is true.  Tests parametrized over it get ids ``fam_<family>``, so
``pytest -k fam_hybrid`` (or ``make test-families``) runs the whole serving
contract for a single family.  Session scope: each family's params are
initialised once and shared by every conformance module.
"""

import os
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# one representative arch per paged family — the conformance matrix
FAMILY_ARCHS = {
    "dense": "smollm-360m",
    "moe": "qwen2-moe-a2.7b",
    "vlm": "qwen2-vl-72b",
    "mla_moe": "deepseek-v2-lite-16b",
    "hybrid": "zamba2-7b",
}


def load_family(family: str):
    import jax

    from repro.configs.registry import ASSIGNED_ARCHS
    from repro.models import model as M

    cfg = ASSIGNED_ARCHS[FAMILY_ARCHS[family]].reduced()
    assert cfg.family == family
    assert M.supports_paged(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0), max_seq=64)
    return cfg, params


@pytest.fixture(scope="session", params=sorted(FAMILY_ARCHS),
                ids=lambda f: f"fam_{f}")
def fam(request):
    cfg, params = load_family(request.param)
    return request.param, cfg, params
