"""jit'd public wrapper for the paged W8A8 GeMV kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.int8_pagegemv.int8_pagegemv import paged_int8_gemm
from repro.quant.int8 import quantize_activation


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit,
                   static_argnames=("tile_h", "tile_w", "interpret"))
def paged_int8_gemv(w_q: jax.Array, scale: jax.Array, x: jax.Array,
                    tile_h: int = 256, tile_w: int = 2048,
                    interpret: bool = True) -> jax.Array:
    """W8A8 GeMV/GeMM through the Pallas kernel.

    w_q: int8 [h, w]; scale: f32 [h]; x: float [w] or [w, b] -> f32 [h(, b)].
    Pads to tile multiples, quantizes activations per column (one dynamic
    scale per token), dequantizes the int32 accumulators with
    ``scale[h] ⊗ x_scale[b]`` (paper §IV-B compute-core flow).
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    h, w = w_q.shape
    th, tw = min(tile_h, max(h, 8)), min(tile_w, max(w, 128))
    x_q, x_scale = quantize_activation(x)
    w_p = _pad_to(_pad_to(w_q, 0, th), 1, tw)
    x_p = _pad_to(x_q, 0, tw)
    acc = paged_int8_gemm(w_p, x_p, tile_h=th, tile_w=tw,
                          interpret=interpret)[:h]
    y = acc.astype(jnp.float32) * scale[:, None] * x_scale[None, :]
    return y[:, 0] if squeeze else y
