"""Pure-jnp oracle for the paged W8A8 GeMV kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.int8 import quantize_activation


def paged_int8_gemm_ref(w_q: jax.Array, x_q: jax.Array) -> jax.Array:
    """int32[h, b] = int8[h, w] @ int8[w, b] (exact integer reference)."""
    return jax.lax.dot_general(
        w_q.astype(jnp.int32), x_q.astype(jnp.int32),
        (((1,), (0,)), ((), ())))


def paged_int8_gemv_ref(w_q: jax.Array, scale: jax.Array,
                        x: jax.Array) -> jax.Array:
    """Full W8A8 path: quantize activations, int GeMV, dequantize.

    x: [w] or [w, b] float; returns f32 [h] or [h, b].
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    x_q, x_scale = quantize_activation(x)
    acc = paged_int8_gemm_ref(w_q, x_q).astype(jnp.float32)
    y = acc * scale[:, None] * x_scale[None, :]
    return y[:, 0] if squeeze else y
