"""Paged W8A8 GeMV/GeMM Pallas TPU kernel — the flash compute-core analogue.

The paper's atomic tile (one 16KB page per compute core; optimal full tile
256x2048 for Cambricon-LLM-S) becomes the VMEM BlockSpec: each grid step
loads a (tile_h, tile_w) int8 weight block — exactly a channel's worth of
pages — multiplies against the resident int8 activation block on the MXU
(int8 x int8 -> int32), and accumulates into the output block, mirroring the
read-compute request pipeline (§IV-B steps 1-5).

Grid: (h_tiles, w_tiles); w is the reduction ("arbitrary") dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _kernel(w_ref, x_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = jax.lax.dot_general(
        w_ref[...], x_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("tile_h", "tile_w", "interpret"))
def paged_int8_gemm(w_q: jax.Array, x_q: jax.Array,
                    tile_h: int = 256, tile_w: int = 2048,
                    interpret: bool = True) -> jax.Array:
    """int32[h, b] = int8[h, w] @ int8[w, b] with paged VMEM tiling.

    Inputs must be pre-padded so tile sizes divide (h, w); see ops.py.
    """
    h, w = w_q.shape
    b = x_q.shape[1]
    assert h % tile_h == 0 and w % tile_w == 0, (h, w, tile_h, tile_w)
    grid = (h // tile_h, w // tile_w)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
            pl.BlockSpec((tile_w, b), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_h, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, b), jnp.int32),
        interpret=interpret,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(w_q, x_q)
