"""GQA decode attention Pallas kernel (flash-decoding style).

One query token per (batch, head) against a long KV cache: grid
(B*H, kv_blocks), kv sequential with online-softmax scratch.  Positions at or
beyond the slot's valid length are masked (the cache is pre-allocated to
max_seq).  ``length`` may be a scalar (shared cursor, the paper's single-batch
decode) or a per-slot vector [B] (continuous batching: each slot is at a
different position in its own sequence).  K/V BlockSpecs fold grouped heads
onto their kv head (no repeat).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_k, n_heads):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # [1, d]
    k = k_ref[0].astype(jnp.float32)          # [bk, d]
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * (d ** -0.5)
    ki = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    my_len = len_ref[pl.program_id(0) // n_heads]  # this slot's valid prefix
    s = jnp.where(ki < my_len, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def gqa_decode_attention(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, length: jax.Array,
                         block_k: int = 512, interpret: bool = True
                         ) -> jax.Array:
    """q: [B, H, D]; caches [B, Smax, Hkv, D]; length: scalar or [B] int32.

    Returns [B, H, D].  Smax must divide block_k (ops.py pads)."""
    b, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    n_rep = h // hkv
    block_k = min(block_k, smax)
    grid = (b * h, smax // block_k)
    qr = q.reshape(b * h, 1, d)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, smax, d)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, smax, d)

    def kv_map(bh, j):
        return ((bh // n_rep) % hkv + (bh // h) * hkv, j, 0)

    lens = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1), (b,))
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, n_heads=h),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda bh, j: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, j: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(lens, qr, kr, vr).reshape(b, h, d)
