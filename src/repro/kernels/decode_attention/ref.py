"""Oracles: models/attention holds the pure-jnp references.

``decode_attention_ref`` accepts scalar or per-slot [B] lengths;
``paged_decode_attention_ref`` is the block-table variant.
"""

from repro.models.attention import (  # noqa: F401
    decode_attention as decode_attention_ref,
    paged_decode_attention as paged_decode_attention_ref,
)
