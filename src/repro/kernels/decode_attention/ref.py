"""Oracle: models/attention.decode_attention is the reference."""

from repro.models.attention import decode_attention as decode_attention_ref  # noqa: F401
