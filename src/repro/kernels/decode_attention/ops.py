"""jit'd wrappers for the decode-attention kernel.

``decode_attention_op`` pads Smax to the kv block and accepts either a shared
scalar cursor or a per-slot lengths vector [B] (continuous batching).
``paged_decode_attention_op`` is the block-table front-end: it gathers each
slot's pages from the shared page pool into the contiguous [B, Smax] layout
the kernel streams over, then masks per-slot valid lengths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import gqa_decode_attention


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_op(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                        length: jax.Array, block_k: int = 512,
                        interpret: bool = True) -> jax.Array:
    """q: [B, H, D]; caches [B, Smax, Hkv, D]; length: scalar or [B]."""
    smax = k_cache.shape[1]
    bk = min(block_k, smax)
    pad = (-smax) % bk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return gqa_decode_attention(q, k_cache, v_cache, length, block_k=bk,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def paged_decode_attention_op(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, block_table: jax.Array,
                              lengths: jax.Array, block_k: int = 512,
                              interpret: bool = True) -> jax.Array:
    """Paged decode attention over a shared page pool.

    q: [B, H, D]; k/v_pages: [P, page, Hkv, D]; block_table: [B, pages_per
    slot] int32 page ids; lengths: [B] valid tokens per slot.
    """
    from repro.models.attention import gather_paged_kv

    k, v = gather_paged_kv(k_pages, v_pages, block_table)
    return decode_attention_op(q, k, v, lengths, block_k=block_k,
                               interpret=interpret)
