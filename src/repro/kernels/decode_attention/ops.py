"""jit'd wrapper for the decode-attention kernel (pads Smax to block)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import gqa_decode_attention


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_op(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                        length: jax.Array, block_k: int = 512,
                        interpret: bool = True) -> jax.Array:
    smax = k_cache.shape[1]
    bk = min(block_k, smax)
    pad = (-smax) % bk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return gqa_decode_attention(q, k_cache, v_cache, length, block_k=bk,
                                interpret=interpret)
