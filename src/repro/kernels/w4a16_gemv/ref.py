"""Oracle for the W4A16 kernel: quant/int4.py's dequantize + matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.int4 import QuantizedLinear4, dequantize4


def w4a16_gemv_ref(q: QuantizedLinear4, x: jax.Array) -> jax.Array:
    w = dequantize4(q)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    y = w @ x.astype(jnp.float32)
    return y[:, 0] if squeeze else y
