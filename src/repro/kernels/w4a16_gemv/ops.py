"""jit'd wrapper for the W4A16 kernel."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.w4a16_gemv.w4a16_gemv import w4a16_gemm
from repro.quant.int4 import GROUP, QuantizedLinear4


def w4a16_gemv(q: QuantizedLinear4, x: jax.Array, tile_h: int = 256,
               tile_w: int = 2048, interpret: bool = True) -> jax.Array:
    """Not jitted at this level: q.h/q.w are static python ints that drive
    padding/tiling; the inner pallas_call wrapper is jitted."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    h, w = q.h, q.w
    group = min(GROUP, w)
    # round the tile width down to a multiple of `group` (the scale-group
    # granularity the kernel reshapes by; group is even, so the nibble-pair
    # constraint rides along), flooring at one group.  The old
    # `tw -= tw % (2 * group) or 0; tw = max(tw, 2 * group)` bounce had a
    # dead `or 0` and inflated padding ~2x whenever w < 2 * group
    # (e.g. w == group padded to 2 * group).
    tw = max((min(tile_w, w) // group) * group, group)
    th = min(tile_h, h)
    ph = (-h) % th
    pw = (-w) % tw
    wp = jnp.pad(q.w_packed, ((0, ph), (0, pw // 2)))
    sc = jnp.pad(q.scale, ((0, ph), (0, pw // group)))
    xp = jnp.pad(x, ((0, pw), (0, 0)))
    y = w4a16_gemm(wp, sc, xp, tile_h=th, tile_w=tw, group=group,
                   interpret=interpret)[:h]
    return y[:, 0] if squeeze else y
