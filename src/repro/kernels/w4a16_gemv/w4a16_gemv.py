"""W4A16 GeMV Pallas kernel (paper §VIII-B / Fig. 11).

Packed int4 weights (two nibbles per byte) are unpacked and dequantized
in-VMEM with group-wise scales, then matmul'd against 16-bit activations.
Tiles follow the same page-derived shapes as the int8 kernel (a page holds
2x the elements at 4 bits — the planner's bytes_per_elem=0.5 mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _kernel(wp_ref, scale_ref, x_ref, out_ref, *, group):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    packed = wp_ref[...]                       # [th, tw//2] uint8
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = ((packed >> 4) & 0xF).astype(jnp.int8) - 8
    w_q = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)  # [th, tw]
    th, tw = w_q.shape
    scales = scale_ref[...]                    # [th, tw//group]
    w = (w_q.reshape(th, tw // group, group).astype(jnp.float32)
         * scales[:, :, None]).reshape(th, tw)
    acc = jax.lax.dot_general(
        w, x_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("tile_h", "tile_w", "group",
                                             "interpret"))
def w4a16_gemm(w_packed: jax.Array, scales: jax.Array, x: jax.Array,
               tile_h: int = 256, tile_w: int = 2048, group: int = 128,
               interpret: bool = True) -> jax.Array:
    """f32[h, b] = dequant(int4[h, w]) @ f16/f32[w, b].

    w_packed: uint8 [h, w//2]; scales: f32 [h, w//group]; x: [w, b].
    Pre-padded to tile multiples (see ops.py)."""
    h, wb = w_packed.shape
    w = wb * 2
    b = x.shape[1]
    assert h % tile_h == 0 and w % tile_w == 0
    grid = (h // tile_h, w // tile_w)
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_h, tile_w // 2), lambda i, j: (i, j)),
            pl.BlockSpec((tile_h, tile_w // group), lambda i, j: (i, j)),
            pl.BlockSpec((tile_w, b), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_h, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, b), jnp.float32),
        interpret=interpret,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(w_packed, scales, x)
