"""Pallas-TPU API compatibility shims.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` across
0.4.x -> 0.5.x; the kernels import the symbol from here so they run on either
side of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
