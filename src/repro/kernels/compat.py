"""JAX API compatibility shims (Pallas-TPU renames + shard_map move).

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` across
0.4.x -> 0.5.x; the kernels import the symbol from here so they run on either
side of the rename.

``shard_map`` graduated from ``jax.experimental.shard_map.shard_map`` to
``jax.shard_map`` (and its ``check_rep`` kwarg became ``check_vma``) across
0.4.x -> 0.6.x.  Every call site in the repo goes through the resolver below
with the NEW spelling (``jax.shard_map`` semantics, ``check_vma=``); on an
older install the wrapper translates the kwarg and falls back to the
experimental import.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        """``jax.shard_map``-compatible wrapper over the pre-0.6 API:
        ``check_vma`` (new name) maps onto ``check_rep`` (old name)."""
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:

    def axis_size(axis_name):
        """``jax.lax.axis_size`` fallback: psum of a literal 1 is folded to
        the axis size at trace time, so callers still get a Python int."""
        return jax.lax.psum(1, axis_name)
