"""Flash attention (prefill) Pallas TPU kernel.

Grid (B*H, q_blocks, kv_blocks); the kv dimension is sequential ("arbitrary")
and carries online-softmax state (m, l, acc) in VMEM scratch.  GQA is free:
the K/V BlockSpec index_map folds the q-head onto its kv-head, so grouped
heads share K/V blocks without materializing the repeat.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, sm_scale, block_q, block_k, causal):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # [bq, d]
    k = k_ref[0].astype(jnp.float32)            # [bk, d]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    if causal:
        qi = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ki = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qi >= ki, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = True) -> jax.Array:
    """q: [B, H, Sq, D]; k/v: [B, Hkv, Skv, D] -> [B, H, Sq, D].

    Sq/Skv must divide by the block sizes (ops.py pads).
    """
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    n_rep = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    grid = (b * h, sq // block_q, skv // block_k)
    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)

    def kv_map(bh, i, j):
        return ((bh // n_rep) % hkv + (bh // h) * hkv, j, 0)

    return pl.pallas_call(
        functools.partial(_kernel, sm_scale=d ** -0.5, block_q=block_q,
                          block_k=block_k, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qr, kr, vr).reshape(b, h, sq, d)
