"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q: [B, H, Sq, D]; k/v: [B, Hkv, Skv, D] -> [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    n_rep = h // hkv
    k = jnp.repeat(k, n_rep, axis=1)
    v = jnp.repeat(v, n_rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
