"""jit'd wrapper: pads sequences to block multiples, handles masking tails."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_op(q: jax.Array, k: jax.Array, v: jax.Array,
                       causal: bool = True, block_q: int = 512,
                       block_k: int = 512, interpret: bool = True
                       ) -> jax.Array:
    b, h, sq, d = q.shape
    skv = k.shape[2]
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pq = (-sq) % bq
    pk = (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        # padded K rows must never win the softmax: rely on causal masking for
        # causal=True; for bidirectional, push keys to -inf via a large
        # negative bias injected through V=0, K=0 and q.k=0 — instead we pad K
        # with zeros and subtract them via explicit masking below.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    if pk and not causal:
        raise ValueError("bidirectional flash op requires Skv % block_k == 0")
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=interpret)
    return out[:, :, :sq]
