"""On-die ECC decode Pallas kernel (paper §VI / Fig. 8b).

Per 16KB page: (1) fake-outlier suppression — any unprotected value whose
|magnitude| exceeds the majority-voted threshold is clamped to 0; (2) outlier
restoration — the 163 protected entries are re-written with the per-bit
majority vote of {in-page value, copy0, copy1} at their (Hamming-corrected)
addresses via an in-kernel fori_loop of dynamic stores.

The address Hamming correction and threshold majority are tiny bit-twiddling
ops done outside the kernel (core/ecc.py); the kernel fuses the page-wide
clamp + scatter, which is the part that touches all 16K elements.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _kernel(page_ref, thr_ref, addr_ref, voted_ref, valid_ref, out_ref):
    page = page_ref[0]                               # [P] uint8 bit patterns
    vals = page.astype(jnp.int8).astype(jnp.int32)
    mags = jnp.abs(vals)
    thr = thr_ref[pl.program_id(0)]  # SMEM ref holds the whole [B] vector
    # protected-position mask via scatter of valid addrs
    k = addr_ref.shape[1]

    out = jnp.where(mags > thr, jnp.uint8(0), page)
    out_ref[0] = out

    def body(i, _):
        addr = addr_ref[0, i]
        val = voted_ref[0, i]
        ok = valid_ref[0, i]
        # every index position must be a Slice: a raw int in the tuple breaks
        # jax 0.4.x's load/store discharge rules (int has no .shape), so the
        # leading block-row index is pl.ds(0, 1) rather than 0
        cur = pl.load(out_ref, (pl.ds(0, 1), pl.ds(addr, 1)))
        new = jnp.where(ok, val, cur[0, 0])
        pl.store(out_ref, (pl.ds(0, 1), pl.ds(addr, 1)), new[None, None])
        return 0

    jax.lax.fori_loop(0, k, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ecc_decode_pages(pages: jax.Array, thr: jax.Array, addr: jax.Array,
                     voted: jax.Array, valid: jax.Array,
                     interpret: bool = True) -> jax.Array:
    """pages: uint8 [B, P]; thr: int32 [B]; addr: int32 [B, K];
    voted: uint8 [B, K]; valid: bool->uint8 [B, K].  Returns corrected pages.

    The scatter inside the clamp region restores protected outliers; entries
    with valid=0 keep the clamped value (paper: 2-bit address errors discard
    the protection).
    """
    b, p = pages.shape
    k = addr.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, p), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, k), lambda i: (i, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, k), lambda i: (i, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, k), lambda i: (i, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p), jnp.uint8),
        interpret=interpret,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
    )(pages, thr, addr, voted, valid)
