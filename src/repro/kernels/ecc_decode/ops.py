"""jit'd wrapper: ECC sidecar decode (bit ops) + fused page clamp/scatter."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ecc as ecc_mod
from repro.kernels.ecc_decode.ecc_decode import ecc_decode_pages


@functools.partial(jax.jit, static_argnames=("interpret",))
def ecc_decode_op(pages: jax.Array, ecc: ecc_mod.PageECC,
                  interpret: bool = True) -> jax.Array:
    """pages: uint8 [B, P] + batched PageECC -> corrected uint8 [B, P]."""
    thr = jax.vmap(lambda t: ecc_mod._majority_bits(t, axis=-1))(ecc.threshold)
    addr, valid = jax.vmap(ecc_mod.hamming_correct)(ecc.addr, ecc.addr_parity)
    addr = jnp.minimum(addr.astype(jnp.int32), pages.shape[-1] - 1)
    in_page = jnp.take_along_axis(pages, addr, axis=1)
    voted = ecc_mod._majority3_u8(in_page, ecc.copies[..., 0],
                                  ecc.copies[..., 1])
    return ecc_decode_pages(pages, thr.astype(jnp.int32), addr, voted,
                            valid.astype(jnp.uint8), interpret=interpret)
