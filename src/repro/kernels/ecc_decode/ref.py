"""Oracle: core/ecc.decode_pages (the bit-exact jnp implementation)."""

from repro.core.ecc import decode_page as decode_page_ref  # noqa: F401
from repro.core.ecc import decode_pages as decode_pages_ref  # noqa: F401
