"""Oracle for the SSD intra-chunk kernel (mirrors models/ssm.ssd_chunked's
y_diag term)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import _segsum


def ssd_intra_chunk_ref(a: jax.Array, b_mat: jax.Array, c_mat: jax.Array,
                        x: jax.Array) -> jax.Array:
    """a: [BH, C, Q]; b/c: [BG, C, Q, N]; x: [BH, C, Q, P] -> [BH, C, Q, P]."""
    bh = a.shape[0]
    bg = b_mat.shape[0]
    rep = bh // bg
    b_full = jnp.repeat(b_mat, rep, axis=0)
    c_full = jnp.repeat(c_mat, rep, axis=0)
    ell = jnp.exp(_segsum(a.astype(jnp.float32)))
    ell = jnp.where(jnp.isfinite(ell), ell, 0.0)
    s = jnp.einsum("gcln,gcsn->gcls", c_full.astype(jnp.float32),
                   b_full.astype(jnp.float32)) * ell
    return jnp.einsum("gcls,gcsp->gclp", s, x.astype(jnp.float32))
