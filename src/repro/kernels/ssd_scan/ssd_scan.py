"""Mamba-2 SSD intra-chunk Pallas kernel (the quadratic "duality" part).

Per (batch*head, chunk): with log-decays a, inputs x, and B/C projections,
    L[i,j] = exp(cumsum(a)_i - cumsum(a)_j)  for i >= j (else 0)
    y      = ((C @ B^T) * L) @ x
The inter-chunk recurrence (linear part) stays in jnp (models/ssm.py); this
kernel covers the FLOPs-dominant blockwise attention-like contraction.
B/C BlockSpecs fold grouped heads onto their group (ngroups < nheads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(a_ref, b_ref, c_ref, x_ref, o_ref):
    a = a_ref[0, 0].astype(jnp.float32)       # [Q]
    bmat = b_ref[0, 0].astype(jnp.float32)    # [Q, N]
    cmat = c_ref[0, 0].astype(jnp.float32)    # [Q, N]
    x = x_ref[0, 0].astype(jnp.float32)       # [Q, P]
    cs = jnp.cumsum(a)
    q = a.shape[0]
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    ell = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    s = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * ell
    y = jax.lax.dot_general(s, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(a: jax.Array, b_mat: jax.Array, c_mat: jax.Array,
                    x: jax.Array, interpret: bool = True) -> jax.Array:
    """a: [BH, C, Q]; b_mat/c_mat: [BG, C, Q, N]; x: [BH, C, Q, P].

    BH = batch*heads, BG = batch*groups; heads fold onto groups in the
    BlockSpec index maps. Returns y_diag [BH, C, Q, P] (f32)."""
    bh, nc, qq = a.shape
    bg, n = b_mat.shape[0], b_mat.shape[3]
    p = x.shape[3]
    rep = bh // bg
    grid = (bh, nc)

    def group_map(i, c):
        return ((i // rep) % bg, c, 0, 0)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qq), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, qq, n), group_map),
            pl.BlockSpec((1, 1, qq, n), group_map),
            pl.BlockSpec((1, 1, qq, p), lambda i, c: (i, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qq, p), lambda i, c: (i, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nc, qq, p), jnp.float32),
        interpret=interpret,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
    )(a, b_mat, c_mat, x)
