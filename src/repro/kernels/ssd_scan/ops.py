"""jit'd wrapper for the SSD intra-chunk kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_intra_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_chunk_op(x: jax.Array, a: jax.Array, b_mat: jax.Array,
                       c_mat: jax.Array, chunk: int = 128,
                       interpret: bool = True) -> jax.Array:
    """Layout adapter: [B,S,H,P]/[B,S,H]/[B,S,G,N] -> chunked kernel call.

    S must divide by ``chunk``. Returns y_diag [B, S, H, P] (f32)."""
    b, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    nc = s // chunk
    xr = x.reshape(b, nc, chunk, h, p).transpose(0, 3, 1, 2, 4) \
        .reshape(b * h, nc, chunk, p)
    ar = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2) \
        .reshape(b * h, nc, chunk)
    br = b_mat.reshape(b, nc, chunk, g, n).transpose(0, 3, 1, 2, 4) \
        .reshape(b * g, nc, chunk, n)
    cr = c_mat.reshape(b, nc, chunk, g, n).transpose(0, 3, 1, 2, 4) \
        .reshape(b * g, nc, chunk, n)
    y = ssd_intra_chunk(ar, br, cr, xr, interpret=interpret)
    return y.reshape(b, h, nc, chunk, p).transpose(0, 2, 3, 1, 4) \
        .reshape(b, s, h, p)
