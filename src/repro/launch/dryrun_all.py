"""Fan out every dry-run cell as a subprocess (isolation: one bad cell can't
kill the sweep; each process gets its own 512-device XLA init).

Usage: PYTHONPATH=src python -m repro.launch.dryrun_all [--jobs 1]
       [--mesh single|multi|both] [--skip-done]
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

from repro.configs.registry import dryrun_cells, skipped_cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    # smallest-first: quick wins early, failures surface fast
    for cfg, shape in sorted(dryrun_cells(),
                             key=lambda cs: cs[0].param_count()):
        for mesh in meshes:
            cells.append((cfg.name, shape.name, mesh))

    print(f"{len(cells)} cells; skipped (documented): "
          f"{len(skipped_cells())}", flush=True)
    procs: list[tuple[subprocess.Popen, tuple]] = []
    failures = []

    def reap(block: bool):
        for p, cell in list(procs):
            if block:
                p.wait()
            if p.poll() is not None:
                procs.remove((p, cell))
                status = "ok" if p.returncode == 0 else f"rc={p.returncode}"
                print(f"[{status}] {cell}", flush=True)
                if p.returncode != 0:
                    failures.append(cell)

    for arch, shape, mesh in cells:
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh}.json")
        if args.skip_done and os.path.exists(path):
            import json

            try:
                rec = json.load(open(path))
                if rec.get("status") == "ok" and (
                        mesh == "multi" or args.skip_cost
                        or "cost" in rec):
                    print(f"[cached] {(arch, shape, mesh)}", flush=True)
                    continue
            except (OSError, ValueError):
                # corrupt or partial cache record: fall through and re-run
                pass
        while len(procs) >= args.jobs:
            reap(block=True)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--out", args.out]
        if args.skip_cost:
            cmd.append("--skip-cost")
        procs.append((subprocess.Popen(
            cmd, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL),
            (arch, shape, mesh)))
    reap(block=True)
    print(f"done; {len(failures)} failures: {failures}", flush=True)


if __name__ == "__main__":
    main()
