"""End-to-end training driver (local devices).

Example (the deliverable "train a ~100M model for a few hundred steps"):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced 0 --steps 300 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.distributed.checkpoint import (latest_step, restore_checkpoint,
                                          save_checkpoint)
from repro.models import model as model_lib
from repro.training.data import DataState, make_batch
from repro.training.optimizer import init_adamw
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0),
                                   dtype=jnp.float32, max_seq=args.seq)
    opt = init_adamw(params)
    ds = DataState(seed=0, step=0)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt), extra = restore_checkpoint(args.ckpt_dir, (params, opt))
        ds = DataState(seed=0, step=extra["data_step"])
        start = extra["train_step"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, microbatches=args.microbatches,
                                      lr=args.lr, remat=False))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")
    t0 = time.time()
    for i in range(start, args.steps):
        toks, ds = make_batch(ds, args.batch, args.seq, cfg.vocab_size)
        params, opt, loss = step_fn(params, opt, toks, None)
        if i % args.log_every == 0 or i == args.steps - 1:
            tps = args.batch * args.seq * (i - start + 1) / (time.time() - t0)
            print(f"step {i:5d} loss {float(loss):.4f} tok/s {tps:,.0f}",
                  flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1, (params, opt),
                            extra={"train_step": i + 1, "data_step": ds.step})
    print("done")


if __name__ == "__main__":
    main()
