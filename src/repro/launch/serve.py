"""Serving driver over the public Request / RequestOutput contract.

Builds an engine with a pluggable scheduling policy, submits a mixed batch
of prioritized requests with per-request sampling, and consumes the
streaming ``RequestOutput`` events as they happen — the same surface a
network frontend would sit on.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 8 --max-new 16 --policy priority --chunk-prefill 8 \
      --temperature 0.8 --top-k 40 --stream
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.registry import get_arch
from repro.models import model as model_lib
from repro.quant.convert import quantize_params
from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import POLICIES, SamplingParams, make_scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "wave", "continuous"],
                    help="auto = continuous where the family supports a "
                         "paged KV cache, else wave")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (continuous mode)")
    ap.add_argument("--policy", default="fcfs", choices=sorted(POLICIES),
                    help="admission/preemption policy "
                         "(serving.scheduler)")
    ap.add_argument("--chunk-prefill", type=int, default=0,
                    help="chunked-prefill token budget per step "
                         "(0 = one-shot prefill)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling seed base (request seed = base + rid)")
    ap.add_argument("--stream", action="store_true",
                    help="print each RequestOutput token event")
    ap.add_argument("--quant", default="int8", choices=["none", "int8"])
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0),
                                   max_seq=args.max_seq)
    if args.quant == "int8":
        params = quantize_params(params)  # the paper's W8A8 deployment mode
    scheduler = make_scheduler(
        args.policy, chunk_tokens=args.chunk_prefill or None)
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, eos_id=-1, mode=args.mode,
                        page_size=args.page_size, scheduler=scheduler)
    rng = jax.random.PRNGKey(42)
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 2, 9))
        prompt = [int(t) for t in jax.random.randint(
            k, (plen,), 0, cfg.vocab_size)]
        eng.submit(Request(
            rid=rid, prompt=prompt, max_new_tokens=args.max_new,
            priority=rid % 3,  # mixed priorities exercise the policy
            sampling=SamplingParams(temperature=args.temperature,
                                    top_k=args.top_k, top_p=args.top_p,
                                    seed=args.seed + rid)))
    t0 = time.time()
    for out in eng.stream():
        if out.finished:
            print(f"rid={out.rid} done n_out={out.n_out} "
                  f"reason={out.finish_reason} "
                  f"ttft={out.ttft_s if out.ttft_s is not None else -1:.3f}s "
                  f"chunks={out.sched['chunks']} "
                  f"preempt={out.sched['preemptions']}")
        elif args.stream:
            print(f"rid={out.rid} tok[{out.n_out - 1}]={out.token}")
    dt = time.time() - t0
    stats = eng.stats
    print(f"requests={args.requests} tokens_out={stats.tokens_out} "
          f"decode_steps={stats.decode_steps} wall={dt:.1f}s "
          f"tok/s={stats.tokens_out/dt:.1f}")
    print(stats.summary())


if __name__ == "__main__":
    main()
