"""Serving driver over the ServingClient surface.

This is the user-facing end of the three-layer serving API
(``ServingClient -> Router -> EngineCore``, see serving/engine.py and the
ROADMAP design note): the client allocates globally unique request ids —
and derives each stochastic request's sampling seed from its id, so seeds
never collide across replicas — the router spreads requests over
``--replicas N`` engine replicas under ``--route`` (round_robin /
least_loaded / session_affinity) and migrates slots off page-starved
replicas, and each replica runs the paged/tiered KV serving loop under the
``--policy`` scheduler (fcfs / priority / sjf / drr / edf).  ``--overlap``
switches every replica to the overlapped decode loop — decode + sampling
fused into ONE jitted dispatch per step, sampled tokens held on device and
read back one step late, so step N+1 is enqueued before step N's token
reaches the host; outputs stay bit-identical to the synchronous loop.

``--prefix-cache`` turns on refcounted prefix caching in every replica:
prefill-written KV pages are registered in a per-replica prefix index
(sha256 chain over page-aligned token spans), a repeated prompt re-maps
those shared pages instead of re-prefilling them (an exact repeat skips
prefill dispatches entirely and replays the stored first-token logits),
writes into shared pages copy-on-write, and released pages park idle in
the index — spillable to the flash tier and prefetched back on the next
hit.  Outputs stay bit-identical to a cold-cache run; under
``--route session_affinity`` the replica whose cache holds the session's
pages wins the routing decision.

``--quant w8a8`` serves from quantized weights: ``quantize_params``
rewrites every non-router linear as int8 weights + per-output-channel
scales (``w4a16`` packs int4 nibbles + per-group scales instead), the
layers dispatch to the quantized matmuls, and the router/gate weights
stay full precision.  ``--kv-dtype int8`` additionally quantizes the
paged KV pool itself: pages hold int8 rows plus a per-row f32 scale,
written once at prefill/decode time and dequantized at the attend, so
spill, prefetch, prefix sharing, migration, and fleet snapshots all
move the half-sized ``(payload, scale)`` pages unchanged — decode
streams stay bit-identical to themselves across every relocation path.

``--workers N`` switches to fleet mode (serving/fleet/): N workers
behind the versioned wire protocol — in-process under
``--transport loopback``, real subprocesses under ``--transport
socket`` — with heartbeat health tracking, ``--spares K`` hot spares,
and snapshot-based failover that keeps every recovered token stream
bit-identical to an undisturbed run.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 8 --max-new 16 --replicas 2 --route least_loaded \
      --policy edf --deadline 5.0 --chunk-prefill 8 \
      --temperature 0.8 --top-k 40 --stream

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 8 --workers 2 --spares 1 --transport socket

Typical surface usage (what this driver does):

    client = ServingClient(cfg, params, replicas=2, route="least_loaded",
                           max_batch=4, max_seq=128, scheduler="edf")
    h = client.submit(prompt, max_new_tokens=16, deadline_s=5.0,
                      sampling=SamplingParams(temperature=0.8))
    for out in client.stream():   # or: for tok in h.tokens()
        ...
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.registry import get_arch
from repro.models import model as model_lib
from repro.quant.convert import quantize_params
from repro.serving.client import ServingClient
from repro.serving.router import ROUTE_POLICIES
from repro.serving.scheduler import POLICIES, SamplingParams, make_scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="slots PER replica")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the router")
    ap.add_argument("--route", default="round_robin",
                    choices=ROUTE_POLICIES,
                    help="router policy distributing requests over "
                         "replicas")
    ap.add_argument("--no-migrate", action="store_true",
                    help="disable cross-replica slot migration")
    ap.add_argument("--workers", type=int, default=0,
                    help="fleet mode: N workers behind the fleet wire "
                         "protocol with heartbeat health tracking and "
                         "snapshot-based failover (0 = classic in-process "
                         "replicas)")
    ap.add_argument("--transport", default="loopback",
                    choices=["loopback", "socket"],
                    help="fleet transport: loopback = in-process workers "
                         "behind the byte-faithful wire codec; socket = "
                         "real subprocess workers over TCP")
    ap.add_argument("--spares", type=int, default=0,
                    help="hot spare workers promoted on failover")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "wave", "continuous"],
                    help="auto = continuous where the family supports a "
                         "paged KV cache, else wave")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (continuous mode)")
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped decode loop: fused decode+sample "
                         "dispatch with one-step-delayed host readback — "
                         "1 jitted dispatch per decode step instead of 2, "
                         "bit-identical outputs")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted prefix caching: repeated prompts "
                         "re-map shared KV pages instead of re-prefilling "
                         "(copy-on-write on writes, bit-identical outputs)")
    ap.add_argument("--policy", default="fcfs", choices=sorted(POLICIES),
                    help="per-replica admission/preemption policy "
                         "(serving.scheduler)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request SLO budget in seconds "
                         "(0 = none; pair with --policy edf)")
    ap.add_argument("--chunk-prefill", type=int, default=0,
                    help="chunked-prefill token budget per step "
                         "(0 = one-shot prefill)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling seed base; the CLIENT derives each "
                         "request's seed as base + global rid, so streams "
                         "never collide across replicas")
    ap.add_argument("--stream", action="store_true",
                    help="print each RequestOutput token event")
    ap.add_argument("--quant", default="w8a8",
                    choices=["none", "w8a8", "w4a16", "int8"],
                    help="weight quantization mode for quantize_params "
                         "(router/gate weights stay full precision; "
                         "'int8' is the legacy alias for w8a8)")
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"],
                    help="paged KV pool dtype: int8 stores quantized page "
                         "rows + per-row f32 scales (half the spill bytes, "
                         "self-bit-identical across every relocation path)")
    args = ap.parse_args()
    if args.quant == "int8":
        args.quant = "w8a8"

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.workers and args.transport == "socket":
        # subprocess workers rebuild params themselves from (arch, seed)
        # and quantize locally, so nothing heavy ships over the wire;
        # prefix-cache / mode are per-worker features the worker CLI does
        # not expose yet
        from repro.serving.fleet.router import FleetRouter
        router = FleetRouter.build_socket(
            args.arch, workers=args.workers, spares=args.spares,
            policy=args.route, migrate=not args.no_migrate,
            sched_policy=args.policy, reduced=bool(args.reduced),
            max_batch=args.max_batch, max_seq=args.max_seq,
            page_size=args.page_size, eos_id=-1, overlap=args.overlap,
            chunk_prefill=args.chunk_prefill,
            kv_dtype=args.kv_dtype, quant=args.quant)
        client = ServingClient(router=router, seed_base=args.seed)
    else:
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0),
                                       max_seq=args.max_seq)
        if args.quant != "none":
            params = quantize_params(params, mode=args.quant)
        client = ServingClient(
            cfg, params, replicas=args.replicas, route=args.route,
            migrate=not args.no_migrate, seed_base=args.seed,
            workers=args.workers, transport=args.transport,
            spares=args.spares,
            max_batch=args.max_batch, max_seq=args.max_seq, eos_id=-1,
            mode=args.mode, page_size=args.page_size, overlap=args.overlap,
            prefix_cache=args.prefix_cache, kv_dtype=args.kv_dtype,
            scheduler=make_scheduler(args.policy,
                                     chunk_tokens=args.chunk_prefill
                                     or None))
    rng = jax.random.PRNGKey(42)
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 2, 9))
        prompt = [int(t) for t in jax.random.randint(
            k, (plen,), 0, cfg.vocab_size)]
        client.submit(
            prompt, max_new_tokens=args.max_new,
            priority=i % 3,  # mixed priorities exercise the policy
            deadline_s=args.deadline or None,
            session=f"user-{i % 4}",  # affinity demo under --route
            sampling=SamplingParams(temperature=args.temperature,
                                    top_k=args.top_k, top_p=args.top_p))
    t0 = time.time()
    for out in client.stream():
        if out.finished:
            print(f"rid={out.rid} done n_out={out.n_out} "
                  f"reason={out.finish_reason} "
                  f"ttft={out.ttft_s if out.ttft_s is not None else -1:.3f}s "
                  f"chunks={out.sched['chunks']} "
                  f"preempt={out.sched['preemptions']}")
        elif args.stream:
            print(f"rid={out.rid} tok[{out.n_out - 1}]={out.token}")
    dt = time.time() - t0
    tokens = sum(s.tokens_out for s in client.router.stats)
    steps = sum(s.decode_steps for s in client.router.stats)
    print(f"requests={args.requests} tokens_out={tokens} "
          f"decode_steps={steps} wall={dt:.1f}s tok/s={tokens/dt:.1f}")
    print(client.summary())
    fleet = getattr(client.router, "fleet", None)
    if fleet is not None:   # fleet mode: surface the failover counters
        print(f"fleet shutdown: workers_lost={fleet.workers_lost} "
              f"failovers={fleet.failovers} "
              f"requests_replayed={fleet.requests_replayed} "
              f"tokens_replayed={fleet.tokens_replayed} "
              f"heartbeat_misses={fleet.heartbeat_misses}")
        client.router.close()


if __name__ == "__main__":
    main()
