"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 8 --max-new 16 --mode continuous
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.registry import get_arch
from repro.models import model as model_lib
from repro.quant.convert import quantize_params
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "wave", "continuous"],
                    help="auto = continuous where the family supports a "
                         "paged KV cache, else wave")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (continuous mode)")
    ap.add_argument("--quant", default="int8", choices=["none", "int8"])
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0),
                                   max_seq=args.max_seq)
    if args.quant == "int8":
        params = quantize_params(params)  # the paper's W8A8 deployment mode
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, eos_id=-1, mode=args.mode,
                        page_size=args.page_size)
    rng = jax.random.PRNGKey(42)
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 2, 9))
        prompt = [int(t) for t in jax.random.randint(
            k, (plen,), 0, cfg.vocab_size)]
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    stats = eng.run()
    dt = time.time() - t0
    print(f"requests={args.requests} tokens_out={stats.tokens_out} "
          f"decode_steps={stats.decode_steps} wall={dt:.1f}s "
          f"tok/s={stats.tokens_out/dt:.1f}")
    print(stats.summary())


if __name__ == "__main__":
    main()
