"""Parse collective traffic out of post-SPMD-partitioning HLO text.

``collective_bytes`` sums, per collective opcode, the result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the optimized module.  Link-traffic weighting for the
roofline is applied downstream (all-reduce counts 2x: ring reduce-scatter +
all-gather phases).

NOTE: ops inside while loops (lax.scan) appear once in the text but execute
trip-count times — the dry-run therefore extracts per-layer costs from
fully-unrolled shallow variants and extrapolates (launch/dryrun.py).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*([^=]+?)\s+(" + "|".join(c + r"(?:-start|-done)?" for c in _COLLECTIVES) + r")\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Returns {opcode: result bytes} summed over the module (loops counted
    once — see module docstring), plus op counts under "<op>_count"."""
    out: dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue  # counted at the matching -start
        op = op.replace("-start", "")
        b = _shape_bytes(shape_str)
        out[op] += b
        out[op + "_count"] += 1
    return dict(out)


def link_traffic_bytes(coll: dict[str, float]) -> float:
    """Per-device ICI traffic estimate: ring all-reduce moves ~2x the buffer,
    the others ~1x the result buffer."""
    total = 0.0
    for op, b in coll.items():
        if op.endswith("_count"):
            continue
        total += 2.0 * b if op == "all-reduce" else b
    return total
