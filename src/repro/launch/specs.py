"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

``input_specs`` returns weak-type-correct, shardable specs — no device
allocation ever happens in the dry-run (the shannon/kernels pattern).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as model_lib
from repro.quant.convert import quantize_params
from repro.training.optimizer import init_adamw


def model_extras_specs(cfg: ModelConfig, batch: int) -> dict:
    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        extras["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return extras


def param_specs(cfg: ModelConfig, max_seq: int, quant: bool,
                dtype=jnp.bfloat16):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def build(k):
        p = model_lib.init_params(cfg, k, dtype=dtype, max_seq=max_seq)
        return quantize_params(p) if quant else p

    return jax.eval_shape(build, key)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model_lib.init_cache(cfg, batch, max_seq, dtype))


def opt_specs(param_spec_tree):
    return jax.eval_shape(init_adamw, param_spec_tree)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Stand-ins for the *data* inputs of the lowered step function."""
    b = shape.global_batch
    if shape.kind == "train":
        toks = jax.ShapeDtypeStruct((b, _text_len(cfg, shape.seq_len)),
                                    jnp.int32)
        return {"tokens": toks, "extras": model_extras_specs(cfg, b)}
    if shape.kind == "prefill":
        toks = jax.ShapeDtypeStruct((b, _text_len(cfg, shape.seq_len)),
                                    jnp.int32)
        return {"tokens": toks, "extras": model_extras_specs(cfg, b)}
    # decode: one new token against a seq_len-deep cache
    return {"token": jax.ShapeDtypeStruct((b,), jnp.int32)}


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """vlm cells: seq_len counts vision prefix + text tokens."""
    if cfg.family == "vlm":
        return seq_len - cfg.n_vision_tokens
    return seq_len
