"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Per (arch × shape) on the single-pod mesh:
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = link_bytes_per_device / ICI_link_bw         [s]
(XLA cost_analysis is per-device on SPMD modules — measured empirically,
ratio exactly 1/n_devices on a sharded matmul — so the /chips in the spec
formula is already applied.)  FLOPs/bytes come from the unrolled shallow
cost variants extrapolated to full depth (dryrun.py); collective bytes from
the partitioned HLO with all-reduce weighted 2x (ring).

MODEL_FLOPS: 6·N·D for train (N = active params, D = tokens), 2·N·D for
prefill, 2·N_active·B per decoded token — matmul-only, attention/cache work
excluded, so ratio < 1 is expected and the gap quantifies attention + GSPMD
redundancy + masked-causal overcompute.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import SHAPES
from repro.configs.registry import get_arch
from repro.core.hw import TPU_V5E

N_DEV = 256


def model_flops_per_device(arch: str, shape_name: str) -> float:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_total * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / N_DEV


def model_bytes_per_device(arch: str, shape_name: str) -> float:
    """Minimal HBM traffic per step: weights touched once (+KV for decode,
    +grad/optimizer state for train). The bandwidth-side roofline ideal."""
    from repro.core import planner

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if shape.kind == "train":
        # bf16 params read + f32 grads written/read + adam m,v read+write
        return n_total * (2 + 4 + 4 * 4) / N_DEV
    kv = planner.kv_cache_bytes(cfg, shape.seq_len, shape.global_batch,
                                bytes_per_elem=2)
    if shape.kind == "prefill":
        return (n_active * 1 + kv) / N_DEV  # int8 weights + cache write
    return (n_active * 1 + kv) / N_DEV      # int8 weights + cache read


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "cost" not in rec or \
            "error" in rec.get("cost", {}):
        return None
    cost = rec["cost"]
    tpu = TPU_V5E
    t_compute = cost["flops"] / tpu.peak_flops_bf16
    t_memory = cost["bytes"] / tpu.hbm_bw
    t_coll = cost["link_bytes"] / tpu.ici_bw_per_link
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"])
    mb = model_bytes_per_device(rec["arch"], rec["shape"])
    bound = max(terms.values())
    # the achievable ideal is itself roofline-limited: compute OR bandwidth
    ideal = max(mf / tpu.peak_flops_bf16, mb / tpu.hbm_bw)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops_pd": mf,
        "hlo_flops_pd": cost["flops"],
        "useful_ratio": mf / cost["flops"] if cost["flops"] else 0.0,
        "roofline_fraction": ideal / bound if bound else 0.0,
        "mem_gb": (rec["memory"]["argument_size_in_bytes"]
                   + rec["memory"]["temp_size_in_bytes"]
                   + rec["memory"]["output_size_in_bytes"]
                   - rec["memory"]["alias_size_in_bytes"]) / 1e9,
    }


MOVE_HINTS = {
    "compute": ("cut HLO FLOPs: causal-aware chunk skipping (masked blocks "
                "currently burn 2x score FLOPs) / drop remat recompute"),
    "memory": ("raise arithmetic intensity: larger per-chip batch, fuse "
               "dequant into the GeMM, int8 KV cache"),
    "collective": ("reshard: move the all-gathered dim, int8 collectives, "
                   "or overlap the gather behind the previous layer's GeMM "
                   "(hybrid_stream)"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*__single.json"))):
        rec = json.load(open(path))
        row = analyze_cell(rec)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac | mem GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2%} | {r['mem_gb']:.1f} |")
    table = "\n".join(lines)
    with open(args.out, "w") as f:
        f.write(table + "\n")
    with open(args.out.replace(".md", ".json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(table)
    print(f"\n{len(rows)} cells analyzed -> {args.out}")


if __name__ == "__main__":
    main()
