import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST précède every other import (jax locks the
# device count on first init), which is why __future__ imports are omitted.

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
  1. FULL compile on the production mesh — proves the sharding config is
     coherent and memory fits (compiled.memory_analysis()).  This is the
     pass/fail deliverable.
  2. Shallow UNROLLED cost variants (per-layer-exact; while-loop bodies are
     otherwise counted once by XLA cost analysis) — lowered, compiled, and
     linearly extrapolated to the full depth for §Roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape decode_32k --mesh single --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, InputShape, ModelConfig
from repro.configs.registry import get_arch
from repro.distributed import ctx as dctx
from repro.distributed import sharding as shd
from repro.launch import specs as specs_lib
from repro.launch.hlo_stats import collective_bytes, link_traffic_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.training.train_loop import make_train_step
from repro.training.optimizer import init_adamw

TRAIN_MICROBATCHES = 8


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_step(cfg: ModelConfig, shape: InputShape, mesh, microbatches: int,
               layout: str = "zero3"):
    """Returns (jitted_fn, arg_specs_tuple).  layout: "zero3" (paper-faithful
    streaming tier) or "tp" (ship-activations residency, §Perf)."""
    dp = shd.dp_axes(mesh)
    quant = shape.kind != "train"
    max_seq = shape.seq_len if shape.kind != "train" else shape.seq_len
    pspecs = specs_lib.param_specs(cfg, max_seq=max_seq, quant=quant,
                                   dtype=jnp.bfloat16)
    pshard = shd.params_shardings(pspecs, mesh, zero3=(layout == "zero3"))
    inputs = specs_lib.input_specs(cfg, shape)

    if shape.kind == "train":
        ospec = jax.eval_shape(init_adamw, pspecs)
        oshard = shd.params_shardings(ospec, mesh,
                                      zero3=(layout == "zero3"))

        # optimizer state: mu/nu follow param sharding; step replicated
        oshard = dataclasses.replace(
            oshard,
            step=shd.replicated(mesh)) if dataclasses.is_dataclass(oshard) \
            else oshard
        tok_shard = NamedSharding(
            mesh, shd.batch_pspec(mesh, shape.global_batch, 2))
        extras_shard = jax.tree.map(
            lambda s: NamedSharding(
                mesh, shd.batch_pspec(mesh, shape.global_batch, s.ndim)),
            inputs["extras"])
        step = make_train_step(cfg, microbatches=microbatches)

        def fn(params, opt_state, tokens, extras):
            return step(params, opt_state, tokens,
                        extras if extras else None)

        jf = jax.jit(fn,
                     in_shardings=(pshard, oshard, tok_shard, extras_shard),
                     donate_argnums=(0, 1))
        return jf, (pspecs, ospec, inputs["tokens"], inputs["extras"])

    cache_spec = specs_lib.cache_specs(cfg, shape.global_batch, shape.seq_len)
    cshard = shd.cache_shardings(cache_spec, mesh, shape.global_batch)

    if shape.kind == "prefill":
        tok_shard = NamedSharding(
            mesh, shd.batch_pspec(mesh, shape.global_batch, 2))
        extras_shard = jax.tree.map(
            lambda s: NamedSharding(
                mesh, shd.batch_pspec(mesh, shape.global_batch, s.ndim)),
            inputs["extras"])

        def fn(params, tokens, cache, extras):
            return model_lib.prefill(params, cfg, tokens, cache,
                                     extras if extras else None)

        jf = jax.jit(fn,
                     in_shardings=(pshard, tok_shard, cshard, extras_shard),
                     out_shardings=(NamedSharding(mesh, P()), cshard),
                     donate_argnums=(2,))
        return jf, (pspecs, inputs["tokens"], cache_spec, inputs["extras"])

    # decode
    tok_shard = NamedSharding(
        mesh, shd.batch_pspec(mesh, shape.global_batch, 1))

    def fn(params, token, cache):
        return model_lib.decode_step(params, cfg, token, cache)

    jf = jax.jit(fn,
                 in_shardings=(pshard, tok_shard, cshard),
                 out_shardings=(NamedSharding(mesh, P()), cshard),
                 donate_argnums=(2,))
    return jf, (pspecs, inputs["token"], cache_spec)


def act_constraint(mesh):
    """Residual stream: sequence-parallel over 'model'; logits: vocab-parallel
    over 'model' (prevents GSPMD replicating [B,S,V] f32 at the LM head)."""
    dp = shd.dp_axes(mesh)
    msize = mesh.shape.get("model", 1)

    def constrain(x, kind="resid"):
        if "model" not in mesh.shape:
            return x
        if kind == "embed":
            # embedding-table gradient: match the table's param sharding
            dims = [None] * x.ndim
            if x.shape[0] % msize == 0:
                dims[0] = "model"
            if x.ndim > 1 and "data" in mesh.shape and \
                    x.shape[-1] % mesh.shape["data"] == 0:
                dims[-1] = "data"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*dims)))
        if kind == "logits":
            if x.shape[-1] % msize == 0:
                dims = [None] * x.ndim
                dims[0] = dp
                dims[-1] = "model"
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(*dims)))
            return x
        if kind == "q_seq":
            # queries/outputs stay sequence-sharded: avoids the SP<->TP
            # reshard (an all-gather of the full residual per layer)
            if x.ndim == 4 and x.shape[1] % msize == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dp, "model", None, None)))
            return x
        if kind == "kv_gather":
            # K/V gathered over model: GQA keys are n_heads/n_kv_heads
            # smaller than the residual, so shipping them is the cheap side
            if x.ndim == 4:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dp, None, None, None)))
            return x
        if kind == "heads":
            # [B, S, H, Dh]: heads -> model when divisible (TP attention),
            # else sequence -> model (keeps GSPMD from replicating the batch)
            if x.ndim == 4 and x.shape[2] % msize == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dp, None, "model", None)))
            if x.ndim == 4 and x.shape[1] % msize == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dp, "model", None, None)))
            return x
        if x.ndim == 3 and x.shape[1] % msize == 0:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, "model", None)))
        return x

    return constrain


# ---------------------------------------------------------------------------
# cost variants: shallow unrolled configs + linear extrapolation
# ---------------------------------------------------------------------------


def cost_variant_cfgs(cfg: ModelConfig) -> list[tuple[str, ModelConfig, dict]]:
    """[(name, variant_cfg, weights)] with weights {name: coefficient} such
    that full_cost = Σ coeff * variant_cost solves to the full depth."""
    f = cfg.family
    if f == "audio":
        a = dataclasses.replace(cfg, n_layers=1, n_encoder_layers=1)
        b = dataclasses.replace(cfg, n_layers=1, n_encoder_layers=2)
        c = dataclasses.replace(cfg, n_layers=2, n_encoder_layers=1)
        e, d = cfg.n_encoder_layers, cfg.n_layers
        # cost = base + E*enc + D*dec;  A = base+enc+dec, B = A+enc, C = A+dec
        return [("A", a, {}), ("B", b, {}), ("C", c, {})], \
            lambda fa, fb, fc: fa + (e - 1) * (fb - fa) + (d - 1) * (fc - fa)
    if f == "hybrid":
        a = dataclasses.replace(cfg, n_layers=2, shared_attn_every=2)
        b = dataclasses.replace(cfg, n_layers=4, shared_attn_every=2)
        c = dataclasses.replace(cfg, n_layers=4, shared_attn_every=4)
        m, s = cfg.n_layers, cfg.n_layers // cfg.shared_attn_every
        # A = base+2m+1s; B = base+4m+2s; C = base+4m+1s
        # m_cost=(C-A)/2; s_cost=B-C; base=A-2m-s
        return [("A", a, {}), ("B", b, {}), ("C", c, {})], \
            lambda fa, fb, fc: (fa - 2 * ((fc - fa) / 2) - (fb - fc)
                                + m * ((fc - fa) / 2) + s * (fb - fc))
    if f == "mla_moe":
        a = dataclasses.replace(cfg, n_layers=2)   # 1 dense + 1 moe
        b = dataclasses.replace(cfg, n_layers=3)   # 1 dense + 2 moe
        nm = cfg.n_layers - cfg.first_k_dense
        return [("A", a, {}), ("B", b, {})], \
            lambda fa, fb: fa + (nm - 1) * (fb - fa)
    a = dataclasses.replace(cfg, n_layers=1)
    b = dataclasses.replace(cfg, n_layers=2)
    return [("A", a, {}), ("B", b, {})], \
        lambda fa, fb: fa + (cfg.n_layers - 1) * (fb - fa)


def run_cost_variants(cfg: ModelConfig, shape: InputShape, mesh,
                      microbatches: int, layout: str = "zero3") -> dict:
    variants, combine = cost_variant_cfgs(cfg)
    results = []
    for name, vcfg, _ in variants:
        with dctx.lowering_ctx(constrain=act_constraint(mesh),
                               remat=(shape.kind == "train"),
                               unroll_scans=True, mesh=mesh):
            with mesh:
                jf, argspecs = build_step(vcfg, shape, mesh, microbatches=1,
                                          layout=layout)
                lowered = jf.lower(*argspecs)
                compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        results.append({
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "link_bytes": link_traffic_bytes(coll),
            "collectives": coll,
        })

    def comb(key):
        vals = [r[key] for r in results]
        return float(combine(*vals))

    out = {"flops": comb("flops"), "bytes": comb("bytes"),
           "link_bytes": comb("link_bytes"),
           "variants": results}
    if shape.kind == "train" and microbatches > 1:
        # variants lowered at microbatches=1 over the full global batch;
        # grad-accumulation splits the same tokens, so per-step totals match
        # up to the (microbatches-1) extra optimizer-free accumulations —
        # negligible; totals kept as-is.
        pass
    return out


# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             skip_cost: bool = False, microbatches: int = TRAIN_MICROBATCHES,
             layout: str = "zero3") -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "layout": layout,
        "microbatches": microbatches,
        "n_devices": int(len(mesh.devices.reshape(-1))),
    }
    t0 = time.time()
    try:
        with dctx.lowering_ctx(constrain=act_constraint(mesh),
                               remat=(shape.kind == "train"), mesh=mesh):
            with mesh:
                jf, argspecs = build_step(cfg, shape, mesh, microbatches,
                                          layout=layout)
                lowered = jf.lower(*argspecs)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        rec.update(
            status="ok", lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                k: int(getattr(mem, k, 0)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes")
            })
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis_raw"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
        }
        rec["collectives_raw"] = collective_bytes(compiled.as_text())
    except Exception as e:  # noqa: BLE001 — recorded, the driver aggregates
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        return rec

    if not skip_cost and not multi_pod:
        try:
            rec["cost"] = run_cost_variants(cfg, shape, mesh, microbatches,
                                            layout)
        except Exception as e:  # noqa: BLE001
            rec["cost"] = {"error": f"{type(e).__name__}: {e}"}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--microbatches", type=int, default=TRAIN_MICROBATCHES)
    ap.add_argument("--layout", default="zero3", choices=["zero3", "tp"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    rec = run_cell(args.arch, args.shape, args.mesh == "multi",
                   skip_cost=args.skip_cost, microbatches=args.microbatches,
                   layout=args.layout)
    os.makedirs(args.out, exist_ok=True)
    suffix = f"__{args.tag}" if args.tag else ""
    path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback", "cost")}, indent=1))
    if rec["status"] != "ok":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
