"""AdamW in pure JAX (no optax dependency), with optional int8 gradient
compression hooks (distributed/grad_compress.py) applied by the train loop."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_adamw(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: AdamWState, lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, n):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        n = b2 * n + (1 - b2) * jnp.square(g)
        mh = m / c1
        nh = n / c2
        step_val = mh / (jnp.sqrt(nh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_val).astype(p.dtype), m, n

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_n = jax.tree.leaves(state.nu)
    outs = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_m, flat_n)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_n = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_n)
