"""Synthetic-but-deterministic data pipeline with resumable state.

Generates structured pseudo-text (Zipf-distributed token ids with short-range
repetition patterns a model can actually learn) so examples/train_smollm.py
shows decreasing loss.  The iterator state is a (seed, step) pair — trivially
checkpointable and restorable, which is what fault tolerance needs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int


def make_batch(state: DataState, batch: int, seq: int, vocab: int
               ) -> tuple[jax.Array, DataState]:
    rng = np.random.default_rng(state.seed * 1_000_003 + state.step)
    # Zipf body + learnable bigram structure: x[t+1] = (a*x[t]+c) % K patterns
    base = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    base = np.minimum(base, vocab - 1)
    a = rng.integers(3, 17, size=(batch, 1))
    mask = rng.random((batch, seq)) < 0.7
    lin = (a * np.arange(seq)[None, :] + 7) % max(vocab // 4, 2)
    toks = np.where(mask, lin, base).astype(np.int32)
    return jnp.asarray(toks), DataState(state.seed, state.step + 1)
