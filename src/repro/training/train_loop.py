"""Training loop: loss, microbatched grad accumulation, remat, train_step."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.training.optimizer import AdamWState, adamw_update


def loss_fn(params, cfg: ModelConfig, tokens: jax.Array,
            extras: dict | None = None) -> jax.Array:
    """Causal LM loss (teacher forcing, shift-by-one)."""
    logits = model_lib.forward(params, cfg, tokens, extras)
    # vlm: vision prefix positions produce no next-token loss
    start = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    logits = logits[:, start:, :][:, :-1].astype(jnp.float32)
    from repro.distributed import ctx
    logits = ctx.constrain(logits, kind="logits")
    tgt = tokens[:, 1:]
    # Vocab-sharding-friendly NLL: contract the (sharded) vocab dim with a
    # one-hot select instead of take_along_axis (which would gather the full
    # logits when the LM head is vocab-parallel).
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = (tgt[..., None] == jnp.arange(v)[None, None, :])
    picked = jnp.sum(logits * onehot, axis=-1)
    return (lse - picked).mean()


def make_train_step(cfg: ModelConfig, microbatches: int = 1, lr: float = 3e-4,
                    remat: bool = True, grad_transform=None):
    """Build a jit-able (params, opt_state, batch) -> (params, opt, loss).

    ``microbatches`` splits the global batch for gradient accumulation via
    lax.scan (bounds activation memory); ``grad_transform`` hooks gradient
    compression (distributed/grad_compress.py).
    """
    lfn = loss_fn
    if remat:
        lfn = jax.checkpoint(loss_fn, static_argnums=(1,))

    def train_step(params, opt_state: AdamWState, tokens, extras=None):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(lfn)(params, cfg, tokens, extras)
        else:
            mb = tokens.reshape(microbatches, -1, tokens.shape[-1])
            mbx = None
            if extras is not None:
                mbx = jax.tree.map(
                    lambda a: a.reshape((microbatches, -1) + a.shape[1:]),
                    extras)

            def acc_step(carry, xs):
                g_acc, l_acc = carry
                tok = xs[0]
                ex = xs[1] if mbx is not None else None
                loss, grads = jax.value_and_grad(lfn)(params, cfg, tok, ex)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros((), jnp.float32)),
                (mb, mbx) if mbx is not None else (mb,))
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        return params, opt_state, loss

    return train_step
