"""Elastic scaling: re-shard a job onto a different device count.

At 1000+-node scale, node loss means restarting on N' ≠ N devices.  Because
checkpoints store leaves unsharded (distributed/checkpoint.py) and every
sharding is *derived* (name+shape rules in distributed/sharding.py), elastic
restart is: build the mesh for the surviving devices, re-derive shardings,
device_put the restored pytree.  ``plan_remesh`` picks the new mesh shape;
``reshard_tree`` performs the placement.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import Mesh

from repro.distributed import sharding as shd


def plan_remesh(n_devices: int, prefer_model: int = 16,
                multi_pod_threshold: int = 512) -> tuple[tuple, tuple]:
    """Choose (shape, axis_names) for an arbitrary surviving device count.

    Keeps the model axis as close to ``prefer_model`` as divisibility allows
    (TP degree changes invalidate head-sharding less often than data-axis
    changes invalidate nothing).
    """
    model = math.gcd(n_devices, prefer_model)
    rest = n_devices // model
    if n_devices >= multi_pod_threshold and rest % 2 == 0:
        if rest >= 2:
            shape = (2, rest // 2, model)
        else:   # model axis swallowed every device: a single "pod"
            shape = (1, rest, model)
        return shape, ("pod", "data", "model")
    return (rest, model), ("data", "model")


def make_elastic_mesh(devices=None, prefer_model: int = 16) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    shape, names = plan_remesh(len(devices), prefer_model)
    import numpy as np

    return Mesh(np.array(devices).reshape(shape), names)


def reshard_params(params, mesh: Mesh):
    """Place a (restored, host-resident) param tree onto a new mesh."""
    shardings = shd.params_shardings(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params),
        mesh)
    return jax.tree.map(jax.device_put, params, shardings)


def reshard_cache(cache, mesh: Mesh, batch: int):
    shardings = shd.cache_shardings(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), cache),
        mesh, batch)
    return jax.tree.map(jax.device_put, cache, shardings)
