"""Fault-tolerant checkpointing (pure numpy — no orbax dependency).

Layout:  <dir>/step_<n>/shard_<i>.npz + manifest.json, written atomically
(tmp dir + rename).  Keeps the last ``keep`` steps.  Restore validates the
manifest (leaf count, shapes, dtypes) and can re-shard to a different device
count (elastic restart: arrays are stored unsharded per-leaf; placement is
re-derived from the current mesh by the caller via distributed/sharding.py).
The data-pipeline state (training/data.DataState) rides in the manifest so a
restarted job resumes mid-stream deterministically.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None,
                    keep: int = 3) -> str:
    """Atomically persist a pytree; returns the final path."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        arrs = {}
        for i, l in enumerate(leaves):
            a = np.asarray(l)
            if a.dtype.name == "bfloat16":  # npz has no native bf16
                # contiguity first: a strided bf16 view (e.g. a sliced KV
                # page payload) reinterprets to garbage under .view
                a = np.ascontiguousarray(a).view(np.uint16)
            arrs[f"leaf_{i}"] = a
        np.savez(os.path.join(tmp, "shard_0.npz"), **arrs)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [list(np.shape(l)) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    valid = [d for d in steps
             if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return int(valid[-1].split("_")[1]) if valid else None


def restore_checkpoint(ckpt_dir: str, like_tree, step: int | None = None
                       ) -> tuple[object, dict]:
    """Restore into the structure of ``like_tree``; returns (tree, extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    leaves_like, treedef = _flatten(like_tree)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"model expects {len(leaves_like)}")
    if manifest["treedef"] != str(treedef):
        # same leaf count but different structure would silently restore
        # leaves into the wrong slots (e.g. a fleet blob set whose rid
        # keys changed between save and restore)
        raise ValueError(
            f"checkpoint treedef does not match like_tree:\n"
            f"  saved:    {manifest['treedef']}\n"
            f"  expected: {treedef}")
    import ml_dtypes

    leaves = []
    for i, like in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        saved_dt = manifest["dtypes"][i]
        if saved_dt == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        want_dt = str(np.asarray(like).dtype)
        if saved_dt != want_dt:
            # a silent .view into the caller's dtype is exactly the bf16
            # corruption this guard exists for: restored bytes must mean
            # what the like_tree says they mean
            raise ValueError(f"leaf {i}: checkpoint dtype {saved_dt} != "
                             f"expected {want_dt}")
        if list(arr.shape) != list(np.shape(like)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != "
                             f"{np.shape(like)}")
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves), manifest["extra"]
