"""Trace-time context for distribution concerns that cut across model code.

The model zoo stays pure; the launcher configures, per lowering:
  * ``constrain``    — sharding constraint applied to the residual stream at
                       layer boundaries (sequence-parallel activations);
  * ``remat``        — per-layer rematerialization inside layer scans;
  * ``unroll_scans`` — unroll lax.scan loops (used by the roofline cost
                       variants so cost_analysis sees every layer; while-loop
                       bodies are otherwise counted once).
"""

from __future__ import annotations

import contextlib
from typing import Callable

import jax

_STATE = {
    "constrain": None,   # Callable[[jax.Array], jax.Array] | None
    "remat": False,
    "unroll_scans": False,
    "mesh": None,        # jax.sharding.Mesh | None — enables shard_map paths
}


@contextlib.contextmanager
def lowering_ctx(constrain: Callable | None = None, remat: bool = False,
                 unroll_scans: bool = False, mesh=None):
    old = dict(_STATE)
    _STATE.update(constrain=constrain, remat=remat,
                  unroll_scans=unroll_scans, mesh=mesh)
    try:
        yield
    finally:
        _STATE.update(old)


def mesh():
    return _STATE["mesh"]


def constrain(x: jax.Array, kind: str = "resid") -> jax.Array:
    fn = _STATE["constrain"]
    return fn(x, kind) if fn is not None else x


def maybe_remat(f):
    return jax.checkpoint(f) if _STATE["remat"] else f


def scan(f, init, xs, **kw):
    if _STATE["unroll_scans"]:
        kw["unroll"] = True
    return jax.lax.scan(f, init, xs, **kw)
