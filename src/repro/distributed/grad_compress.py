"""Int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce at 1000+-node scale).

quantize -> all-reduce int8 (4x less ICI traffic than f32) -> dequantize;
the residual (g - dequant(quant(g))) is carried to the next step so the
compression is unbiased over time (error-feedback SGD, Seide et al. 2014 /
Karimireddy et al. 2019).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import compat


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def psum_compressed(g: jax.Array, axis: str) -> jax.Array:
    """Mean-all-reduce in int8 over a mesh axis (inside shard_map).

    The quantization scale is agreed globally first (pmax of |g|) so the
    int8 payloads are commensurable; ICI moves 1/4 the bytes of f32.
    """
    absmax = jax.lax.pmax(jnp.max(jnp.abs(g.astype(jnp.float32))), axis)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    summed = jax.lax.psum(q.astype(jnp.int32), axis)
    return summed.astype(jnp.float32) * scale / compat.axis_size(axis)


def make_error_feedback_transform():
    """Stateless-from-jit's-view transform: error buffers ride in opt extras.

    Returns (init_state, transform) where transform(grads, state) ->
    (compressed_grads, new_state)."""

    def init_state(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def transform(grads, err):
        def one(g, e):
            g = g.astype(jnp.float32) + e
            q, s = compress(g)
            deq = decompress(q, s)
            return deq, g - deq

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
                jax.tree.unflatten(tdef, [o[1] for o in outs]))

    return init_state, transform
