"""Sharding rules: params / caches / batches → PartitionSpec trees.

Baseline layout (paper-faithful "flash tier", DESIGN.md §2):
  * ``data``  axis: ZeRO-3-style parameter sharding (the capacity tier that
    plays the NAND flash role) + batch data parallelism;
  * ``model`` axis: tensor parallelism (attention heads / FFN hidden / expert
    parallelism / KV-sequence for decode);
  * ``pod``   axis (multi-pod): pure data parallelism on top.

Rules are name+shape driven with divisibility fallbacks, so every assigned
architecture (including awkward dims like smollm's 15 heads, qwen2-moe's 60
experts — padded to 64 — and mamba2's 3352-wide in_proj) gets a legal spec.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# parent-dict names that mark a linear layer's weight
_LINEAR_KEYS = {"q", "k", "v", "o", "gate", "up", "down", "in_proj",
                "out_proj", "router", "kv_a", "kv_b", "lm_head", "xattn"}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.shape and n % mesh.shape[axis] == 0 and n > 0


def _matmul_spec(shape, mesh: Mesh, transposed: bool = False,
                 zero3: bool = True):
    """Spec for a linear weight [..., in, out] (or [..., out, in] if
    transposed, the W8A8 layout). Leading dims are layer stacks (replicated).
    zero3=True: out->model (TP) + in->data (the paper-faithful "flash tier" —
    weights stream via all-gather, the ship-weights path).
    zero3=False: TP-only residency (the planner's ship-activations answer for
    decode; §Perf hillclimb layout)."""
    nd = len(shape)
    d_in = shape[-1] if transposed else shape[-2]
    d_out = shape[-2] if transposed else shape[-1]
    in_ax = out_ax = None
    if _div(d_out, mesh, "model"):
        out_ax = "model"
        if zero3 and _div(d_in, mesh, "data"):
            in_ax = "data"
    elif _div(d_in, mesh, "model"):
        # TP on the contraction dim instead (mamba2-130m's ragged out dims)
        in_ax = "model"
    elif zero3 and _div(d_in, mesh, "data"):
        in_ax = "data"
    dims = [None] * nd
    if transposed:
        dims[-1], dims[-2] = in_ax, out_ax
    else:
        dims[-2], dims[-1] = in_ax, out_ax
    return P(*dims)


def _expert_spec(shape, mesh: Mesh, zero3: bool = True):
    """MoE expert stacks [..., E, in, out]: expert-parallel on model."""
    nd = len(shape)
    dims = [None] * nd
    if _div(shape[-3], mesh, "model"):
        dims[-3] = "model"
        if zero3 and _div(shape[-2], mesh, "data"):
            dims[-2] = "data"
    else:
        return _matmul_spec(shape, mesh, zero3=zero3)
    return P(*dims)


def _vector_spec(shape, mesh: Mesh, prefer: str = "model"):
    dims = [None] * len(shape)
    if len(shape) and _div(shape[-1], mesh, prefer):
        dims[-1] = prefer
    return P(*dims)


def param_pspec(path: tuple, leaf, mesh: Mesh, zero3: bool = True) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = "/".join(str(k) for k in keys)
    shape = leaf.shape
    parent = keys[-2] if len(keys) >= 2 else ""
    last = keys[-1] if keys else ""

    if last == "embed" or name.endswith("pos_embed") or last == "enc_pos":
        # [V, D]: vocab -> model (big tables), hidden -> data when divisible
        dims = [None] * len(shape)
        if _div(shape[0], mesh, "model"):
            dims[0] = "model"
        if len(shape) > 1 and _div(shape[-1], mesh, "data"):
            dims[-1] = "data"
        return P(*dims)
    if last in ("w", "w_q", "scale", "b") and parent == "router":
        if last == "w":
            return P(*([None] * (len(shape) - 2) + [None, None]))
        return P(*([None] * len(shape)))
    if last == "w" and parent in _LINEAR_KEYS:
        return _matmul_spec(shape, mesh, zero3=zero3)
    if last == "w_q" and parent in _LINEAR_KEYS:
        return _matmul_spec(shape, mesh, transposed=True, zero3=zero3)
    if last == "scale" and parent in _LINEAR_KEYS:
        # follows w_q's out dim = scale's last dim
        dims = [None] * len(shape)
        if _div(shape[-1], mesh, "model"):
            dims[-1] = "model"
        return P(*dims)
    if last == "b" and parent in _LINEAR_KEYS:
        return _vector_spec(shape, mesh)
    if parent == "moe" or (len(keys) >= 2 and keys[-2] == "moe") or \
            (last in ("gate", "up", "down") and len(shape) >= 3
             and parent not in _LINEAR_KEYS):
        return _expert_spec(shape, mesh, zero3=zero3)
    if last == "conv_w":
        return _vector_spec(shape, mesh)  # channels -> model when divisible
    if last in ("conv_b", "norm"):
        return _vector_spec(shape, mesh)
    # norms, dt_bias, a_log, d_skip, thresholds... replicate
    return P(*([None] * len(shape)))


def params_shardings(param_specs, mesh: Mesh, zero3: bool = True):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_pspec(path, leaf, mesh, zero3)),
        param_specs)


# ---------------------------------------------------------------------------
# activations / caches / batches
# ---------------------------------------------------------------------------


def dp_axes(mesh: Mesh):
    """Batch data-parallel axes: ('pod','data') when the pod axis exists."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_pspec(mesh: Mesh, batch: int, ndim: int = 2) -> P:
    axes = dp_axes(mesh)
    total = 1
    used = []
    for a in axes:
        if batch % (total * mesh.shape[a]) == 0:
            used.append(a)
            total *= mesh.shape[a]
    dims = [tuple(used) if used else None] + [None] * (ndim - 1)
    return P(*dims)


def cache_pspec(path: tuple, leaf, mesh: Mesh, batch: int) -> P:
    """KV caches [L, B, S, Hkv, Dh]: batch -> dp axes (when divisible),
    sequence -> model (flash-decoding style split-K; kv-head counts are
    often < model axis, sequence always divides).  SSM states: batch -> dp,
    last dim -> model when divisible."""
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    last = keys[-1] if keys else ""
    shape = leaf.shape
    if last == "len" or len(shape) <= 1:
        return P()
    bspec = batch_pspec(mesh, batch, 1)[0]
    dims: list[Any] = [None] * len(shape)
    if last in ("k", "v", "xk", "xv", "ckv", "krope"):
        # [L, B, S, Hkv, Dh] or [L, B, S, R]: prefer kv-heads -> model
        # (local attention per head, no cross-shard softmax); fall back to
        # sequence -> model (flash-decoding split-K) for kv < model.  When the
        # batch can't use the dp axes (long_500k: B=1), the sequence takes
        # them instead — a 500k-token cache then shards 256-way.
        dims[1] = bspec
        seq_ax = None
        if bspec is None and _div(shape[2], mesh, "data"):
            seq_ax = "data"
        if len(shape) >= 5 and _div(shape[3], mesh, "model"):
            dims[3] = "model"
            dims[2] = seq_ax
        elif seq_ax is not None:
            dims[2] = (seq_ax, "model") if _div(
                shape[2], mesh, "model") and shape[2] % (
                mesh.shape["model"] * mesh.shape["data"]) == 0 else seq_ax
        elif _div(shape[2], mesh, "model"):
            dims[2] = "model"
        return P(*dims)
    # mamba caches: conv [*, B, K-1, C], state [*, B, H, P, N]
    b_axis = len(shape) - 3 if last == "conv" else len(shape) - 4
    b_axis = max(b_axis, 0)
    dims[b_axis] = bspec
    if _div(shape[-1], mesh, "model"):
        dims[-1] = "model"
    return P(*dims)


def cache_shardings(cache_specs, mesh: Mesh, batch: int):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_pspec(path, leaf, mesh, batch)),
        cache_specs)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
