"""GPipe-style pipeline parallelism over the ``pod`` axis (multi-pod option).

The multi-pod mesh's ``pod`` axis defaults to data parallelism; for models
whose per-pod weight residency is the constraint, ``pipelined_forward`` runs
the layer stack split into ``pod`` stages with microbatch rotation via
``collective-permute`` (the canonical shard_map pipeline: all stages run the
same program; microbatch m enters stage s at step m+s).

This is a library primitive with a small-scale correctness test; the dry-run
exercises it through launch/dryrun.py --pipeline (optional mode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.compat import shard_map


def pipelined_forward(layer_fn, params_stages, x_microbatches, mesh: Mesh,
                      axis: str = "pod"):
    """Run ``x`` through layers split into ``n = |axis|`` stages.

    layer_fn(stage_params, x) -> x ; params_stages: pytree with leading dim n
    (stacked per-stage parameters, sharded P(axis)); x_microbatches:
    [m, mb, ...] microbatched inputs (replicated). Returns [m, mb, ...].
    """
    n = mesh.shape[axis]

    def body(stage_params, xs):
        stage = jax.lax.axis_index(axis)
        m = xs.shape[0]
        steps = m + n - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def step(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others take the permuted
            # output of the previous stage from the previous step.
            take = jnp.clip(t, 0, m - 1)
            inp = jnp.where(stage == 0, xs[take], buf)
            out = layer_fn(jax.tree.map(lambda a: a[0], stage_params), inp)
            # rotate stage s -> s+1
            buf_next = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n) for i in range(n)])
            # last stage emits microbatch t-(n-1)
            emit_idx = jnp.clip(t - (n - 1), 0, m - 1)
            valid = (t >= n - 1) & (stage == n - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[emit_idx].set(out),
                lambda o: o, outs)
            return buf_next, outs

        buf, outs = jax.lax.fori_loop(0, steps, step, (buf, outs))
        # broadcast the last stage's outputs to every stage for a replicated
        # return value (psum of masked contributions)
        outs = jax.lax.psum(
            jnp.where(stage == n - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(*([None] * x_microbatches.ndim))),
        out_specs=P(*([None] * x_microbatches.ndim)),
        check_vma=False,
    )(params_stages, x_microbatches)
