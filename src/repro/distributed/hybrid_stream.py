"""Double-buffered weight streaming — the TPU realization of Slice Control.

The paper interleaves NPU-bound weight reads into the channel bubbles left by
read-compute requests.  On a TPU mesh the same idea: while layer k computes
on its (already gathered) weights, layer k+1's ZeRO-3-sharded weights
all-gather in the background.  Expressed with shard_map + ppermute-based ring
all-gather structured so XLA can overlap the collective with the compute
(the collective for step k+1 has no data dependency on step k's compute).

``streamed_matmul_chain`` is the demonstrable primitive: y = x @ W1 @ W2 ...
with every Wi sharded over ``axis`` and gathered one step ahead.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import compat
from repro.kernels.compat import shard_map


def ring_all_gather(shard: jax.Array, axis: str) -> jax.Array:
    """All-gather along ``axis`` via ppermute ring (overlappable)."""
    n = compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(i, carry):
        acc, buf = carry
        buf = jax.lax.ppermute(buf, axis, perm)
        src = (idx - i - 1) % n
        acc = jax.lax.dynamic_update_index_in_dim(acc, buf, src, 0)
        return acc, buf

    acc0 = jnp.zeros((n,) + shard.shape, shard.dtype)
    acc0 = jax.lax.dynamic_update_index_in_dim(acc0, shard, idx, 0)
    acc, _ = jax.lax.fori_loop(1, n, lambda i, c: body(i - 1, c),
                               (acc0, shard))
    return acc.reshape((n * shard.shape[0],) + shard.shape[1:])


def streamed_matmul_chain(x: jax.Array, weight_shards: list[jax.Array],
                          mesh: Mesh, axis: str = "data") -> jax.Array:
    """x: [B, D0]; weight_shards[i]: [Di/n, Di+1] sharded on ``axis``.

    Gathers W_{i+1} while computing x @ W_i (double buffering): inside
    shard_map the gather for the next layer is issued before the current
    matmul, so the scheduler can overlap them.
    """

    def body(x_loc, *shards):
        nxt = ring_all_gather(shards[0], axis)
        for i in range(len(shards)):
            w = nxt
            if i + 1 < len(shards):
                nxt = ring_all_gather(shards[i + 1], axis)  # prefetch
            x_loc = x_loc @ w.astype(x_loc.dtype)
        return x_loc

    in_specs = tuple([P(None, None)] + [P(axis, None)] * len(weight_shards))
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=P(None, None),
                     check_vma=False)(x, *weight_shards)


def alpha_split_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                       alpha: float, axis_store: str = "data",
                       axis_tp: str = "model") -> jax.Array:
    """Paper's α-split on a TPU mesh (core/partition_plan.py decides α).

    Rows [0, αH) run ship-activations (weights stay sharded on
    ``axis_store``, partial matvec + psum — "read-compute request"); rows
    [αH, H) run ship-weights (all-gather then local matmul — "read request").
    Numerically identical to x @ w; structurally the two collective schedules
    coexist so the compiler can overlap them (the paper's channel-bubble
    filling).
    """
    d, h = w.shape
    h_act = int(alpha * h)

    def body(x_full, w_act_shard, w_gat_shard):
        parts = []
        if h_act:
            # "read-compute": W sharded on the contraction dim; every shard
            # computes a partial GeMM on resident weights, small output psum'd
            n = compat.axis_size(axis_store)
            i = jax.lax.axis_index(axis_store)
            x_slice = jax.lax.dynamic_slice_in_dim(
                x_full, i * (d // n), d // n, axis=1)
            parts.append(jax.lax.psum(
                x_slice @ w_act_shard.astype(x_full.dtype), axis_store))
        if h_act < h:
            # "read": stream (gather) the weight rows, compute locally
            w_gat = ring_all_gather(w_gat_shard, axis_store)
            parts.append(x_full @ w_gat.astype(x_full.dtype))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, -1)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P(axis_store, None), P(axis_store, None)),
        out_specs=P(None, None), check_vma=False,
    )(x, w[:, :h_act], w[:, h_act:])
