"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

from repro.configs.base import (ALL_SHAPES, InputShape, ModelConfig,
                                shape_applicable)
from repro.configs.chatglm3_6b import CONFIG as CHATGLM3_6B
from repro.configs.command_r_plus_104b import CONFIG as COMMAND_R_PLUS_104B
from repro.configs.deepseek_v2_lite_16b import CONFIG as DEEPSEEK_V2_LITE_16B
from repro.configs.internlm2_20b import CONFIG as INTERNLM2_20B
from repro.configs.mamba2_130m import CONFIG as MAMBA2_130M
from repro.configs.paper_models import PAPER_MODELS
from repro.configs.qwen2_moe_a2_7b import CONFIG as QWEN2_MOE_A2_7B
from repro.configs.qwen2_vl_72b import CONFIG as QWEN2_VL_72B
from repro.configs.smollm_360m import CONFIG as SMOLLM_360M
from repro.configs.whisper_small import CONFIG as WHISPER_SMALL
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B

ASSIGNED_ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        DEEPSEEK_V2_LITE_16B,
        QWEN2_MOE_A2_7B,
        QWEN2_VL_72B,
        SMOLLM_360M,
        COMMAND_R_PLUS_104B,
        INTERNLM2_20B,
        CHATGLM3_6B,
        WHISPER_SMALL,
        ZAMBA2_7B,
        MAMBA2_130M,
    ]
}

ARCHS: dict[str, ModelConfig] = {**ASSIGNED_ARCHS, **PAPER_MODELS}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def dryrun_cells() -> list[tuple[ModelConfig, InputShape]]:
    """Every runnable (assigned arch × shape) baseline cell."""
    cells = []
    for cfg in ASSIGNED_ARCHS.values():
        for shape in ALL_SHAPES:
            ok, _ = shape_applicable(cfg, shape)
            if ok:
                cells.append((cfg, shape))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for cfg in ASSIGNED_ARCHS.values():
        for shape in ALL_SHAPES:
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                out.append((cfg.name, shape.name, why))
    return out
