"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) d_ff=1408 vocab=151936; 60 routed top-4 +
4 shared experts (the HF model's single 5632-wide shared expert == 4×1408;
we implement 4 shared experts of moe_d_ff each, equivalent capacity).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    rope_theta=1.0e6,
    use_bias=True,  # qwen qkv bias
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
)
