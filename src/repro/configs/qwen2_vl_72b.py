"""qwen2-vl-72b [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. M-RoPE; dynamic
resolution.  The vision frontend is a STUB per the assignment: input_specs()
provides precomputed patch embeddings (n_vision_tokens, d_model) that are
prepended to the text sequence; M-RoPE positions (temporal/h/w) are computed
for both segments.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    rope_theta=1.0e6,
    rope_mode="mrope",
    use_bias=True,
    n_vision_tokens=256,
)
