"""zamba2-7b [arXiv:2411.15242; unverified].

81 Mamba2 layers, d_model=3584, d_ff=14336, vocab=32000, ssm_state=64, plus a
SHARED attention block (32H, kv=32) applied every 6 mamba layers (weights
reused at each application — Zamba2's shared-block design). Layout:
13 × (6 mamba + shared attn) + 3 tail mamba layers.

Sub-quadratic flag: the backbone is SSM; the shared-attn KV at 524288 tokens ×
batch 1 is ~13 invocation caches, shardable — long_500k runs.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1.0e4,
    ssm_state=64,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_expand=2,
    shared_attn_every=6,
    sub_quadratic=True,
)
