"""The paper's own evaluation models (OPT family [arXiv:2205.01068] and
Llama-2 family [arXiv:2307.09288]) — used by the sim/ benchmarks that
reproduce Figs 9/11/12/13/14/15/16."""

from repro.configs.base import ModelConfig


def _opt(name, n_layers, d_model, n_heads, vocab=50272):
    return ModelConfig(
        name=name, family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_heads, d_ff=4 * d_model,
        vocab_size=vocab, rope_mode="learned", use_bias=True,
        gated_ffn=False, norm="ln", tie_embeddings=True,
    )


OPT_6_7B = _opt("opt-6.7b", 32, 4096, 32)
OPT_13B = _opt("opt-13b", 40, 5120, 40)
OPT_30B = _opt("opt-30b", 48, 7168, 56)
OPT_66B = _opt("opt-66b", 64, 9216, 72)

LLAMA2_7B = ModelConfig(
    name="llama2-7b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=32, d_ff=11008, vocab_size=32000)
LLAMA2_13B = ModelConfig(
    name="llama2-13b", family="dense", n_layers=40, d_model=5120, n_heads=40,
    n_kv_heads=40, d_ff=13824, vocab_size=32000)
LLAMA2_70B = ModelConfig(
    name="llama2-70b", family="dense", n_layers=80, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=28672, vocab_size=32000)

PAPER_MODELS = {
    m.name: m for m in [OPT_6_7B, OPT_13B, OPT_30B, OPT_66B,
                        LLAMA2_7B, LLAMA2_13B, LLAMA2_70B]
}
