"""deepseek-v2-lite-16b [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff=1408(MoE) vocab=102400; MLA kv_lora=512;
2 shared + 64 routed experts, top-6 (assignment header; the "160 routed" tail
note conflicts — we follow the primary spec, matching HF DeepSeek-V2-Lite).
First layer is dense with d_ff=10944 (HF config: first_k_dense_replace=1).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="mla_moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=192,  # qk_nope(128) + qk_rope(64)
    d_ff=1408,
    vocab_size=102400,
    rope_theta=1.0e4,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_k_dense=1,
    dense_d_ff=10944,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)
