"""chatglm3-6b [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024. "RoPE 2d": rotary
applied to half of each head dim (rope_fraction=0.5). QKV bias (chatglm
uses add_qkv_bias=True).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_theta=1.0e4,
    rope_fraction=0.5,
    use_bias=True,
)
