"""whisper-small [arXiv:2212.04356; unverified].

12L enc + 12L dec, d_model=768 12H (kv=12) d_ff=3072 vocab=51865. Enc-dec
with conv audio frontend STUBBED per the assignment: input_specs() provides
precomputed frame embeddings (encoder_seq=1500, d_model). Learned positions.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    rope_mode="learned",
    use_bias=True,
    gated_ffn=False,
    norm="ln",
    is_encoder_decoder=True,
    n_encoder_layers=12,
    encoder_seq=1500,
)
