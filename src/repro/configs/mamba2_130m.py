"""mamba2-130m [arXiv:2405.21060; unverified].

24L d_model=768 attention-free, vocab=50280, ssm_state=128, SSD (state-space
duality) with headdim=64 (nheads = 2*768/64 = 24), ngroups=1, conv=4.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    rope_mode="none",
    ssm_state=128,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
    sub_quadratic=True,
)
