"""Config system: architecture + input-shape descriptions.

``ModelConfig`` is the single source of truth consumed by models/, the
Cambricon-LLM planner (core/planner.py), the simulator (sim/llm_perf.py), the
sharding rules (distributed/sharding.py) and the dry-run launcher.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | mla_moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # --- positional / structural flags ---
    rope_theta: float = 1.0e4
    rope_fraction: float = 1.0  # chatglm3 "2d rope": rotary on half the head dim
    rope_mode: str = "standard"  # standard | mrope | learned | none
    tie_embeddings: bool = False
    parallel_block: bool = False  # command-r: attn and FFN in parallel
    use_bias: bool = False
    gated_ffn: bool = True  # SwiGLU-style; False -> 2-matrix GELU/ReLU MLP (OPT, whisper)
    norm: str = "rms"  # "rms" | "ln"
    norm_eps: float = 1e-5

    # --- MoE ---
    n_experts: int = 0          # routed experts
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # per-expert FFN width
    first_k_dense: int = 0      # leading dense layers (deepseek)
    dense_d_ff: int = 0         # width of those dense layers

    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_expand: int = 2

    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0  # apply the shared attention block every k layers

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # stub frontend: precomputed frame embeddings

    # --- vlm (qwen2-vl) ---
    n_vision_tokens: int = 0  # stub frontend: precomputed patch embeddings

    sub_quadratic: bool = False  # supports long_500k decode

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        from repro.core.planner import model_matrices

        total = 0
        for m in model_matrices(self):
            total += m.h * m.w * m.count
        # norms + small vectors are negligible but add d_model per layer-ish
        total += 2 * self.n_layers * self.d_model
        return total

    def active_param_count(self) -> int:
        from repro.core.planner import model_matrices

        total = 0
        for m in model_matrices(self):
            total += m.h * m.w * (m.count if not m.is_expert else m.active_count)
        return total

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test configuration of the same family (runs on 1 CPU)."""
        scale = {
            "n_layers": min(self.n_layers, 2),
            "d_model": 64,
            "n_heads": 4,
            "n_kv_heads": min(max(self.n_kv_heads, 1), 2) if self.n_heads else 0,
            "d_head": 16,
            "d_ff": 128,
            "vocab_size": 256,
        }
        extra = {}
        if self.n_experts:
            extra.update(n_experts=8, top_k=min(self.top_k, 2), moe_d_ff=32,
                         n_shared_experts=min(self.n_shared_experts, 1))
        if self.first_k_dense:
            extra.update(first_k_dense=1, dense_d_ff=128)
        if self.kv_lora_rank:
            extra.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        if self.ssm_state:
            extra.update(ssm_state=16, ssm_headdim=16, ssm_ngroups=1)
        if self.shared_attn_every:
            extra.update(shared_attn_every=2, n_layers=5)
        if self.is_encoder_decoder:
            extra.update(n_encoder_layers=2, encoder_seq=16)
        if self.n_vision_tokens:
            extra.update(n_vision_tokens=8)
        return dataclasses.replace(self, name=self.name + "-reduced", **{**scale, **extra})


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k-token decode needs "
                       "sub-quadratic attention (run only for ssm/hybrid)")
    return True, ""
