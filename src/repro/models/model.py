"""Unified functional model: init / forward / prefill / decode per family.

Layer stacks are scanned (params stacked on a leading layer axis) so that
lowering stays compact for 80-layer models.  Heterogeneous pieces (deepseek's
first dense layer, zamba2's shared block and tail) are unstacked.

Public API (all pure functions):
    init_params(cfg, key, dtype, max_seq)        -> params
    forward(params, cfg, tokens, extras)         -> logits [B, S, V]
    init_cache(cfg, batch, max_seq, dtype)       -> cache
    prefill(params, cfg, tokens, cache, extras)  -> (last_logits, cache)
    decode_step(params, cfg, token, cache)       -> (logits, cache)

Paged per-slot variants (continuous batching; dense/vlm/moe page full K/V,
mla_moe pages the compressed ckv+krope rows, hybrid pages the shared-attn
KV and keeps Mamba state in a slot-indexed state pool):
    init_paged_cache(cfg, slots, max_seq, dtype, page_size)   -> cache
    prefill_into_slots(params, cfg, tokens, true_lens, cache, slot_ids,
                       extras)                   -> (last_logits [M, V], cache)
    prefill_into_slot(params, cfg, tokens, true_len, cache, slot, extras)
                                                 -> (last_logits [V], cache)
    prefill_chunk_into_slot(params, cfg, tokens, start, chunk_len, cache,
                            slot)                -> (last_logits [V], cache)
    decode_step_paged(params, cfg, token, cache, active)
                                                 -> (logits [B, V], cache)
    swap_out_pages(cache, page_ids)              -> (k_pages, v_pages)
    swap_in_pages(cache, page_ids, ks, vs)       -> cache

The legacy cache keeps ONE shared length cursor (``cache["len"]``) — every
slot advances in lockstep, which forces wave admission in the serving
engine.  The paged cache keeps a per-slot length vector and a block table
into a shared page pool, so any slot can prefill/decode/free independently.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.models import attention, blocks, moe as moe_mod, ssm as ssm_mod
from repro.models.layers import (ffn, init_ffn, init_linear, linear,
                                 mrope_positions)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _init_dense_layer(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "attn_norm": blocks.init_norm(cfg, dtype),
        "attn": blocks.init_attn(k1, cfg, dtype),
        "ffn_norm": blocks.init_norm(cfg, dtype),
        "ffn": init_ffn(k2, cfg, cfg.d_ff, dtype),
    }


def _init_moe_layer(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": blocks.init_norm(cfg, dtype),
        "attn": blocks.init_attn(k1, cfg, dtype),
        "ffn_norm": blocks.init_norm(cfg, dtype),
        "moe": moe_mod.init_moe(k2, cfg, dtype),
    }


def _init_mla_layer(key, cfg: ModelConfig, dtype, dense_ffn: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": blocks.init_norm(cfg, dtype),
        "attn": blocks.init_mla(k1, cfg, dtype),
        "ffn_norm": blocks.init_norm(cfg, dtype),
    }
    if dense_ffn:
        p["ffn"] = init_ffn(k2, cfg, cfg.dense_d_ff, dtype)
    else:
        p["moe"] = moe_mod.init_moe(k2, cfg, dtype)
    return p


def _init_audio_dec_layer(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": blocks.init_norm(cfg, dtype),
        "attn": blocks.init_attn(k1, cfg, dtype),
        "xattn_norm": blocks.init_norm(cfg, dtype),
        "xattn": blocks.init_attn(k2, cfg, dtype),
        "ffn_norm": blocks.init_norm(cfg, dtype),
        "ffn": init_ffn(k3, cfg, cfg.d_ff, dtype),
    }


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.bfloat16,
                max_seq: int = 4096) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 16)
    p: dict = {}
    p["embed"] = (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype)
    p["final_norm"] = blocks.init_norm(cfg, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(keys[-2], cfg.d_model, cfg.vocab_size,
                                   False, dtype)
    f = cfg.family
    if f in ("dense", "vlm"):
        p["layers"] = _stack([_init_dense_layer(keys[i], cfg, dtype)
                              for i in range(cfg.n_layers)])
    elif f == "moe":
        p["layers"] = _stack([_init_moe_layer(keys[i], cfg, dtype)
                              for i in range(cfg.n_layers)])
    elif f == "mla_moe":
        p["dense_layers"] = _stack(
            [_init_mla_layer(keys[i], cfg, dtype, True)
             for i in range(cfg.first_k_dense)])
        p["layers"] = _stack(
            [_init_mla_layer(keys[cfg.first_k_dense + i], cfg, dtype, False)
             for i in range(cfg.n_layers - cfg.first_k_dense)])
    elif f == "audio":
        enc_cfg = dataclasses.replace(cfg, rope_mode="none")
        p["enc_layers"] = _stack([_init_dense_layer(keys[i], enc_cfg, dtype)
                                  for i in range(cfg.n_encoder_layers)])
        p["layers"] = _stack(
            [_init_audio_dec_layer(keys[cfg.n_encoder_layers + i], cfg, dtype)
             for i in range(cfg.n_layers)])
        p["enc_pos"] = (jax.random.normal(
            keys[-3], (cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02
            ).astype(dtype)
        p["enc_final_norm"] = blocks.init_norm(cfg, dtype)
    elif f == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        tail = cfg.n_layers - n_groups * every
        p["groups"] = _stack([
            _stack([ssm_mod.init_mamba_block(
                jax.random.fold_in(keys[gi], li), cfg, dtype)
                for li in range(every)])
            for gi in range(n_groups)])
        p["tail"] = _stack([ssm_mod.init_mamba_block(keys[-4 - i], cfg, dtype)
                            for i in range(tail)]) if tail else None
        p["shared"] = _init_dense_layer(keys[-5], cfg, dtype)
    elif f == "ssm":
        p["layers"] = _stack([ssm_mod.init_mamba_block(keys[i], cfg, dtype)
                              for i in range(cfg.n_layers)])
    else:
        raise ValueError(f)
    if cfg.rope_mode == "learned":
        p["pos_embed"] = (jax.random.normal(
            keys[-6], (max_seq, cfg.d_model), jnp.float32) * 0.02).astype(dtype)
    return p


def lm_head(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = (x.astype(jnp.float32)
                  @ params["embed"].T.astype(jnp.float32))
    else:
        from repro.models.layers import dense_weight
        logits = x.astype(jnp.float32) @ dense_weight(
            params["lm_head"]).astype(jnp.float32)
    return ctx.constrain(logits, kind="logits")


# ---------------------------------------------------------------------------
# full-sequence layer applications (train / prefill): return cache entries
# ---------------------------------------------------------------------------


def _dense_layer_full(lp, x, cfg: ModelConfig, positions, causal=True):
    h = blocks.norm(cfg, lp["attn_norm"], x)
    attn_out, k, v = blocks.attn_full(lp["attn"], h, cfg, positions, causal)
    if cfg.parallel_block:
        f = ffn(lp["ffn"], h, cfg.gated_ffn)  # same normed input (command-r)
        x = x + attn_out + f
    else:
        x = x + attn_out
        x = x + ffn(lp["ffn"], blocks.norm(cfg, lp["ffn_norm"], x),
                    cfg.gated_ffn)
    return x, (k, v)


def _moe_layer_full(lp, x, cfg: ModelConfig, positions):
    h = blocks.norm(cfg, lp["attn_norm"], x)
    attn_out, k, v = blocks.attn_full(lp["attn"], h, cfg, positions)
    x = x + attn_out
    x = x + moe_mod.moe_ffn(lp["moe"],
                            blocks.norm(cfg, lp["ffn_norm"], x), cfg)
    return x, (k, v)


def _mla_layer_full(lp, x, cfg: ModelConfig, positions, dense: bool):
    h = blocks.norm(cfg, lp["attn_norm"], x)
    attn_out, ckv, krope = blocks.mla_full(lp["attn"], h, cfg, positions)
    x = x + attn_out
    h2 = blocks.norm(cfg, lp["ffn_norm"], x)
    if dense:
        x = x + ffn(lp["ffn"], h2, cfg.gated_ffn)
    else:
        x = x + moe_mod.moe_ffn(lp["moe"], h2, cfg)
    return x, (ckv, krope)


def _audio_dec_layer_full(lp, x, cfg: ModelConfig, positions, enc_out):
    h = blocks.norm(cfg, lp["attn_norm"], x)
    attn_out, k, v = blocks.attn_full(lp["attn"], h, cfg, positions)
    x = x + attn_out
    h = blocks.norm(cfg, lp["xattn_norm"], x)
    xout, xk, xv = blocks.attn_full(lp["xattn"], h, cfg, positions,
                                    causal=False, kv_override=enc_out)
    x = x + xout
    x = x + ffn(lp["ffn"], blocks.norm(cfg, lp["ffn_norm"], x), cfg.gated_ffn)
    return x, (k, v, xk, xv)


# ---------------------------------------------------------------------------
# forward (train) / prefill
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _embed_lookup(embed, tokens):
    return embed[tokens]


def _embed_lookup_fwd(embed, tokens):
    # the embed residual is only used for shape/dtype (it's a live param, so
    # keeping the reference costs nothing)
    return embed[tokens], (tokens, embed)


def _embed_lookup_bwd(res, ct):
    """Keep the scatter-add cotangent sharded: without the constraints GSPMD
    materializes the full [B,S,D] f32 cotangent replicated (22 GB/device on
    command-r train_4k)."""
    tokens, embed = res
    ct = ctx.constrain(ct.astype(jnp.float32))
    g = jnp.zeros(embed.shape, jnp.float32).at[tokens].add(ct)
    return ctx.constrain(g, kind="embed").astype(embed.dtype), None


_embed_lookup.defvjp(_embed_lookup_fwd, _embed_lookup_bwd)


def _embed(params, cfg: ModelConfig, tokens, extras):
    x = _embed_lookup(params["embed"], tokens)
    if cfg.family == "vlm":
        vis = extras["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    if cfg.rope_mode == "learned":
        s = x.shape[1]
        x = x + params["pos_embed"][:s][None]
    return x


def _positions(cfg: ModelConfig, batch, seq):
    if cfg.rope_mode == "mrope":
        return mrope_positions(batch, seq, cfg.n_vision_tokens)
    return jnp.broadcast_to(jnp.arange(seq), (batch, seq))


def _encode_audio(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub frame embeddings [B, Senc, D]."""
    x = frames + params["enc_pos"][None, :frames.shape[1]]
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                           (frames.shape[0], frames.shape[1]))

    enc_cfg = dataclasses.replace(cfg, rope_mode="none")

    @ctx.maybe_remat
    def step(h, lp):
        h, _ = _dense_layer_full(lp, h, enc_cfg, pos, causal=False)
        return ctx.constrain(h), None

    x, _ = ctx.scan(step, x, params["enc_layers"])
    return blocks.norm(cfg, params["enc_final_norm"], x)


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
            extras: dict | None = None) -> jax.Array:
    """Teacher-forced full-sequence logits (training / eval)."""
    extras = extras or {}
    x = _embed(params, cfg, tokens, extras)
    b, s = x.shape[0], x.shape[1]
    positions = _positions(cfg, b, s)
    f = cfg.family

    x = ctx.constrain(x)
    if f in ("dense", "vlm"):
        @ctx.maybe_remat
        def step(h, lp):
            h, _ = _dense_layer_full(lp, h, cfg, positions)
            return ctx.constrain(h), None
        x, _ = ctx.scan(step, x, params["layers"])
    elif f == "moe":
        @ctx.maybe_remat
        def step(h, lp):
            h, _ = _moe_layer_full(lp, h, cfg, positions)
            return ctx.constrain(h), None
        x, _ = ctx.scan(step, x, params["layers"])
    elif f == "mla_moe":
        @ctx.maybe_remat
        def dstep(h, lp):
            h, _ = _mla_layer_full(lp, h, cfg, positions, dense=True)
            return ctx.constrain(h), None
        x, _ = ctx.scan(dstep, x, params["dense_layers"])

        @ctx.maybe_remat
        def mstep(h, lp):
            h, _ = _mla_layer_full(lp, h, cfg, positions, dense=False)
            return ctx.constrain(h), None
        x, _ = ctx.scan(mstep, x, params["layers"])
    elif f == "audio":
        enc_out = _encode_audio(params, cfg, extras["frames"])

        @ctx.maybe_remat
        def step(h, lp):
            h, _ = _audio_dec_layer_full(lp, h, cfg, positions, enc_out)
            return ctx.constrain(h), None
        x, _ = ctx.scan(step, x, params["layers"])
    elif f == "hybrid":
        @ctx.maybe_remat
        def mamba_step(h, lp):
            out, _ = ssm_mod.mamba_block(lp, h, cfg)
            return ctx.constrain(h + out), None

        def group_step(h, gp):
            h, _ = ctx.scan(mamba_step, h, gp)
            h, _ = _dense_layer_full(params["shared"], h, cfg, positions)
            return ctx.constrain(h), None
        x, _ = ctx.scan(group_step, x, params["groups"])
        if params.get("tail") is not None:
            x, _ = ctx.scan(mamba_step, x, params["tail"])
    elif f == "ssm":
        @ctx.maybe_remat
        def step(h, lp):
            out, _ = ssm_mod.mamba_block(lp, h, cfg)
            return ctx.constrain(h + out), None
        x, _ = ctx.scan(step, x, params["layers"])
    else:
        raise ValueError(f)

    x = blocks.norm(cfg, params["final_norm"], x)
    return lm_head(params, cfg, x)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    f = cfg.family
    if f in ("dense", "vlm", "moe"):
        shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "len": jnp.zeros((), jnp.int32)}
    if f == "mla_moe":
        nl = cfg.n_layers
        return {
            "ckv": jnp.zeros((nl, batch, max_seq, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((nl, batch, max_seq, cfg.qk_rope_dim), dtype),
            "len": jnp.zeros((), jnp.int32)}
    if f == "audio":
        nl = cfg.n_layers
        kv = (nl, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
        xkv = (nl, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.d_head)
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype),
                "len": jnp.zeros((), jnp.int32)}
    if f == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        tail = cfg.n_layers - n_groups * every
        one = ssm_mod.init_mamba_cache(cfg, batch, dtype)

        def rep(tree, *dims):
            return jax.tree.map(
                lambda a: jnp.zeros(tuple(dims) + a.shape, a.dtype), tree)
        kv = (n_groups, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
        return {"mamba": rep(one, n_groups, every),
                "tail": rep(one, tail) if tail else None,
                "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                "len": jnp.zeros((), jnp.int32)}
    if f == "ssm":
        one = ssm_mod.init_mamba_cache(cfg, batch, dtype)
        return {"layers": jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one),
            "len": jnp.zeros((), jnp.int32)}
    raise ValueError(f)


def supports_paged(cfg: ModelConfig) -> bool:
    """Paged serving needs per-slot cache storage the block table can
    relocate: plain attention K/V (dense/vlm/moe), the MLA compressed
    ckv/krope pair (paged the same way, just thinner rows), or the hybrid
    family's shared-attention KV (its Mamba state lives in a slot-indexed
    state pool instead — recurrent state never pages).  Pure-SSM and
    encoder-decoder families keep the shared cursor."""
    return cfg.family in ("dense", "vlm", "moe", "mla_moe", "hybrid")


def has_slot_state(cfg: ModelConfig) -> bool:
    """True when the paged cache carries per-slot recurrent state (the
    hybrid family's Mamba conv window + SSM state) that the engine must
    checkpoint/restore across preempt-resume."""
    return cfg.family == "hybrid"


def _hybrid_layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, layers_per_group, tail_layers) of the zamba2-style stack."""
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    return n_groups, every, cfg.n_layers - n_groups * every


def init_paged_cache(cfg: ModelConfig, num_slots: int, max_seq: int,
                     dtype=jnp.bfloat16, page_size: int = 16,
                     num_pages: int | None = None,
                     kv_dtype: str = "bf16") -> dict:
    """Block-table KV cache: a shared page pool + per-slot state.

    Layout (family-dependent page pools, one shared block table):
      dense/vlm/moe:  k/v    [L, P, page, Hkv, Dh]
      mla_moe:        ckv    [L, P, page, R]      — pages carry COMPRESSED
                      krope  [L, P, page, Dr]       [page, R + Dr] rows; MLA
                                                    decode attends the
                                                    gathered compressed row
      hybrid:         k/v    [G, P, page, Hkv, Dh] — only the shared-attn
                                                    applications carry KV
                      mamba  {conv, state} pools with a leading [G, every,
                             slots] / [tail, slots] axis — the slot-indexed
                             SSM state pool; recurrent state never pages
      block  [slots, pages_per_slot] int32 page ids (0 where unallocated).
      lens   [slots] int32 per-slot valid lengths.

    ``kv_dtype="int8"`` stores each page pool as int8 with a companion f32
    scale pool under ``<pool>_scale`` (shape = pool shape minus the last
    axis: one symmetric scale per page row per head, or per compressed row
    for MLA).  Rows quantize at write and dequantize at the gathered
    block-row attend (``models.attention``); spill/snapshot machinery moves
    the (int8 payload, scales) pair as extra ``paged_pool_keys`` entries.

    Page 0 is the reserved *null page*: inactive slots park their writes
    there so freed pages can be handed to other requests immediately.

    By default P is sized so a full complement of max-length slots always
    fits; ``num_pages`` caps the *hot* pool below that (KV demand > NPU DRAM,
    the paper's regime applied to the cache), in which case the engine's
    tiered allocator spills cold pages to the flash tier and prefetches them
    back through the Slice Control bubbles.  The block-table indirection is
    what lets the engine admit/free mid-stream and relocate pages across
    tiers without touching decode math.
    """
    if not supports_paged(cfg):
        raise ValueError(f"paged cache unsupported for family {cfg.family!r}")
    if kv_dtype not in ("bf16", "int8"):
        raise ValueError(f"unknown kv_dtype: {kv_dtype!r}")
    pages_per_slot = -(-max_seq // page_size)
    if num_pages is None:
        num_pages = num_slots * pages_per_slot + 1
    base = {"block": jnp.zeros((num_slots, pages_per_slot), jnp.int32),
            "lens": jnp.zeros((num_slots,), jnp.int32)}

    def pools(**shapes) -> dict:
        if kv_dtype == "int8":
            out = {k: jnp.zeros(s, jnp.int8) for k, s in shapes.items()}
            out.update({k + "_scale": jnp.zeros(s[:-1], jnp.float32)
                        for k, s in shapes.items()})
            return out
        return {k: jnp.zeros(s, dtype) for k, s in shapes.items()}

    f = cfg.family
    if f == "mla_moe":
        nl = cfg.n_layers
        return {**pools(ckv=(nl, num_pages, page_size, cfg.kv_lora_rank),
                        krope=(nl, num_pages, page_size, cfg.qk_rope_dim)),
                **base}
    if f == "hybrid":
        n_groups, every, tail = _hybrid_layout(cfg)
        one = ssm_mod.init_mamba_cache(cfg, num_slots, dtype)

        def rep(tree, *dims):
            return jax.tree.map(
                lambda a: jnp.zeros(tuple(dims) + a.shape, a.dtype), tree)
        kv = (n_groups, num_pages, page_size, cfg.n_kv_heads, cfg.d_head)
        return {**pools(k=kv, v=kv),
                "mamba": rep(one, n_groups, every),
                "tail": rep(one, tail) if tail else None,
                **base}
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads, cfg.d_head)
    return {**pools(k=shape, v=shape), **base}


def paged_pool_dtype(cache: dict):
    """dtype of the page pools (the bytes that move on spill/prefetch) —
    int8 under kv_dtype="int8"."""
    return cache["ckv" if "ckv" in cache else "k"].dtype


def paged_kv_dtype(cache: dict) -> str:
    """The cache's kv_dtype string ("bf16" or "int8")."""
    return "int8" if paged_pool_dtype(cache) == jnp.int8 else "bf16"


def paged_slot_capacity(cache: dict) -> int:
    """Max tokens one slot can hold (pages_per_slot * page_size)."""
    pool = cache["ckv" if "ckv" in cache else "k"]
    return cache["block"].shape[1] * pool.shape[2]


def _pool(cache: dict, key: str):
    """The attend/write view of one page pool: the plain array, or the
    (int8 data, f32 scales) pair under kv_dtype="int8"."""
    sk = key + "_scale"
    return (cache[key], cache[sk]) if sk in cache else cache[key]


def _pool_update(cache: dict, key: str, pool) -> dict:
    """Cache-dict updates for a pool coming back out of a scan."""
    if isinstance(pool, tuple):
        return {key: pool[0], key + "_scale": pool[1]}
    return {key: pool}


def _pool_slice(pool, sl):
    """Slice a (possibly paired) pool along its leading layer axis."""
    if isinstance(pool, tuple):
        return tuple(p[sl] for p in pool)
    return pool[sl]


def _pool_concat(a, b):
    """Concatenate two (possibly paired) pool slices along the layer axis."""
    if isinstance(a, tuple):
        return tuple(jnp.concatenate([x, y], 0) for x, y in zip(a, b))
    return jnp.concatenate([a, b], 0)


def swap_out_pages(cache: dict, page_ids: jax.Array
                   ) -> tuple[jax.Array, ...]:
    """Gather page payloads (one array per ``blocks.paged_pool_keys`` entry,
    e.g. [L, n, page, Hkv, Dh] x2, plus f32 scale payloads when int8) for
    spill to the flash KV tier.  ``page_ids`` may be null-page padded to a
    shape bucket."""
    return blocks.kv_swap_out(cache, page_ids)


def swap_in_pages(cache: dict, page_ids: jax.Array, *payloads: jax.Array
                  ) -> dict:
    """Scatter prefetched page payloads back into the hot pool; the caller
    remaps the owning slot's block-table row to the new pids."""
    return blocks.kv_swap_in(cache, page_ids, *payloads)


def checkpoint_slot_state(cache: dict, slot: int):
    """Snapshot one slot's recurrent state (hybrid Mamba conv window + SSM
    state) as host arrays — the engine's preempt seam.  KV pages relocate
    through the flash tier; the state pool stays device-resident and masked,
    so this checkpoint is the belt-and-braces guarantee that a suspended
    slot resumes bit-identical no matter what ran in between.  Returns None
    for families without per-slot recurrent state."""
    if "mamba" not in cache:
        return None
    import numpy as np
    out = {"mamba": jax.tree.map(lambda a: np.asarray(a[:, :, slot]),
                                 cache["mamba"])}
    if cache.get("tail") is not None:
        out["tail"] = jax.tree.map(lambda a: np.asarray(a[:, slot]),
                                   cache["tail"])
    return out


def restore_slot_state(cache: dict, slot: int, ckpt) -> dict:
    """Write a ``checkpoint_slot_state`` snapshot back into the slot's rows
    of the state pool (resume path)."""
    if ckpt is None:
        return cache
    cache = {**cache, "mamba": jax.tree.map(
        lambda pool, row: pool.at[:, :, slot].set(
            jnp.asarray(row, pool.dtype)), cache["mamba"], ckpt["mamba"])}
    if ckpt.get("tail") is not None and cache.get("tail") is not None:
        cache = {**cache, "tail": jax.tree.map(
            lambda pool, row: pool.at[:, slot].set(
                jnp.asarray(row, pool.dtype)), cache["tail"], ckpt["tail"])}
    return cache


def kv_page_bytes(cfg: ModelConfig, page_size: int,
                  dtype=jnp.bfloat16) -> int:
    """Bytes one KV page moves across the NAND channels when spilled or
    prefetched — per-family: full K/V for GQA pools, the compressed
    ckv+krope rows for MLA, shared-attention groups only for hybrid
    (``serving.kv_cache.kv_page_elems`` is the single source of truth).
    int8 pages carry 1-byte elements plus their f32 per-row scales
    (``kv_page_scale_elems``) — a ~2x reduction vs bf16 for typical head
    dims, which is what reprices spill/TTFT in ``sim.llm_perf``."""
    from repro.serving.kv_cache import kv_page_elems, kv_page_scale_elems
    if jnp.dtype(dtype) == jnp.int8:
        return (kv_page_elems(cfg, page_size)
                + 4 * kv_page_scale_elems(cfg, page_size))
    return kv_page_elems(cfg, page_size) * jnp.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# prefill: full-sequence pass that also fills the cache
# ---------------------------------------------------------------------------


def _pad_seq(arr, max_seq, axis=2):
    pad = max_seq - arr.shape[axis]
    if pad <= 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths)


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict,
            extras: dict | None = None) -> tuple[jax.Array, dict]:
    """Process the prompt; returns (last-position logits [B, V], cache)."""
    extras = extras or {}
    x = _embed(params, cfg, tokens, extras)
    b, s = x.shape[0], x.shape[1]
    max_seq = _cache_max_seq(cfg, cache)
    positions = _positions(cfg, b, s)
    f = cfg.family

    if f in ("dense", "vlm", "moe"):
        layer_full = _moe_layer_full if f == "moe" else _dense_layer_full

        def step(h, xs):
            lp, _ = xs
            h, (k, v) = layer_full(lp, h, cfg, positions)
            return h, (_pad_seq(k.astype(cache["k"].dtype), max_seq, 1),
                       _pad_seq(v.astype(cache["v"].dtype), max_seq, 1))
        x, (ks, vs) = ctx.scan(step, x, (params["layers"], None))
        cache = {**cache, "k": ks, "v": vs,
                 "len": jnp.asarray(s, jnp.int32)}
    elif f == "mla_moe":
        all_ckv, all_krope = [], []

        def dstep(h, lp):
            h, (ckv, krope) = _mla_layer_full(lp, h, cfg, positions, True)
            return h, (ckv, krope)
        x, (ckv_d, krope_d) = ctx.scan(dstep, x, params["dense_layers"])

        def mstep(h, lp):
            h, (ckv, krope) = _mla_layer_full(lp, h, cfg, positions, False)
            return h, (ckv, krope)
        x, (ckv_m, krope_m) = ctx.scan(mstep, x, params["layers"])
        ckv = jnp.concatenate([ckv_d, ckv_m], 0)
        krope = jnp.concatenate([krope_d, krope_m], 0)
        cache = {**cache,
                 "ckv": _pad_seq(ckv.astype(cache["ckv"].dtype), max_seq),
                 "krope": _pad_seq(krope.astype(cache["krope"].dtype), max_seq),
                 "len": jnp.asarray(s, jnp.int32)}
    elif f == "audio":
        enc_out = _encode_audio(params, cfg, extras["frames"])

        def step(h, lp):
            h, (k, v, xk, xv) = _audio_dec_layer_full(lp, h, cfg, positions,
                                                      enc_out)
            return h, (k, v, xk, xv)
        x, (ks, vs, xks, xvs) = ctx.scan(step, x, params["layers"])
        cache = {**cache,
                 "k": _pad_seq(ks.astype(cache["k"].dtype), max_seq),
                 "v": _pad_seq(vs.astype(cache["v"].dtype), max_seq),
                 "xk": xks.astype(cache["xk"].dtype),
                 "xv": xvs.astype(cache["xv"].dtype),
                 "len": jnp.asarray(s, jnp.int32)}
    elif f == "hybrid":
        def mamba_step(h, xs):
            lp, _ = xs
            out, state = ssm_mod.mamba_block(lp, h, cfg)
            conv_tail = _conv_tail(h, lp, cfg)
            return h + out, {"conv": conv_tail, "state": state}

        def group_step(h, xs):
            gp, _ = xs
            h, mcache = ctx.scan(mamba_step, h, (gp, None))
            h, (k, v) = _dense_layer_full(params["shared"], h, cfg, positions)
            return h, (mcache, _pad_seq(k.astype(cache["k"].dtype), max_seq, 1),
                       _pad_seq(v.astype(cache["v"].dtype), max_seq, 1))
        x, (mcaches, ks, vs) = ctx.scan(group_step, x,
                                            (params["groups"], None))
        tail_cache = cache["tail"]
        if params.get("tail") is not None:
            x, tail_cache = ctx.scan(mamba_step, x, (params["tail"], None))
        cache = {"mamba": mcaches, "tail": tail_cache, "k": ks, "v": vs,
                 "len": jnp.asarray(s, jnp.int32)}
    elif f == "ssm":
        def step(h, xs):
            lp, _ = xs
            out, state = ssm_mod.mamba_block(lp, h, cfg)
            conv_tail = _conv_tail(h, lp, cfg)
            return h + out, {"conv": conv_tail, "state": state}
        x, lcache = ctx.scan(step, x, (params["layers"], None))
        cache = {"layers": lcache, "len": jnp.asarray(s, jnp.int32)}
    else:
        raise ValueError(f)

    x_last = blocks.norm(cfg, params["final_norm"], x[:, -1])
    return lm_head(params, cfg, x_last), cache


def prefill_into_slots(params: dict, cfg: ModelConfig, tokens: jax.Array,
                       true_lens: jax.Array, cache: dict, slot_ids: jax.Array,
                       extras: dict | None = None) -> tuple[jax.Array, dict]:
    """Prefill M requests into M slots of a paged cache, in one pass.

    tokens: [M, Sp] right-padded to a common bucket length; true_lens: [M]
    int32 valid cache lengths (prompt + any prepended vision tokens);
    slot_ids: [M] int32.  Other slots keep decoding against the same pool —
    only the named slots' pages (already present in their block-table rows)
    are written.  Returns (per-request last-valid-position logits [M, V],
    cache).

    Right-padding keeps every row's positions 0-based, so outputs are
    identical to prefilling each request alone: causality keeps tail pads out
    of every valid position's attention, pad K/V land in the row's own pages
    (or the null page past its allocation) masked by ``lens`` and overwritten
    as decode advances.
    """
    extras = extras or {}
    x = _embed(params, cfg, tokens, extras)
    m, s = x.shape[0], x.shape[1]
    positions = _positions(cfg, m, s)
    if not supports_paged(cfg):
        raise ValueError(f"paged prefill unsupported for family {cfg.family!r}")
    true_lens = jnp.asarray(true_lens, jnp.int32)
    page = paged_slot_capacity(cache) // cache["block"].shape[1]
    n_pages = -(-s // page)
    pids = cache["block"][slot_ids][:, :n_pages]                  # [M, n_pages]

    def to_pages(arr, pool):
        # arr: [L, M, S, *row] -> page-shaped [L, M, n_pages, page, *row]
        pad = n_pages * page - s
        if pad:
            widths = [(0, 0)] * arr.ndim
            widths[2] = (0, pad)
            arr = jnp.pad(arr, widths)
        return arr.reshape(arr.shape[0], m, n_pages, page,
                           *arr.shape[3:]).astype(pool.dtype)

    def set_pool(key, arr):
        # write one pool's prefill rows; int8 pools quantize per row HERE
        # (the write) so the page bits depend only on the token span
        sk = key + "_scale"
        if sk in cache:
            q, sc = attention.quantize_rows(arr)
            return {key: cache[key].at[:, pids].set(to_pages(q, cache[key])),
                    sk: cache[sk].at[:, pids].set(to_pages(sc, cache[sk]))}
        return {key: cache[key].at[:, pids].set(to_pages(arr, cache[key]))}

    f = cfg.family
    if f in ("dense", "vlm", "moe"):
        layer_full = _moe_layer_full if f == "moe" else _dense_layer_full

        def step(h, xs):
            lp, _ = xs
            h, (k, v) = layer_full(lp, h, cfg, positions)
            return h, (k, v)

        x, (ks, vs) = ctx.scan(step, x, (params["layers"], None))
        cache = {**cache, **set_pool("k", ks), **set_pool("v", vs)}
    elif f == "mla_moe":
        # page the COMPRESSED cache: ckv [L, M, S, R] + krope [L, M, S, Dr]
        def dstep(h, lp):
            h, kv = _mla_layer_full(lp, h, cfg, positions, True)
            return h, kv

        def mstep(h, lp):
            h, kv = _mla_layer_full(lp, h, cfg, positions, False)
            return h, kv

        x, (ckv_d, kr_d) = ctx.scan(dstep, x, params["dense_layers"])
        x, (ckv_m, kr_m) = ctx.scan(mstep, x, params["layers"])
        ckv = jnp.concatenate([ckv_d, ckv_m], 0)
        krope = jnp.concatenate([kr_d, kr_m], 0)
        cache = {**cache, **set_pool("ckv", ckv), **set_pool("krope", krope)}
    elif f == "hybrid":
        # right-padded rows: the SSM recurrence (unlike causal attention)
        # would fold trailing pads into the state, so pad positions get
        # dt=0 (identity state update) and the decode conv window is
        # gathered at each row's OWN length, not the batch bucket's tail
        valid = jnp.arange(s)[None, :] < true_lens[:, None]

        def mamba_step(h, xs):
            lp, _ = xs
            out, state = ssm_mod.mamba_block(lp, h, cfg, valid=valid)
            conv = ssm_mod.conv_tail_at(lp, h, cfg, true_lens)
            return h + out, {"conv": conv, "state": state}

        def group_step(h, xs):
            gp, _ = xs
            h, mcache = ctx.scan(mamba_step, h, (gp, None))
            h, (k, v) = _dense_layer_full(params["shared"], h, cfg, positions)
            return h, (mcache, k, v)

        x, (mcaches, ks, vs) = ctx.scan(group_step, x,
                                        (params["groups"], None))
        tail_cache = cache["tail"]
        if params.get("tail") is not None:
            x, new_tail = ctx.scan(mamba_step, x, (params["tail"], None))
            # [tail, M, ...] rows scatter into the [tail, slots, ...] pool
            tail_cache = jax.tree.map(
                lambda pool, row: pool.at[:, slot_ids].set(
                    row.astype(pool.dtype)), cache["tail"], new_tail)
        # mcaches: [G, every, M, ...] -> slot rows of the [G, every, slots,
        # ...] state pool (duplicate slot_ids from group padding write
        # identical values, so the scatter stays deterministic)
        mamba_pool = jax.tree.map(
            lambda pool, row: pool.at[:, :, slot_ids].set(
                row.astype(pool.dtype)), cache["mamba"], mcaches)
        cache = {**cache, "mamba": mamba_pool, "tail": tail_cache,
                 **set_pool("k", ks), **set_pool("v", vs)}
    else:
        raise ValueError(f)
    cache = {**cache, "lens": cache["lens"].at[slot_ids].set(true_lens)}
    x_last = jnp.take_along_axis(
        x, (true_lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    x_last = blocks.norm(cfg, params["final_norm"], x_last)
    return lm_head(params, cfg, x_last), cache


def prefill_into_slot(params: dict, cfg: ModelConfig, tokens: jax.Array,
                      true_len: jax.Array, cache: dict, slot: jax.Array,
                      extras: dict | None = None) -> tuple[jax.Array, dict]:
    """Single-request convenience wrapper over ``prefill_into_slots``.

    tokens: [1, Sp]; returns (logits [V], cache)."""
    logits, cache = prefill_into_slots(
        params, cfg, tokens, jnp.asarray(true_len, jnp.int32).reshape(1),
        cache, jnp.asarray(slot, jnp.int32).reshape(1), extras)
    return logits[0], cache


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill needs a paged cache AND a 1-D token/position stream
    (no prepended vision/audio embeddings to split across chunk calls)."""
    return supports_paged(cfg) and cfg.family in ("dense", "moe")


def prefill_chunk_into_slot(params: dict, cfg: ModelConfig,
                            tokens: jax.Array, start: jax.Array,
                            chunk_len: jax.Array, cache: dict,
                            slot: jax.Array) -> tuple[jax.Array, dict]:
    """Chunked-prefill continuation: process ``chunk_len`` prompt tokens of
    one slot, starting at cache position ``start``.

    tokens: [C] int32 — the chunk, right-padded to any shape bucket C
    (the engine uses power-of-two buckets with floor = page size, so traces
    stay O(log max_seq) and per-chunk compute scales with the budget);
    start / chunk_len / slot: [] int32, all traced.

    Bit-identity contract: each chunk position's K/V is scattered into the
    slot's pages FIRST, then attention for the chunk queries runs against
    the gathered block row (key position <= query position) — exactly the
    buffer decode reads.  Every position's math is therefore independent of
    how the prompt was split, so the final cache bits, the returned
    last-position logits, and every subsequent decode logit are identical
    for ANY chunk schedule, including the single-chunk (one-shot) case.
    Pinned by tests/test_chunked_prefill.py.

    Returns (logits [V] at chunk position ``chunk_len - 1``, cache).
    """
    if not supports_chunked_prefill(cfg):
        raise ValueError(
            f"chunked prefill unsupported for family {cfg.family!r}")
    c = tokens.shape[0]
    x = params["embed"][tokens][None]                      # [1, C, D]
    gpos = jnp.asarray(start, jnp.int32) + jnp.arange(c, dtype=jnp.int32)
    if cfg.rope_mode == "learned":
        tbl = params["pos_embed"]
        x = x + tbl[jnp.clip(gpos, 0, tbl.shape[0] - 1)][None]
    positions = gpos[None]                                 # [1, C]
    valid = jnp.arange(c) < chunk_len
    block_row = cache["block"][slot]
    f = cfg.family

    def step(h, xs):
        lp, kp, vp = xs
        hn = blocks.norm(cfg, lp["attn_norm"], h)
        attn_out, kp, vp = blocks.attn_prefill_chunk_paged(
            lp["attn"], hn, cfg, kp, vp, block_row, positions, valid)
        if cfg.parallel_block:
            h = h + attn_out + ffn(lp["ffn"], hn, cfg.gated_ffn)
        else:
            h = h + attn_out
            hn2 = blocks.norm(cfg, lp["ffn_norm"], h)
            if f == "moe":
                h = h + moe_mod.moe_ffn(lp["moe"], hn2, cfg)
            else:
                h = h + ffn(lp["ffn"], hn2, cfg.gated_ffn)
        return h, (kp, vp)

    x, (ks, vs) = ctx.scan(step, x,
                           (params["layers"], _pool(cache, "k"),
                            _pool(cache, "v")))
    cache = {**cache, **_pool_update(cache, "k", ks),
             **_pool_update(cache, "v", vs),
             "lens": cache["lens"].at[slot].set(
                 jnp.asarray(start + chunk_len, jnp.int32))}
    idx = jnp.clip(chunk_len - 1, 0, c - 1).reshape(1, 1, 1)
    x_last = jnp.take_along_axis(x, idx.astype(jnp.int32), axis=1)[:, 0]
    x_last = blocks.norm(cfg, params["final_norm"], x_last)
    return lm_head(params, cfg, x_last)[0], cache


def decode_step_paged(params: dict, cfg: ModelConfig, token: jax.Array,
                      cache: dict, active: jax.Array
                      ) -> tuple[jax.Array, dict]:
    """One decode step over mixed-progress slots of a paged cache.

    token: int32 [B]; active: bool [B].  Each slot attends its own valid
    prefix (``cache["lens"]``) through its block-table row; inactive slots
    write to the null page and keep length 0, so their lanes are pure
    padding.  Returns (logits [B, V], cache) — logits of inactive slots are
    garbage and must be ignored by the caller.
    """
    if not supports_paged(cfg):
        raise ValueError(f"paged decode unsupported for family {cfg.family!r}")
    x = params["embed"][token]
    lens = cache["lens"]
    # a slot at capacity must not decode: its block-table gather would clamp
    # and silently overwrite its own last page — deactivate the lane instead
    # (lens freezes, logits are garbage like any inactive lane's)
    active = jnp.asarray(active, bool) & (lens < paged_slot_capacity(cache))
    if cfg.rope_mode == "learned":
        x = x + params["pos_embed"][lens]
    f = cfg.family

    if f in ("dense", "vlm", "moe"):
        def step(h, xs):
            lp, kp, vp = xs
            hn = blocks.norm(cfg, lp["attn_norm"], h)
            attn_out, kp, vp = blocks.attn_decode_paged(
                lp["attn"], hn, cfg, kp, vp, cache["block"], lens, active)
            if cfg.parallel_block:
                fo = ffn(lp["ffn"], hn, cfg.gated_ffn)
                h = h + attn_out + fo
            else:
                h = h + attn_out
                hn2 = blocks.norm(cfg, lp["ffn_norm"], h)
                if f == "moe":
                    h = h + moe_mod.moe_ffn(lp["moe"], hn2[:, None], cfg)[:, 0]
                else:
                    h = h + ffn(lp["ffn"], hn2, cfg.gated_ffn)
            return h, (kp, vp)

        x, (ks, vs) = ctx.scan(step, x,
                               (params["layers"], _pool(cache, "k"),
                                _pool(cache, "v")))
        cache = {**cache, **_pool_update(cache, "k", ks),
                 **_pool_update(cache, "v", vs)}
    elif f == "mla_moe":
        def make_step(dense):
            def step(h, xs):
                lp, ckv_p, kr_p = xs
                hn = blocks.norm(cfg, lp["attn_norm"], h)
                attn_out, ckv_p, kr_p = blocks.mla_decode_paged(
                    lp["attn"], hn, cfg, ckv_p, kr_p, cache["block"], lens,
                    active)
                h = h + attn_out
                hn2 = blocks.norm(cfg, lp["ffn_norm"], h)
                if dense:
                    h = h + ffn(lp["ffn"], hn2, cfg.gated_ffn)
                else:
                    h = h + moe_mod.moe_ffn(lp["moe"], hn2[:, None], cfg)[:, 0]
                return h, (ckv_p, kr_p)
            return step
        kd = cfg.first_k_dense
        ckv_pool, kr_pool = _pool(cache, "ckv"), _pool(cache, "krope")
        x, (ckv_d, kr_d) = ctx.scan(
            make_step(True), x,
            (params["dense_layers"], _pool_slice(ckv_pool, slice(None, kd)),
             _pool_slice(kr_pool, slice(None, kd))))
        x, (ckv_m, kr_m) = ctx.scan(
            make_step(False), x,
            (params["layers"], _pool_slice(ckv_pool, slice(kd, None)),
             _pool_slice(kr_pool, slice(kd, None))))
        cache = {**cache,
                 **_pool_update(cache, "ckv", _pool_concat(ckv_d, ckv_m)),
                 **_pool_update(cache, "krope", _pool_concat(kr_d, kr_m))}
    elif f == "hybrid":
        # Mamba state updates are masked by ``active`` (a suspended slot's
        # conv window and SSM state stay bit-identical until resume) and the
        # shared-attention KV goes through the same block-table indirection
        # as every other family
        def mamba_step(h, xs):
            lp, mc = xs
            out, mc = ssm_mod.mamba_decode_step(lp, h, mc, cfg,
                                                active=active)
            return h + out, mc

        def group_step(h, xs):
            gp, mc, kp, vp = xs
            h, mc = ctx.scan(mamba_step, h, (gp, mc))
            hn = blocks.norm(cfg, params["shared"]["attn_norm"], h)
            attn_out, kp, vp = blocks.attn_decode_paged(
                params["shared"]["attn"], hn, cfg, kp, vp, cache["block"],
                lens, active)
            h = h + attn_out
            h = h + ffn(params["shared"]["ffn"],
                        blocks.norm(cfg, params["shared"]["ffn_norm"], h),
                        cfg.gated_ffn)
            return h, (mc, kp, vp)

        x, (mcaches, ks, vs) = ctx.scan(
            group_step, x,
            (params["groups"], cache["mamba"], _pool(cache, "k"),
             _pool(cache, "v")))
        tail_cache = cache["tail"]
        if params.get("tail") is not None:
            x, tail_cache = ctx.scan(mamba_step, x,
                                     (params["tail"], cache["tail"]))
        cache = {**cache, "mamba": mcaches, "tail": tail_cache,
                 **_pool_update(cache, "k", ks),
                 **_pool_update(cache, "v", vs)}
    else:
        raise ValueError(f)
    cache = {**cache, "lens": lens + active.astype(jnp.int32)}
    x = blocks.norm(cfg, params["final_norm"], x)
    return lm_head(params, cfg, x), cache


def decode_and_sample_paged(params: dict, cfg: ModelConfig,
                            tok_host: jax.Array, tok_dev: jax.Array,
                            use_dev: jax.Array, cache: dict,
                            active: jax.Array, sample_fn
                            ) -> tuple[jax.Array, dict]:
    """Fused decode + sample: the overlapped serving loop's ONE dispatch.

    The input token is merged on-device — ``where(use_dev, tok_dev,
    tok_host)`` — so a slot whose previous token was sampled by the
    previous fused step (``tok_dev``, still unread by the host) chains
    straight into this step with no host round-trip, while freshly
    prefilled / injected slots feed their host-known first token through
    ``tok_host``.  ``sample_fn(logits) -> tokens`` keeps this module
    sampler-agnostic; the engine closes it over the per-request sampling
    parameter rows.  Returns (tokens [B], cache); tokens of inactive rows
    are garbage exactly like ``decode_step_paged``'s logits.
    """
    token = jnp.where(jnp.asarray(use_dev, bool), tok_dev, tok_host)
    logits, cache = decode_step_paged(params, cfg, token, cache, active)
    return sample_fn(logits), cache


def decode_and_sample(params: dict, cfg: ModelConfig, tok_host: jax.Array,
                      tok_dev: jax.Array, use_dev: jax.Array, cache: dict,
                      sample_fn) -> tuple[jax.Array, dict]:
    """`decode_and_sample_paged` for the legacy shared-cursor (wave) cache."""
    token = jnp.where(jnp.asarray(use_dev, bool), tok_dev, tok_host)
    logits, cache = decode_step(params, cfg, token, cache)
    return sample_fn(logits), cache


def _conv_tail(h, lp, cfg: ModelConfig):
    """Last K-1 conv inputs of the sequence (pre-activation), for decode."""
    z_xbc_dt = linear(lp["in_proj"], h[:, -(cfg.ssm_conv - 1):, :])
    d_in = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    xbc = z_xbc_dt[..., d_in:d_in + d_in + 2 * g * n]
    return xbc




def _cache_max_seq(cfg: ModelConfig, cache: dict) -> int:
    if cfg.family in ("dense", "vlm", "moe", "audio", "hybrid"):
        return cache["k"].shape[-3]
    if cfg.family == "mla_moe":
        return cache["ckv"].shape[-2]
    return 0


# ---------------------------------------------------------------------------
# decode: one token through the whole stack
# ---------------------------------------------------------------------------


def decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                cache: dict) -> tuple[jax.Array, dict]:
    """token: int32 [B]. Returns (logits [B, V], updated cache)."""
    x = params["embed"][token]
    pos = cache["len"]
    if cfg.rope_mode == "learned":
        x = x + params["pos_embed"][pos]
    f = cfg.family

    if f in ("dense", "vlm", "moe"):
        mesh = ctx.mesh()
        use_splitk = (
            mesh is not None and "model" in mesh.shape
            and cfg.n_kv_heads % mesh.shape["model"] != 0)
        if use_splitk:
            from repro.distributed.sharding import batch_pspec
            batch_axes = batch_pspec(mesh, x.shape[0], 1)[0]

        def step(h, xs):
            lp, kc, vc = xs
            hn = blocks.norm(cfg, lp["attn_norm"], h)
            if use_splitk:
                attn_out, kc, vc = blocks.attn_decode_sharded(
                    lp["attn"], hn, cfg, kc, vc, pos, mesh, batch_axes)
            else:
                attn_out, kc, vc = blocks.attn_decode(lp["attn"], hn, cfg,
                                                      kc, vc, pos)
            if cfg.parallel_block:
                fo = ffn(lp["ffn"], hn, cfg.gated_ffn)
                h = h + attn_out + fo
            else:
                h = h + attn_out
                hn2 = blocks.norm(cfg, lp["ffn_norm"], h)
                if f == "moe":
                    h = h + moe_mod.moe_ffn(lp["moe"], hn2[:, None], cfg)[:, 0]
                else:
                    h = h + ffn(lp["ffn"], hn2, cfg.gated_ffn)
            return h, (kc, vc)
        x, (ks, vs) = ctx.scan(step, x,
                                   (params["layers"], cache["k"], cache["v"]))
        cache = {**cache, "k": ks, "v": vs, "len": pos + 1}
    elif f == "mla_moe":
        def make_step(dense):
            def step(h, xs):
                lp, ckv, krope = xs
                hn = blocks.norm(cfg, lp["attn_norm"], h)
                attn_out, ckv, krope = blocks.mla_decode(lp["attn"], hn, cfg,
                                                         ckv, krope, pos)
                h = h + attn_out
                hn2 = blocks.norm(cfg, lp["ffn_norm"], h)
                if dense:
                    h = h + ffn(lp["ffn"], hn2, cfg.gated_ffn)
                else:
                    h = h + moe_mod.moe_ffn(lp["moe"], hn2[:, None], cfg)[:, 0]
                return h, (ckv, krope)
            return step
        kd = cfg.first_k_dense
        x, (ckv_d, kr_d) = ctx.scan(
            make_step(True), x,
            (params["dense_layers"], cache["ckv"][:kd], cache["krope"][:kd]))
        x, (ckv_m, kr_m) = ctx.scan(
            make_step(False), x,
            (params["layers"], cache["ckv"][kd:], cache["krope"][kd:]))
        cache = {**cache,
                 "ckv": jnp.concatenate([ckv_d, ckv_m], 0),
                 "krope": jnp.concatenate([kr_d, kr_m], 0),
                 "len": pos + 1}
    elif f == "audio":
        def step(h, xs):
            lp, kc, vc, xk, xv = xs
            hn = blocks.norm(cfg, lp["attn_norm"], h)
            attn_out, kc, vc = blocks.attn_decode(lp["attn"], hn, cfg, kc, vc,
                                                  pos)
            h = h + attn_out
            hn = blocks.norm(cfg, lp["xattn_norm"], h)
            h = h + blocks.cross_attn_decode(lp["xattn"], hn, cfg, xk, xv,
                                             cfg.encoder_seq)
            h = h + ffn(lp["ffn"], blocks.norm(cfg, lp["ffn_norm"], h),
                        cfg.gated_ffn)
            return h, (kc, vc)
        x, (ks, vs) = ctx.scan(
            step, x, (params["layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        cache = {**cache, "k": ks, "v": vs, "len": pos + 1}
    elif f == "hybrid":
        def mamba_step(h, xs):
            lp, mc = xs
            out, mc = ssm_mod.mamba_decode_step(lp, h, mc, cfg)
            return h + out, mc

        def group_step(h, xs):
            gp, mc, kc, vc = xs
            h, mc = ctx.scan(mamba_step, h, (gp, mc))
            hn = blocks.norm(cfg, params["shared"]["attn_norm"], h)
            attn_out, kc, vc = blocks.attn_decode(params["shared"]["attn"],
                                                  hn, cfg, kc, vc, pos)
            h = h + attn_out
            h = h + ffn(params["shared"]["ffn"],
                        blocks.norm(cfg, params["shared"]["ffn_norm"], h),
                        cfg.gated_ffn)
            return h, (mc, kc, vc)
        x, (mcaches, ks, vs) = ctx.scan(
            group_step, x,
            (params["groups"], cache["mamba"], cache["k"], cache["v"]))
        tail_cache = cache["tail"]
        if params.get("tail") is not None:
            x, tail_cache = ctx.scan(mamba_step, x,
                                         (params["tail"], cache["tail"]))
        cache = {"mamba": mcaches, "tail": tail_cache, "k": ks, "v": vs,
                 "len": pos + 1}
    elif f == "ssm":
        def step(h, xs):
            lp, mc = xs
            out, mc = ssm_mod.mamba_decode_step(lp, h, mc, cfg)
            return h + out, mc
        x, lcache = ctx.scan(step, x, (params["layers"], cache["layers"]))
        cache = {"layers": lcache, "len": pos + 1}
    else:
        raise ValueError(f)

    x = blocks.norm(cfg, params["final_norm"], x)
    return lm_head(params, cfg, x), cache
