"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD for train/prefill (quadratic within chunks, linear across) and a
constant-memory recurrent step for decode — this is what makes the
``long_500k`` cells runnable for the ssm/hybrid architectures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_linear, linear, rms_norm

NEG_INF = -1e30


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., T] -> lower-triangular pairwise sums a[i..j) as [..., T, T]."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, diff, NEG_INF)


def ssd_chunked(x: jax.Array, a: jax.Array, b_mat: jax.Array, c_mat: jax.Array,
                chunk: int = 128,
                init_state: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """SSD scan. x: [B,S,H,P]; a: [B,S,H] (log decay = dt*A, negative);
    b_mat/c_mat: [B,S,G,N]. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk
    rep = h // g
    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = a.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)  # [B,H,C,Q]
    bc = jnp.repeat(b_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(c_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    a_cumsum = jnp.cumsum(ac, axis=-1)  # [B,H,C,Q]
    # 1) intra-chunk (the "duality" quadratic part)
    ell = jnp.exp(_segsum(ac))  # [B,H,C,Q,Q]
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        cc.astype(jnp.float32), bc.astype(jnp.float32),
                        ell.astype(jnp.float32), xc.astype(jnp.float32))
    # 2) chunk summaries
    decay_states = jnp.exp(a_cumsum[..., -1:] - a_cumsum)  # [B,H,C,Q]
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn",
                        bc.astype(jnp.float32), decay_states.astype(jnp.float32),
                        xc.astype(jnp.float32))
    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(a_cumsum[..., -1])  # [B,H,C]
    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp  # st: [B,H,P,N], dec: [B,H]
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    (final_state, prev_states) = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,C,H,P,N]
    # 4) inter-chunk contribution
    state_decay = jnp.exp(a_cumsum)  # [B,H,C,Q]
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       cc.astype(jnp.float32), prev_states,
                       state_decay.astype(jnp.float32))
    y = (y_diag + y_off).reshape(bsz, nc * chunk, h, p)
    return y[:, :s].astype(x.dtype), final_state


def ssd_decode_step(state: jax.Array, x: jax.Array, a: jax.Array,
                    b_mat: jax.Array, c_mat: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """One recurrent step. state: [B,H,P,N]; x: [B,H,P]; a: [B,H];
    b_mat/c_mat: [B,G,N]. Returns (y [B,H,P], new_state)."""
    h, g = x.shape[1], b_mat.shape[1]
    rep = h // g
    bb = jnp.repeat(b_mat, rep, axis=1).astype(jnp.float32)  # [B,H,N]
    cc = jnp.repeat(c_mat, rep, axis=1).astype(jnp.float32)
    new_state = (state * jnp.exp(a.astype(jnp.float32))[..., None, None]
                 + jnp.einsum("bhp,bhn->bhpn", x.astype(jnp.float32), bb))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, cc)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba-2 block
# ---------------------------------------------------------------------------


def init_mamba_block(key: jax.Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d_in = cfg.d_inner
    g, n, hh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_ch = d_in + 2 * g * n
    proj_out = 2 * d_in + 2 * g * n + hh
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(ks[0], cfg.d_model, proj_out, False, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((hh,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, hh, dtype=jnp.float32)),
        "d_skip": jnp.ones((hh,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": init_linear(ks[2], d_in, cfg.d_model, False, dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_in = cfg.d_inner
    g, n, hh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + d_in + 2 * g * n], axis=-1)
    return z, xbc, dt  # xbc still fused for the conv


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, C] with kernel [K, C]."""
    k = w.shape[0]
    xp = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)
                       ).astype(xbc.dtype)


def mamba_block(params: dict, x: jax.Array, cfg: ModelConfig,
                chunk: int = 128,
                init_state: jax.Array | None = None,
                valid: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Full-sequence mamba2 block. x: [B, S, D] -> ([B, S, D], final_state).

    ``valid`` ([B, S] bool) zeroes the dt of pad positions, which makes their
    state update the identity (decay exp(0)=1, zero input) — a RIGHT-padded
    batch row therefore ends the scan with exactly the state of its valid
    prefix.  Outputs at invalid positions are garbage (callers mask them);
    valid positions are untouched because the recurrence only flows forward.
    """
    d_in = cfg.d_inner
    g, n, hh, p = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    bsz, s, _ = x.shape
    z, xbc, dt = _split_proj(cfg, linear(params["in_proj"], x))
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, b_mat, c_mat = jnp.split(xbc, [d_in, d_in + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    if valid is not None:
        dt = jnp.where(valid[..., None], dt, 0.0)
    a = -jnp.exp(params["a_log"])[None, None, :] * dt  # log decay, negative
    xh = xs.reshape(bsz, s, hh, p)
    xin = xh * dt[..., None].astype(xh.dtype)
    y, state = ssd_chunked(xin, a, b_mat.reshape(bsz, s, g, n),
                           c_mat.reshape(bsz, s, g, n), chunk,
                           init_state=init_state)
    y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(bsz, s, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    return linear(params["out_proj"], y), state


def mamba_decode_step(params: dict, x: jax.Array, cache: dict,
                      cfg: ModelConfig,
                      active: jax.Array | None = None
                      ) -> tuple[jax.Array, dict]:
    """One-token step. x: [B, D]; cache: {"conv": [B,K-1,C], "state": [B,H,P,N]}.

    ``active`` ([B] bool) freezes inactive lanes' recurrent state: their conv
    window and SSM state come back bit-identical (a suspended serving slot
    must be able to resume exactly where it stopped; the analogue of the
    paged-attention null-page redirect).  Their y output is garbage, like any
    inactive lane's — callers ignore it.
    """
    d_in = cfg.d_inner
    g, n, hh, p = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    bsz = x.shape[0]
    z, xbc, dt = _split_proj(cfg, linear(params["in_proj"], x))
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)
                      ).astype(x.dtype)
    new_conv = window[:, 1:, :]
    xs, b_mat, c_mat = jnp.split(xbc, [d_in, d_in + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    a = -jnp.exp(params["a_log"])[None, :] * dt
    xh = xs.reshape(bsz, hh, p) * dt[..., None].astype(x.dtype)
    y, new_state = ssd_decode_step(cache["state"], xh, a,
                                   b_mat.reshape(bsz, g, n),
                                   c_mat.reshape(bsz, g, n))
    y = y + xs.reshape(bsz, hh, p) * params["d_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(bsz, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    if active is not None:
        am = jnp.asarray(active, bool)
        new_conv = jnp.where(am[:, None, None], new_conv, cache["conv"])
        new_state = jnp.where(am[:, None, None, None], new_state,
                              cache["state"])
    return linear(params["out_proj"], y), {"conv": new_conv, "state": new_state}


def conv_tail_at(params: dict, h: jax.Array, cfg: ModelConfig,
                 true_lens: jax.Array) -> jax.Array:
    """Per-row decode conv window from a right-padded prefill pass.

    h: [B, S, D] layer input; true_lens: [B] valid lengths.  Returns
    [B, K-1, C] — the PRE-activation conv inputs at the last K-1 *valid*
    positions of each row, zeroed where the row is shorter than the window
    (matching the zero-initialised conv cache).  The fixed tail slice the
    shared-cursor prefill takes would read pad junk for any row shorter
    than the batch bucket, so the paged path gathers at each row's own
    length — and it gathers the [B, K-1, D] input window FIRST, so in_proj
    runs over K-1 positions here instead of a second full-sequence pass.
    """
    k = cfg.ssm_conv - 1
    d_in = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    hp = jnp.pad(h, ((0, 0), (k, 0), (0, 0)))
    # padded index j holds original position j - k; we want originals
    # [true_len - k, true_len), i.e. padded [true_len, true_len + k)
    idx = true_lens[:, None].astype(jnp.int32) + jnp.arange(k, dtype=jnp.int32)
    hw = jnp.take_along_axis(hp, idx[..., None], axis=1)       # [B, K-1, D]
    xbc = linear(params["in_proj"], hw)[..., d_in:d_in + d_in + 2 * g * n]
    # positions before the row's start mirror the zero-init conv cache
    return jnp.where((idx >= k)[..., None], xbc, jnp.zeros_like(xbc))


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    d_in = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    conv_ch = d_in + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim, n),
                           jnp.float32),
    }
