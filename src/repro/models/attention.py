"""Attention: chunked (flash-style) full attention + single-token decode.

Pure-jnp implementations used by every model and by the dry-run lowering;
the Pallas kernels in kernels/flash_attention and kernels/decode_attention
are the TPU hot-path versions validated against these in tests.

Memory discipline: scores materialize only per (q_chunk, kv_chunk) block via
a double ``lax.scan`` with online softmax, so prefill_32k fits. Causality is
mask-based inside blocks (upper-triangle blocks are computed-then-masked;
see EXPERIMENTS.md §Perf for the accounting).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """[B, S, Hkv, D] -> [B, S, Hkv*n_rep, D] (GQA head sharing)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, q_chunk: int = 1024,
                      kv_chunk: int = 1024,
                      positions_q: jax.Array | None = None,
                      positions_kv: jax.Array | None = None) -> jax.Array:
    """Online-softmax attention over chunks.

    q: [B, Sq, H, D]; k/v: [B, Skv, Hkv, D]. Returns [B, Sq, H, D].
    """
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = -(-sq // q_chunk)
    nkv = -(-skv // kv_chunk)
    pad_q = nq * q_chunk - sq
    pad_kv = nkv * kv_chunk - skv
    if positions_q is None:
        positions_q = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    if positions_kv is None:
        positions_kv = jnp.broadcast_to(jnp.arange(skv), (b, skv))
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    pq = jnp.pad(positions_q, ((0, 0), (0, pad_q)), constant_values=-1)
    pkv = jnp.pad(positions_kv, ((0, 0), (0, pad_kv)), constant_values=2**30)

    qs = qp.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 3, 2, 4)  # [nq,B,H,Cq,D]
    ks = kp.reshape(b, nkv, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vs = vp.reshape(b, nkv, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    pqs = pq.reshape(b, nq, q_chunk).transpose(1, 0, 2)   # [nq, B, Cq]
    pkvs = pkv.reshape(b, nkv, kv_chunk).transpose(1, 0, 2)

    sm_scale = d ** -0.5

    def q_step(_, qc):
        q_blk, pq_blk = qc  # [B,H,Cq,D], [B,Cq]

        # checkpoint: recompute s/p during backward instead of storing the
        # [B,H,Cq,Ckv] probabilities for every (q,kv) chunk pair (which is
        # what turns a 32k-token prefill into tens of GB of residuals)
        @jax.checkpoint
        def kv_step(carry, kc):
            m, l, acc = carry
            k_blk, v_blk, pk_blk = kc
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * sm_scale
            if causal:
                mask = pq_blk[:, None, :, None] >= pk_blk[:, None, None, :]
            else:
                mask = (pk_blk < 2**30)[:, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, pkvs))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qs, pqs))  # [nq, B, H, Cq, D]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array) -> jax.Array:
    """One-token attention against a cache.

    q: [B, H, D]; k_cache/v_cache: [B, Smax, Hkv, D]; length: [] or [B]
    (valid prefix length per slot, the new token's kv already written).
    A slot with length 0 produces a garbage-but-finite row (uniform softmax
    over masked scores) — callers ignore inactive slots' outputs.
    """
    b, smax, hkv, d = k_cache.shape
    h = q.shape[1]
    k = _repeat_kv(k_cache, h // hkv)
    v = _repeat_kv(v_cache, h // hkv)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    pos = jnp.arange(smax)
    valid = pos[None, :] < jnp.asarray(length).reshape(-1, 1)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# paged KV cache (block-table indirection, per-slot lengths)
#
# A pool is either a plain array [P, page, *row] or — for kv_dtype="int8" —
# a (data int8 [P, page, *row], scale f32 [P, page, *row[:-1]]) pair with
# one symmetric per-row scale over the last axis (per-page-per-head for GQA
# pools, per-page-row for MLA's compressed rows).  Rows quantize ONCE at
# write (prefill scatter + decode append) and dequantize at the gathered
# block-row attend; the scale depends only on the row's own values, so the
# page bits are a pure function of the token span — the property that keeps
# spill/prefetch, migration, and prefix sharing bit-identical.
# ---------------------------------------------------------------------------


def quantize_rows(rows: jax.Array) -> tuple[jax.Array, jax.Array]:
    """float [..., D] -> (int8 [..., D], f32 [...] per-row scale)."""
    rf = rows.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(rf), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(rf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def gather_paged_rows(pages, block_table: jax.Array) -> jax.Array:
    """Materialize each slot's contiguous view from ONE page pool.

    pages: [P, page, *row] (or an int8 ``(data, scale)`` pool — the view
    comes back dequantized to f32); block_table: [B, pages_per_slot] int32
    page ids (0 = the reserved null page).  Returns [B, Smax, *row] with
    Smax = pages_per_slot * page.  Row shape is free — [Hkv, D] for a GQA
    K or V pool, [R] for an MLA compressed-ckv pool, [Dr] for its krope.
    """
    if isinstance(pages, tuple):
        data, scale = pages
        return dequantize_rows(gather_paged_rows(data, block_table),
                               gather_paged_rows(scale, block_table))
    b, pages_per_slot = block_table.shape
    page = pages.shape[1]
    rest = pages.shape[2:]
    return pages[block_table].reshape(b, pages_per_slot * page, *rest)


def write_paged_rows(pages, rows: jax.Array,
                     block_table: jax.Array, lengths: jax.Array,
                     active: jax.Array):
    """Scatter one new token's row per slot into its current page.

    pages: [P, page, *row] or an int8 ``(data, scale)`` pool (rows quantize
    at this write); rows: [B, *row] (this step's values); lengths: [B]
    write positions (= valid length before this token); active: [B] bool.
    Inactive slots are redirected to the reserved null page 0 so their
    garbage never lands in a page owned by a live request.
    """
    page = (pages[0] if isinstance(pages, tuple) else pages).shape[1]
    b = rows.shape[0]
    page_idx = block_table[jnp.arange(b), lengths // page]
    page_idx = jnp.where(active, page_idx, 0)
    offset = lengths % page
    if isinstance(pages, tuple):
        data, scale = pages
        q, s = quantize_rows(rows)
        return (data.at[page_idx, offset].set(q),
                scale.at[page_idx, offset].set(s))
    return pages.at[page_idx, offset].set(rows.astype(pages.dtype))


def scatter_chunk_rows(pages, rows: jax.Array, pid: jax.Array,
                       off: jax.Array):
    """Scatter a chunk's rows at explicit (page, offset) indices — the
    chunked-prefill write path.  rows: [C, *row]; pid/off: [C]."""
    if isinstance(pages, tuple):
        data, scale = pages
        q, s = quantize_rows(rows)
        return data.at[pid, off].set(q), scale.at[pid, off].set(s)
    return pages.at[pid, off].set(rows.astype(pages.dtype))


def gather_paged_kv(k_pages: jax.Array, v_pages: jax.Array,
                    block_table: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Materialize each slot's contiguous KV view from the shared page pool.

    k/v_pages: [P, page, Hkv, D]; block_table: [B, pages_per_slot] int32 page
    ids (0 = the reserved null page).  Returns [B, Smax, Hkv, D] with
    Smax = pages_per_slot * page.
    """
    return (gather_paged_rows(k_pages, block_table),
            gather_paged_rows(v_pages, block_table))


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           lengths: jax.Array) -> jax.Array:
    """One-token attention against a paged cache.

    q: [B, H, D]; k/v_pages: [P, page, Hkv, D]; block_table: [B,
    pages_per_slot]; lengths: [B] valid tokens per slot (new token included).
    """
    k, v = gather_paged_kv(k_pages, v_pages, block_table)
    return decode_attention(q, k, v, lengths)


def paged_chunk_attention(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, block_table: jax.Array,
                          q_positions: jax.Array) -> jax.Array:
    """Multi-query attention for one chunk of prefill against a paged cache.

    q: [B, C, H, D] chunk queries; k/v_pages: [P, page, Hkv, D];
    block_table: [B, pages_per_slot]; q_positions: [B, C] global (cache)
    positions of the chunk queries.  Each query attends exactly the cache
    positions <= its own — all keys are read from the gathered block row, so
    a given position's math is independent of how the prompt was split into
    chunks (the bit-identity contract of chunked prefill; see
    ``models.model.prefill_chunk_into_slot``).
    """
    b, c, h, d = q.shape
    k, v = gather_paged_kv(k_pages, v_pages, block_table)  # [B, Smax, Hkv, D]
    k = _repeat_kv(k, h // k.shape[2])
    v = _repeat_kv(v, h // v.shape[2])
    s = jnp.einsum("bchd,bshd->bhcs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    key_pos = jnp.arange(k.shape[1])
    mask = key_pos[None, None, None, :] <= q_positions[:, None, :, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhcs,bshd->bchd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gather_kv_pages(k_pages: jax.Array, v_pages: jax.Array,
                    page_ids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Pull whole pages out of the pool (spill path of the flash KV tier).

    k/v_pages: [L, P, page, Hkv, D] (the layer-stacked pool); page_ids: [n]
    int32.  Returns ([L, n, page, Hkv, D], same for v).  Callers may pad
    ``page_ids`` with the null page 0 to hit a shape bucket — the junk rows
    are sliced off host-side.
    """
    return jnp.take(k_pages, page_ids, axis=1), \
        jnp.take(v_pages, page_ids, axis=1)


def scatter_kv_pages(k_pages: jax.Array, v_pages: jax.Array,
                     page_ids: jax.Array, ks: jax.Array, vs: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """Write whole pages back into the pool (prefetch path of the KV tier).

    k/v_pages: [L, P, page, Hkv, D]; page_ids: [n]; ks/vs: [L, n, page, Hkv,
    D].  Bucket padding uses the null page 0 with zero payloads — duplicate
    scatters to page 0 write identical values, so the result stays
    deterministic, and null-page contents are never read unmasked.
    """
    return (k_pages.at[:, page_ids].set(ks.astype(k_pages.dtype)),
            v_pages.at[:, page_ids].set(vs.astype(v_pages.dtype)))


def write_paged_kv(k_pages: jax.Array, v_pages: jax.Array, k: jax.Array,
                   v: jax.Array, block_table: jax.Array, lengths: jax.Array,
                   active: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Scatter one new token's K/V per slot into its current page.

    k/v: [B, Hkv, D] (this step's projections); lengths: [B] write positions
    (= valid length before this token); active: [B] bool.  Inactive slots are
    redirected to the reserved null page 0 so their garbage never lands in a
    page owned by a live request.
    """
    return (write_paged_rows(k_pages, k, block_table, lengths, active),
            write_paged_rows(v_pages, v, block_table, lengths, active))
