"""Shared model layers: norms, RoPE variants, linear (float or W8A8), MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear: params are {"w": [in, out] float}, the W8A8 form
# {"w_q": int8 [out, in], "scale": f32 [out]}, or the W4A16 form
# {"w_p4": uint8 [out, in//2], "scale4": f32 [out, ng]}
# (+ optional {"b": [out]} on any of them).
# ---------------------------------------------------------------------------


def linear(params: dict, x: jax.Array) -> jax.Array:
    if "w_q" in params:
        y = _w8a8_matmul(params["w_q"], params["scale"], x)
    elif "w_p4" in params:
        y = (x.astype(jnp.float32)
             @ _w4a16_weight(params["w_p4"], params["scale4"])).astype(x.dtype)
    else:
        y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def _w8a8_matmul(w_q: jax.Array, scale: jax.Array, x: jax.Array) -> jax.Array:
    """y[..., out] = dequant(int8 matmul). Per-token dynamic act quant:
    one scale per row of x (absmax over the feature axis), so an outlier
    token cannot crush the quantization resolution of its batchmates."""
    xf = x.astype(jnp.float32)
    absmax = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True), 1e-8)
    x_scale = absmax / 127.0
    x_q = jnp.clip(jnp.round(xf / x_scale), -127, 127).astype(jnp.int8)
    acc = jax.lax.dot_general(
        x_q, w_q, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * scale.astype(jnp.float32) * x_scale
    return y.astype(x.dtype)


def _w4a16_weight(w_p4: jax.Array, scale4: jax.Array) -> jax.Array:
    """Dequantize packed-nibble W4 weights to f32 [in, out] on the fly."""
    from repro.quant.int4 import GROUP, QuantizedLinear4, dequantize4

    h, wdt = w_p4.shape[0], 2 * w_p4.shape[1]
    q = QuantizedLinear4(w_packed=w_p4, scale=scale4, h=h, w=wdt)
    return dequantize4(q, group=min(GROUP, wdt)).T


def dense_weight(params: dict) -> jax.Array:
    """Materialize the float [in, out] weight of a (possibly quantized)
    linear."""
    if "w" in params:
        return params["w"]
    if "w_p4" in params:
        return _w4a16_weight(params["w_p4"], params["scale4"])
    return (params["w_q"].astype(jnp.float32)
            * params["scale"][:, None].astype(jnp.float32)).T


def init_linear(key: jax.Array, d_in: int, d_out: int, use_bias: bool,
                dtype=jnp.bfloat16, scale: float | None = None) -> dict:
    s = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# ---------------------------------------------------------------------------
# RoPE family
# ---------------------------------------------------------------------------


def rope_freqs(d_rot: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, jnp.float32) / d_rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] int. Rotate the first
    ``fraction * D`` dims (chatglm3 "2d rope" -> fraction=0.5)."""
    d = x.shape[-1]
    d_rot = int(d * fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    freqs = rope_freqs(d_rot, theta)  # [d_rot/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, d_rot/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float) -> jax.Array:
    """Qwen2-VL M-RoPE: head dim split into 3 sections rotated by
    (temporal, height, width) position streams. positions3: [3, B, S]."""
    d = x.shape[-1]
    sec = d // 3 - (d // 3) % 2
    secs = [sec, sec, d - 2 * sec - (d - 2 * sec) % 2]
    outs = []
    off = 0
    for i, ds in enumerate(secs):
        part = x[..., off:off + ds]
        outs.append(apply_rope(part, positions3[i], theta, fraction=1.0))
        off += ds
    if off < d:
        outs.append(x[..., off:])
    return jnp.concatenate(outs, axis=-1)


def mrope_grid_side(n_vision: int) -> int:
    """Vision-grid side length; also the first text position's offset (text
    token at cache index ``idx`` sits at ``idx - n_vision + side`` in every
    stream).  Decode paths continue the stream through this helper so prefill
    and decode can't drift."""
    import math

    return max(int(math.sqrt(max(n_vision, 1))), 1)


def mrope_positions(batch: int, seq: int, n_vision: int) -> jax.Array:
    """Stub M-RoPE position streams: vision tokens on a sqrt grid (t=0),
    text tokens sequential in all three streams."""
    side = mrope_grid_side(n_vision)
    idx = jnp.arange(seq)
    is_vis = idx < n_vision
    t_pos = jnp.where(is_vis, 0, idx - n_vision + side)
    h_pos = jnp.where(is_vis, idx // side, idx - n_vision + side)
    w_pos = jnp.where(is_vis, idx % side, idx - n_vision + side)
    pos = jnp.stack([t_pos, h_pos, w_pos])  # [3, S]
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def ffn(params: dict, x: jax.Array, gated: bool) -> jax.Array:
    if gated:
        g = linear(params["gate"], x)
        u = linear(params["up"], x)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(linear(params["up"], x).astype(jnp.float32)
                        ).astype(x.dtype)
    return linear(params["down"], h)


def init_ffn(key: jax.Array, cfg: ModelConfig, d_ff: int,
             dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    p = {"up": init_linear(ks[0], cfg.d_model, d_ff, cfg.use_bias, dtype),
         "down": init_linear(ks[1], d_ff, cfg.d_model, cfg.use_bias, dtype)}
    if cfg.gated_ffn:
        p["gate"] = init_linear(ks[2], cfg.d_model, d_ff, cfg.use_bias, dtype)
    return p
