"""Attention blocks (GQA, MLA) shared by every transformer family."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.compat import shard_map
from repro.models.attention import (chunked_attention,
                                    decode_attention, gather_paged_rows,
                                    paged_chunk_attention,
                                    paged_decode_attention,
                                    scatter_chunk_rows, write_paged_kv,
                                    write_paged_rows)
from repro.models.layers import (apply_mrope, apply_rope, init_linear,
                                 layer_norm, linear, rms_norm)


def norm(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "ln":
        return layer_norm(x, params["g"], params["b"], cfg.norm_eps)
    return rms_norm(x, params["g"], cfg.norm_eps)


def init_norm(cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    p = {"g": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "ln":
        p["b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def init_attn(key: jax.Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 4)
    qd = cfg.n_heads * cfg.d_head
    kvd = cfg.n_kv_heads * cfg.d_head
    return {
        "q": init_linear(ks[0], cfg.d_model, qd, cfg.use_bias, dtype),
        "k": init_linear(ks[1], cfg.d_model, kvd, cfg.use_bias, dtype),
        "v": init_linear(ks[2], cfg.d_model, kvd, cfg.use_bias, dtype),
        "o": init_linear(ks[3], qd, cfg.d_model, False, dtype),
    }


def _mrope_decode_pos(cfg: ModelConfig, pos):
    """M-RoPE position of the text token at cache index ``pos``: prefill
    assigns text tokens ``idx - n_vision + side`` (layers.mrope_positions),
    and decode must continue that stream, not the raw cache index."""
    from repro.models.layers import mrope_grid_side

    return pos - cfg.n_vision_tokens + mrope_grid_side(cfg.n_vision_tokens)


def _rope_qk(cfg: ModelConfig, q, k, positions):
    if cfg.rope_mode == "standard":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    elif cfg.rope_mode == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    return q, k


def attn_full(params: dict, x: jax.Array, cfg: ModelConfig,
              positions: jax.Array, causal: bool = True,
              kv_override: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence attention. Returns (out, k, v) so callers can build the
    cache. ``kv_override``: cross-attention source states [B, Senc, D]."""
    b, s, _ = x.shape
    from repro.distributed import ctx

    src = kv_override if kv_override is not None else x
    q = linear(params["q"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = linear(params["k"], src).reshape(b, src.shape[1], cfg.n_kv_heads,
                                         cfg.d_head)
    v = linear(params["v"], src).reshape(b, src.shape[1], cfg.n_kv_heads,
                                         cfg.d_head)
    if kv_override is None:
        q, k = _rope_qk(cfg, q, k, positions)
    # constraint policy "heads" won the §Perf bake-off: the alternative
    # (q seq-sharded + K/V gathered) measured WORSE (48.8 vs 24.4 GB/layer
    # of all-gather on command-r train) because the o-proj/FFN TP dims then
    # conflict with the sequence sharding on the same mesh axis.
    q = ctx.constrain(q, kind="heads")
    k = ctx.constrain(k, kind="heads")
    v = ctx.constrain(v, kind="heads")
    out = chunked_attention(q, k, v, causal=causal and kv_override is None)
    out = ctx.constrain(out, kind="heads")
    out = linear(params["o"], out.reshape(b, s, -1))
    return out, k, v


def attn_decode(params: dict, x: jax.Array, cfg: ModelConfig,
                k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention. x: [B, D]; caches [B, Smax, Hkv, Dh]; pos [].

    Returns (out [B, D], new k_cache, new v_cache)."""
    b = x.shape[0]
    q = linear(params["q"], x).reshape(b, 1, cfg.n_heads, cfg.d_head)
    k = linear(params["k"], x).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    v = linear(params["v"], x).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    posb = jnp.broadcast_to(jnp.asarray(pos).reshape(1, 1), (b, 1))
    if cfg.rope_mode == "mrope":
        mpos = _mrope_decode_pos(cfg, jnp.asarray(pos))
        pos3 = jnp.broadcast_to(mpos.reshape(1, 1, 1), (3, b, 1))
        q, k = _rope_qk(cfg, q, k, pos3)
    else:
        q, k = _rope_qk(cfg, q, k, posb)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)
    out = decode_attention(q[:, 0], k_cache, v_cache, pos + 1)
    out = linear(params["o"], out.reshape(b, -1))
    return out, k_cache, v_cache


def attn_decode_paged(params: dict, x: jax.Array, cfg: ModelConfig,
                      k_pages: jax.Array, v_pages: jax.Array,
                      block_table: jax.Array, lengths: jax.Array,
                      active: jax.Array
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention against a paged per-slot cache.

    x: [B, D]; k/v_pages: [P, page, Hkv, Dh]; block_table: [B,
    pages_per_slot]; lengths: [B] per-slot valid lengths (the new token's
    write position); active: [B] bool.  Unlike ``attn_decode`` every slot
    carries its own position, so mixed-progress slots decode in one batch.

    Returns (out [B, D], new k_pages, new v_pages)."""
    b = x.shape[0]
    q = linear(params["q"], x).reshape(b, 1, cfg.n_heads, cfg.d_head)
    k = linear(params["k"], x).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    v = linear(params["v"], x).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    posb = lengths.reshape(b, 1)
    if cfg.rope_mode == "mrope":
        pos3 = jnp.broadcast_to(_mrope_decode_pos(cfg, posb)[None], (3, b, 1))
        q, k = _rope_qk(cfg, q, k, pos3)
    else:
        q, k = _rope_qk(cfg, q, k, posb)
    k_pages, v_pages = write_paged_kv(k_pages, v_pages, k[:, 0], v[:, 0],
                                      block_table, lengths, active)
    out = paged_decode_attention(q[:, 0], k_pages, v_pages, block_table,
                                 lengths + active.astype(jnp.int32))
    out = linear(params["o"], out.reshape(b, -1))
    return out, k_pages, v_pages


def attn_prefill_chunk_paged(params: dict, x: jax.Array, cfg: ModelConfig,
                             k_pages: jax.Array, v_pages: jax.Array,
                             block_row: jax.Array, positions: jax.Array,
                             valid: jax.Array
                             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked-prefill attention for ONE slot against the paged pool.

    x: [1, C, D] chunk hidden states (C is any shape bucket; rows past
    the chunk carry ``valid=False``); k/v_pages: [P, page, Hkv, Dh];
    block_row: [pages_per_slot] the slot's block-table row; positions:
    [1, C] global cache positions (start + arange(C)); valid: [C] bool.

    The chunk's K/V is scattered into the slot's pages FIRST (invalid rows
    are redirected to the reserved null page 0), then every query attends
    the gathered block row under a per-position causal mask — so each
    position's math is identical no matter how the prompt was chunked.
    Returns (out [1, C, D], new k_pages, new v_pages).
    """
    b, c, _ = x.shape
    q = linear(params["q"], x).reshape(b, c, cfg.n_heads, cfg.d_head)
    k = linear(params["k"], x).reshape(b, c, cfg.n_kv_heads, cfg.d_head)
    v = linear(params["v"], x).reshape(b, c, cfg.n_kv_heads, cfg.d_head)
    q, k = _rope_qk(cfg, q, k, positions)
    page = (k_pages[0] if isinstance(k_pages, tuple) else k_pages).shape[1]
    pps = block_row.shape[0]
    gpos = positions[0]
    pid = jnp.where(valid, block_row[jnp.clip(gpos // page, 0, pps - 1)], 0)
    off = gpos % page
    k_pages = scatter_chunk_rows(k_pages, k[0], pid, off)
    v_pages = scatter_chunk_rows(v_pages, v[0], pid, off)
    out = paged_chunk_attention(q, k_pages, v_pages, block_row[None],
                                positions)
    out = linear(params["o"], out.reshape(b, c, -1))
    return out, k_pages, v_pages


def paged_pool_names(cache: dict) -> tuple[str, str]:
    """The two layer-stacked page pools a paged cache spills/prefetches.

    GQA families page full K/V; MLA pages the compressed (ckv, krope) pair
    instead — a page row is [page, R] + [page, Dr] rather than
    2x[page, Hkv, Dh], which is exactly why flash-resident KV is cheapest
    per token for the MLA family (the spilled bytes shrink with the cache).
    """
    return ("ckv", "krope") if "ckv" in cache else ("k", "v")


def paged_pool_keys(cache: dict) -> tuple[str, ...]:
    """Every cache key whose pages move on spill/snapshot — the two data
    pools plus, under kv_dtype="int8", their f32 scale pools.  A page
    payload is one array per key, in THIS order; everything that carries
    payloads (tier, snapshots, wire) treats them as an opaque tuple, which
    is how quantized pages ride the machinery unchanged."""
    a, b = paged_pool_names(cache)
    keys = (a, b)
    if a + "_scale" in cache:
        keys = keys + (a + "_scale", b + "_scale")
    return keys


def kv_swap_out(cache: dict, page_ids: jax.Array) -> tuple[jax.Array, ...]:
    """Spill path of the tiered KV cache: gather whole pages from the pool.

    cache: the paged cache dict (layer-stacked pools); page_ids: [n].
    Returns one page payload per pool key bound for the flash tier —
    ([L, n, page, Hkv, Dh] x2) for GQA k/v pools, ([L, n, page, R],
    [L, n, page, Dr]) for MLA ckv/krope, plus the matching [L, n, page,
    ...] f32 scale payloads when the pools are int8.  The pool itself is
    untouched — the freed pids are simply handed back to the hot allocator.
    """
    return tuple(jnp.take(cache[key], page_ids, axis=1)
                 for key in paged_pool_keys(cache))


def kv_swap_in(cache: dict, page_ids: jax.Array, *payloads: jax.Array
               ) -> dict:
    """Prefetch path: scatter fetched page payloads into (new) hot pages.

    The pages come back on *different* pids than they were spilled from; the
    engine remaps the owning slot's block-table row, which is what keeps
    decode math bit-identical to the all-resident run — attention only ever
    sees the gathered values, not the pids.  ``payloads`` is one array per
    ``paged_pool_keys`` entry, exactly as ``kv_swap_out`` returned them.
    """
    keys = paged_pool_keys(cache)
    assert len(payloads) == len(keys), (len(payloads), keys)
    out = {**cache}
    for key, payload in zip(keys, payloads):
        pool = cache[key]
        out[key] = pool.at[:, page_ids].set(payload.astype(pool.dtype))
    return out


def cross_attn_decode(params: dict, x: jax.Array, cfg: ModelConfig,
                      k_cache: jax.Array, v_cache: jax.Array,
                      enc_len: int) -> jax.Array:
    """Decoder cross-attention against fixed encoder K/V."""
    b = x.shape[0]
    q = linear(params["q"], x).reshape(b, cfg.n_heads, cfg.d_head)
    return linear(params["o"],
                  decode_attention(q, k_cache, v_cache, enc_len
                                   ).reshape(b, -1))


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key: jax.Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 5)
    qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "q": init_linear(ks[0], cfg.d_model, cfg.n_heads * qk_head, False, dtype),
        "kv_a": init_linear(ks[1], cfg.d_model,
                            cfg.kv_lora_rank + cfg.qk_rope_dim, False, dtype),
        "kv_a_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        "kv_b": init_linear(ks[2], cfg.kv_lora_rank,
                            cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim),
                            False, dtype),
        "o": init_linear(ks[3], cfg.n_heads * cfg.v_head_dim, cfg.d_model,
                         False, dtype),
    }


def mla_full(params: dict, x: jax.Array, cfg: ModelConfig,
             positions: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence MLA. Returns (out, c_kv, k_rope) for the compressed cache."""
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = linear(params["q"], x).reshape(b, s, h, qk_head)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    kv = linear(params["kv_a"], x)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_a_norm"], cfg.norm_eps)
    kvb = linear(params["kv_b"], c_kv).reshape(
        b, s, h, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kvb, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope.reshape(b, s, 1, cfg.qk_rope_dim), positions,
                        cfg.rope_theta)
    k_rope_h = jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope_dim))
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # pad v to qk_head so we can reuse chunked_attention, then slice back
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_head - cfg.v_head_dim)))
    # chunked_attention applies the qk_head^-0.5 scale (the MLA convention)
    out = chunked_attention(qf, kf, vpad, causal=True)[..., :cfg.v_head_dim]
    out = linear(params["o"], out.reshape(b, s, -1))
    return out, c_kv, k_rope[:, :, 0, :]


def _mla_decode_qkv(params: dict, x: jax.Array, cfg: ModelConfig,
                    posb: jax.Array):
    """Shared decode-token projections: (q_nope, roped q_rope, normed c_kv,
    roped k_rope) for one token per lane at per-lane positions ``posb``
    ([B, 1])."""
    b = x.shape[0]
    h, r = cfg.n_heads, cfg.kv_lora_rank
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = linear(params["q"], x).reshape(b, h, dn + dr)
    q_nope, q_rope = jnp.split(q, [dn], axis=-1)
    kv = linear(params["kv_a"], x)
    c_kv, k_rope = jnp.split(kv, [r], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_a_norm"], cfg.norm_eps)
    q_rope = apply_rope(q_rope[:, None], posb, cfg.rope_theta)[:, 0]
    k_rope = apply_rope(k_rope[:, None, None, :], posb, cfg.rope_theta)[:, 0, 0]
    return q_nope, q_rope, c_kv, k_rope


def _mla_absorbed_attend(params: dict, x: jax.Array, cfg: ModelConfig,
                         q_nope: jax.Array, q_rope: jax.Array,
                         ckv: jax.Array, krope: jax.Array,
                         valid_len: jax.Array) -> jax.Array:
    """Absorbed-matrix attention against a contiguous compressed cache view.

    ckv: [B, Smax, R]; krope: [B, Smax, Dr]; valid_len: [] or [B] tokens
    (new token included).  Per-token FLOPs scale with R + Dr instead of the
    H*(Dn+Dr) decompressed cache width.
    """
    b = x.shape[0]
    h, r = cfg.n_heads, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    # absorb W_UK into the query: q_c[b,h,r] = q_nope . W_uk
    from repro.models.layers import dense_weight
    wkb = dense_weight(params["kv_b"]).reshape(r, h, dn + dv)
    w_uk, w_uv = wkb[..., :dn], wkb[..., dn:]
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    scores = (jnp.einsum("bhr,bsr->bhs", q_c, ckv.astype(jnp.float32))
              + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                           krope.astype(jnp.float32)))
    scores = scores * ((dn + dr) ** -0.5)
    smax = ckv.shape[1]
    valid = jnp.arange(smax)[None, :] < jnp.asarray(valid_len).reshape(-1, 1)
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", p, ckv.astype(jnp.float32))
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
    return linear(params["o"], out.reshape(b, -1).astype(x.dtype))


def mla_decode(params: dict, x: jax.Array, cfg: ModelConfig,
               ckv_cache: jax.Array, krope_cache: jax.Array, pos: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-matrix MLA decode: queries hit the compressed cache directly.

    ckv_cache: [B, Smax, R]; krope_cache: [B, Smax, Dr].
    """
    b = x.shape[0]
    posb = jnp.broadcast_to(jnp.asarray(pos).reshape(1, 1), (b, 1))
    q_nope, q_rope, c_kv, k_rope = _mla_decode_qkv(params, x, cfg, posb)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_kv[:, None].astype(ckv_cache.dtype), pos, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, k_rope[:, None].astype(krope_cache.dtype), pos, axis=1)
    out = _mla_absorbed_attend(params, x, cfg, q_nope, q_rope, ckv_cache,
                               krope_cache, pos + 1)
    return out, ckv_cache, krope_cache


def mla_decode_paged(params: dict, x: jax.Array, cfg: ModelConfig,
                     ckv_pages: jax.Array, krope_pages: jax.Array,
                     block_table: jax.Array, lengths: jax.Array,
                     active: jax.Array
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token MLA decode against a paged compressed cache.

    ckv_pages: [P, page, R]; krope_pages: [P, page, Dr]; block_table: [B,
    pages_per_slot]; lengths: [B] per-slot valid lengths (the new token's
    write position); active: [B] bool.  Pages carry compressed
    [page, R + Dr] rows instead of full K/V, and decode attends the gathered
    compressed block row — the math the all-resident ``mla_decode`` does,
    with per-slot positions instead of the shared cursor.

    Returns (out [B, D], new ckv_pages, new krope_pages)."""
    b = x.shape[0]
    posb = lengths.reshape(b, 1)
    q_nope, q_rope, c_kv, k_rope = _mla_decode_qkv(params, x, cfg, posb)
    ckv_pages = write_paged_rows(ckv_pages, c_kv, block_table, lengths,
                                 active)
    krope_pages = write_paged_rows(krope_pages, k_rope, block_table, lengths,
                                   active)
    ckv = gather_paged_rows(ckv_pages, block_table)      # [B, Smax, R]
    krope = gather_paged_rows(krope_pages, block_table)  # [B, Smax, Dr]
    out = _mla_absorbed_attend(params, x, cfg, q_nope, q_rope, ckv, krope,
                               lengths + jnp.asarray(active, jnp.int32))
    return out, ckv_pages, krope_pages


# ---------------------------------------------------------------------------
# shard_map split-K decode attention (flash-decoding over the model axis)
# ---------------------------------------------------------------------------
#
# GSPMD cannot partition a dynamic-position dynamic_update_slice on the
# sharded sequence dim of the KV cache: it all-gathers the cache, updates,
# and re-scatters (≈2 GB/layer/token on command-r decode_32k — the dominant
# §Roofline collective).  The explicit version below keeps every cache shard
# local: each model shard owns S/16 of the sequence, performs the update only
# if the write position lands in its slice, computes its partial
# online-softmax, and the shards combine with tiny (m, l, o) reductions.


NEG_INF = -1e30


def attn_decode_sharded(params: dict, x: jax.Array, cfg: ModelConfig,
                        k_cache: jax.Array, v_cache: jax.Array,
                        pos: jax.Array, mesh, batch_axes
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Split-K decode attention. caches [B, Smax, Hkv, Dh] sharded
    (batch_axes, 'model', None, None); x [B, D] sharded (batch_axes,)."""
    from jax.sharding import PartitionSpec as P

    b = x.shape[0]
    q = linear(params["q"], x).reshape(b, 1, cfg.n_heads, cfg.d_head)
    k = linear(params["k"], x).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    v = linear(params["v"], x).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    posb = jnp.broadcast_to(jnp.asarray(pos).reshape(1, 1), (b, 1))
    if cfg.rope_mode == "mrope":
        mpos = _mrope_decode_pos(cfg, jnp.asarray(pos))
        pos3 = jnp.broadcast_to(mpos.reshape(1, 1, 1), (3, b, 1))
        q, k = _rope_qk(cfg, q, k, pos3)
    else:
        q, k = _rope_qk(cfg, q, k, posb)

    n_rep = cfg.n_heads // cfg.n_kv_heads

    def body(qb, kb, vb, kc, vc, p):
        s_loc = kc.shape[1]
        shard = jax.lax.axis_index("model")
        s0 = shard * s_loc
        idx = jnp.clip(p - s0, 0, s_loc - 1)
        in_range = (p >= s0) & (p < s0 + s_loc)
        cur_k = jax.lax.dynamic_slice_in_dim(kc, idx, 1, axis=1)
        cur_v = jax.lax.dynamic_slice_in_dim(vc, idx, 1, axis=1)
        new_k = jnp.where(in_range, kb.astype(kc.dtype), cur_k)
        new_v = jnp.where(in_range, vb.astype(vc.dtype), cur_v)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, new_k, idx, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, new_v, idx, axis=1)
        # local online softmax over this shard's sequence slice; GQA via
        # grouped einsum — a materialized repeat would read the cache n_rep
        # times over (12x HBM amplification on command-r's 96q/8kv)
        bq = qb[:, 0].reshape(qb.shape[0], cfg.n_kv_heads, n_rep, cfg.d_head)
        s = jnp.einsum("bgrd,bsgd->bgrs", bq.astype(jnp.float32),
                       kc.astype(jnp.float32)) * (cfg.d_head ** -0.5)
        valid = (s0 + jnp.arange(s_loc))[None, :] < (p + 1)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_loc = s.max(-1)                                  # [B, G, R]
        p_exp = jnp.exp(s - m_loc[..., None])
        l_loc = p_exp.sum(-1)
        o_loc = jnp.einsum("bgrs,bsgd->bgrd", p_exp, vc.astype(jnp.float32))
        m = jax.lax.pmax(m_loc, "model")
        scale = jnp.exp(m_loc - m)
        l = jax.lax.psum(l_loc * scale, "model")
        o = jax.lax.psum(o_loc * scale[..., None], "model")
        o = o / jnp.maximum(l, 1e-20)[..., None]
        o = o.reshape(o.shape[0], cfg.n_heads * cfg.d_head)
        return o.astype(x.dtype), kc, vc

    bspec = batch_axes
    cache_spec = P(bspec, "model", None, None)
    out, k_cache, v_cache = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, None, None, None),
                  P(bspec, None, None, None), cache_spec, cache_spec, P()),
        out_specs=(P(bspec, None), cache_spec, cache_spec),
        check_vma=False,
    )(q, k, v, k_cache, v_cache, jnp.asarray(pos, jnp.int32))
    out = linear(params["o"], out)
    return out, k_cache, v_cache
