"""Mixture-of-Experts layer: top-k routing + ragged_dot grouped GEMM.

Two execution paths sharing the same math:

* **Local** (no mesh): tokens sorted by expert, one ``jax.lax.ragged_dot``
  against the stacked expert weights.  Used by smoke tests and examples.

* **Expert-parallel shard_map** (mesh in ctx): activations replicated over
  the ``model`` axis, experts sharded over it; every model shard locally
  sorts the (token, slot) pairs that hit *its* experts into a fixed
  ``capacity``-bounded buffer (2x balanced load; overflow drops, standard
  capacity-style MoE), runs the local ragged GEMM, scatters back, and the
  shards' partial outputs are ``psum``'d over ``model`` — the same collective
  pattern as dense TP-FFN, so MoE costs no extra collective class.  This
  avoids GSPMD's global-argsort gather (which blew per-device memory to
  ~77 GB on qwen2-moe train before this path existed).

Expert padding: non-divisible routed-expert counts (qwen2's 60) pad to the
mesh multiple with router logits pinned to -inf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.kernels.compat import shard_map
from repro.models.layers import init_linear, linear

CAPACITY_FACTOR = 2.0


def padded_experts(cfg: ModelConfig, divisor: int = 16) -> int:
    return -(-cfg.n_experts // divisor) * divisor


def init_moe(key: jax.Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    e = padded_experts(cfg)
    dff = cfg.moe_d_ff
    ks = jax.random.split(key, 8)
    s_in = cfg.d_model ** -0.5
    s_dn = dff ** -0.5

    def w(k, shape, s):
        return (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)

    p = {
        "router": init_linear(ks[0], cfg.d_model, e, False, jnp.float32),
        "up": w(ks[1], (e, cfg.d_model, dff), s_in),
        "down": w(ks[2], (e, dff, cfg.d_model), s_dn),
    }
    if cfg.gated_ffn:
        p["gate"] = w(ks[3], (e, cfg.d_model, dff), s_in)
    if cfg.n_shared_experts:
        sh_ff = cfg.moe_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "up": init_linear(ks[4], cfg.d_model, sh_ff, False, dtype),
            "down": init_linear(ks[5], sh_ff, cfg.d_model, False, dtype),
        }
        if cfg.gated_ffn:
            p["shared"]["gate"] = init_linear(ks[6], cfg.d_model, sh_ff,
                                              False, dtype)
    return p


def _route(params, xt, cfg: ModelConfig, e: int):
    logits = xt.astype(jnp.float32) @ params["router"]["w"]
    if e > cfg.n_experts:
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    topw, topi = jax.lax.top_k(logits, cfg.top_k)
    probs = jax.nn.softmax(topw, axis=-1)
    return topi, probs


def _expert_gemm(params, xs, group_sizes, cfg: ModelConfig):
    h_up = jax.lax.ragged_dot(xs, params["up"].astype(xs.dtype), group_sizes)
    if cfg.gated_ffn:
        h_g = jax.lax.ragged_dot(xs, params["gate"].astype(xs.dtype),
                                 group_sizes)
        h = jax.nn.silu(h_g.astype(jnp.float32)).astype(xs.dtype) * h_up
    else:
        h = jax.nn.gelu(h_up.astype(jnp.float32)).astype(xs.dtype)
    return jax.lax.ragged_dot(h, params["down"].astype(xs.dtype), group_sizes)


def _shared_ffn(params, xt, cfg: ModelConfig):
    sh = params["shared"]
    u = linear(sh["up"], xt)
    if cfg.gated_ffn:
        g = linear(sh["gate"], xt)
        hs = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
    else:
        hs = jax.nn.gelu(u.astype(jnp.float32)).astype(xt.dtype)
    return linear(sh["down"], hs)


def _moe_local(params, xt, cfg: ModelConfig, e: int) -> jax.Array:
    n, d = xt.shape
    k = cfg.top_k
    topi, probs = _route(params, xt, cfg, e)
    flat_expert = topi.reshape(-1)
    order = jnp.argsort(flat_expert)
    inv = jnp.argsort(order)
    xs = jnp.repeat(xt, k, axis=0)[order]
    group_sizes = jnp.bincount(flat_expert, length=e).astype(jnp.int32)
    ye = _expert_gemm(params, xs, group_sizes, cfg)
    ye = ye[inv].reshape(n, k, d)
    return jnp.einsum("nkd,nk->nd", ye.astype(jnp.float32), probs)


def _moe_expert_parallel(params, xt, cfg: ModelConfig, e: int) -> jax.Array:
    """Per-(data,model) shard body. xt local tokens [n_loc, d]; expert stacks
    are the LOCAL slices [e_loc, ...]."""
    n, d = xt.shape
    k = cfg.top_k
    e_loc = params["up"].shape[0]
    n_shards = e // e_loc
    shard = jax.lax.axis_index("model")
    e0 = shard * e_loc
    topi, probs = _route(params, xt, cfg, e)   # router is replicated

    flat_expert = topi.reshape(-1)              # [n*k] global expert ids
    local_e = flat_expert - e0
    mine = (local_e >= 0) & (local_e < e_loc)
    sort_key = jnp.where(mine, local_e, e_loc)  # dump bucket sorts last
    # 2x balanced load, floored at 64 so small/imbalanced batches (decode,
    # randomly-initialized routers) never drop; capped at n*k (zero drops)
    capacity = max(int(-(-n * k * CAPACITY_FACTOR // n_shards)), 64)
    capacity = min(capacity, n * k)
    order = jnp.argsort(sort_key)[:capacity]    # hits first, then dumps
    key_sel = sort_key[order]
    token_idx = order // k
    xs = xt[token_idx]
    group_sizes = jnp.bincount(key_sel, length=e_loc).astype(jnp.int32)
    ye = _expert_gemm(params, xs, group_sizes, cfg)
    # zero out dump-bucket rows (they ran through the last real expert's tail
    # group implicitly — ragged_dot leaves rows past the groups at garbage,
    # so mask by selection validity) and combine with router probs.
    valid = (key_sel < e_loc)[:, None]
    w = probs.reshape(-1)[order][:, None]
    contrib = ye.astype(jnp.float32) * w * valid
    y = jnp.zeros((n, d), jnp.float32).at[token_idx].add(contrib)
    return jax.lax.psum(y, "model")


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    e = params["up"].shape[0] if "up" in params else padded_experts(cfg)
    mesh = ctx.mesh()
    xt = x.reshape(-1, d)
    if mesh is not None and "model" in mesh.shape:
        dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
        routed = {"router": params["router"], "up": params["up"],
                  "down": params["down"]}
        specs = {"router": {"w": P()}, "up": P("model", None, None),
                 "down": P("model", None, None)}
        if "gate" in params:
            routed["gate"] = params["gate"]
            specs["gate"] = P("model", None, None)

        def body(p, xloc):
            nl, dd = xloc.shape[0] * xloc.shape[1], xloc.shape[2]
            y = _moe_expert_parallel(p, xloc.reshape(nl, dd), cfg, e)
            return y.reshape(xloc.shape).astype(x.dtype)

        y = shard_map(
            body, mesh=mesh,
            in_specs=(specs, P(dp, None, None)),
            out_specs=P(dp, None, None),
        )(routed, x)
        y = y.reshape(-1, d).astype(jnp.float32)
    else:
        y = _moe_local(params, xt, cfg, e)
    if "shared" in params:
        y = y + _shared_ffn(params, xt, cfg).astype(jnp.float32)
    return y.astype(x.dtype).reshape(b, s, d)
