"""Bridge from ``src/repro`` to the optional sanitizer rails.

The serving hot paths call :func:`load` and get either the
``tools.analysis.sanitize`` module or ``None``; everything downstream is
gated on that, so a checkout without ``tools/`` (or with
``REPRO_SANITIZE`` unset) pays one ``os.environ`` lookup and nothing else.

``tools`` is importable under pytest (the repo root is the rootdir) but
not from standalone scripts run as ``PYTHONPATH=src python ...``, so the
bridge bootstraps the repo root onto ``sys.path`` when needed.
"""

from __future__ import annotations

import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def load():
    """Return the sanitize module when rails are enabled, else ``None``."""
    if not enabled():
        return None
    try:
        from tools.analysis import sanitize
    except ImportError:
        if _REPO_ROOT in sys.path:
            return None
        sys.path.insert(0, _REPO_ROOT)
        try:
            from tools.analysis import sanitize
        except ImportError:
            return None
    return sanitize
