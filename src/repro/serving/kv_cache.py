"""Host-side page management for the paged / tiered per-slot KV cache.

The device arrays (page pool, block table, length vector) live in the cache
dict built by ``models.model.init_paged_cache``; admission/free decisions are
control flow, so the free list stays host-side in the engine.  Page 0 is the
reserved null page (inactive slots park their writes there) and is never
handed out.

Two allocators:

* :class:`PageAllocator` — the flat free-list over the *hot* (NPU-DRAM
  resident) page pool.
* :class:`TieredPageAllocator` — the two-tier store: the hot pool above plus
  a *cold* flash tier (the simulated NAND dies of the paper's chiplet).  It
  tracks per-(slot, page) residency, keeps an LRU queue of eviction-eligible
  hot pages (oldest non-tail pages of suspended/idle slots first), and holds
  the spilled page payloads so the engine can prefetch a slot's pages back
  before its next decode step.  This is the KVNAND-style seam the block table
  was built for: KV capacity scales past NPU DRAM exactly like the weights
  do, with spill/prefetch bytes riding the Slice Control channel bubbles
  (see ``core/schedule.py`` and the "Flash-resident KV pages" design note in
  ROADMAP.md for the bubble accounting).

The allocator is pure host bookkeeping (payloads are opaque to it — the
engine hands it numpy page blobs); all device data movement goes through
``models.model.swap_out_pages`` / ``swap_in_pages``.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Hashable


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class PageAllocator:
    """Free-list allocator over ``num_pages`` pages; page 0 is reserved."""

    num_pages: int

    def __post_init__(self):
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._free_set = set(self._free)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free):
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        return out

    def free(self, pids: list[int]) -> None:
        seen: set[int] = set()
        for p in pids:  # validate the whole batch before applying any of it
            if p == 0:
                raise ValueError("page 0 is the reserved null page")
            if p in self._free_set or p in seen or not 0 < p < self.num_pages:
                # a double-freed id would be handed out to two slots and
                # silently corrupt both KV streams
                raise ValueError(f"page {p} freed twice (or never allocated)")
            seen.add(p)
        self._free.extend(pids)
        self._free_set.update(pids)


PageKey = Hashable  # engine uses (slot, page_idx)


class TieredPageAllocator:
    """Two-tier page store: hot device pool + cold flash tier.

    Residency bookkeeping only — the engine performs the device gather /
    scatter and hands page payloads (opaque host blobs) in and out:

    * ``mark_evictable(key, pid)`` — a hot page becomes an eviction candidate
      (call in LRU order: oldest page of the least-recently-suspended slot
      first, tail pages last).
    * ``pop_evictable(n, exclude)`` — up to ``n`` LRU candidates to spill.
    * ``store(key, payload)`` / ``fetch(key)`` — the cold store proper.
    * ``cold_keys(match)`` — cold pages of one slot, for prefetch before its
      next decode step.

    ``flash_pages`` bounds the cold tier (None = the NAND dies dwarf the KV
    working set, the paper's regime).
    """

    def __init__(self, num_pages: int, flash_pages: int | None = None):
        self.hot = PageAllocator(num_pages)
        self.flash_pages = flash_pages
        self._cold: dict[PageKey, object] = {}
        self._evictable: OrderedDict[PageKey, int] = OrderedDict()

    # -------------------------------------------------------- hot pool
    @property
    def available(self) -> int:
        return self.hot.available

    def alloc(self, n: int = 1) -> list[int]:
        return self.hot.alloc(n)

    def free(self, pids: list[int]) -> None:
        self.hot.free(pids)

    # -------------------------------------------------------- residency
    @property
    def cold_count(self) -> int:
        return len(self._cold)

    @property
    def flash_available(self) -> int | None:
        """Free cold-tier pages (None = unbounded)."""
        if self.flash_pages is None:
            return None
        return self.flash_pages - len(self._cold)

    @property
    def evictable_count(self) -> int:
        return len(self._evictable)

    def mark_evictable(self, key: PageKey, pid: int) -> None:
        if key in self._evictable or key in self._cold:
            raise ValueError(f"page {key!r} already evictable/cold")
        self._evictable[key] = pid

    def pop_evictable(self, n: int,
                      exclude=None) -> list[tuple[PageKey, int]]:
        """Up to ``n`` oldest candidates ``(key, hot pid)``, removed from the
        queue; the caller must spill each one (``store``) and free its pid.
        ``exclude(key) -> bool`` shields a slot's own pages (used when making
        room to prefetch that very slot)."""
        out = []
        for key in list(self._evictable):
            if len(out) >= n:
                break
            if exclude is not None and exclude(key):
                continue
            out.append((key, self._evictable.pop(key)))
        return out

    # -------------------------------------------------------- cold store
    def store(self, key: PageKey, payload) -> None:
        if key in self._cold:
            raise ValueError(f"page {key!r} already cold")
        if (self.flash_pages is not None
                and len(self._cold) >= self.flash_pages):
            raise OutOfPages(f"flash tier full ({self.flash_pages} pages)")
        self._cold[key] = payload

    def fetch(self, key: PageKey):
        """Pop one cold page's payload (the engine scatters it back into a
        freshly allocated hot page and remaps the block table)."""
        return self._cold.pop(key)

    def cold_keys(self, match) -> list[PageKey]:
        """Cold pages with ``match(key)`` true, in insertion (spill) order."""
        return [k for k in self._cold if match(k)]

    def unmark_slot(self, match) -> None:
        """Withdraw a resumed slot's remaining eviction candidates (every
        page of a decoding slot must stay hot until its next suspension)."""
        for k in [k for k in self._evictable if match(k)]:
            del self._evictable[k]

    def drop_slot(self, match) -> None:
        """Forget every page of a finished slot (cold payloads and eviction
        candidates; the engine frees the hot pids itself)."""
        for k in [k for k in self._cold if match(k)]:
            del self._cold[k]
        for k in [k for k in self._evictable if match(k)]:
            del self._evictable[k]


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def kv_page_elems(cfg, page_size: int) -> int:
    """Elements one KV page holds across ALL its layer-stacked pools — the
    single source of truth for per-family page-byte accounting (the engine's
    ``kv_page_bytes`` and the simulator's tier pricing both derive from it).

    * dense/vlm/moe: K + V rows, every layer — 2 * L * page * Hkv * Dh.
    * mla_moe: the page carries COMPRESSED [page, d_ckv + d_krope] rows
      (ckv + krope pools), every layer — spilled bytes shrink with the
      cache, which is what makes flash-resident KV cheapest per token here.
    * hybrid: only the shared-attention applications carry KV — 2 *
      (L // shared_attn_every) * page * Hkv * Dh; the Mamba state never
      pages (it lives in the slot-indexed state pool).
    """
    f = cfg.family
    if f == "mla_moe":
        return cfg.n_layers * page_size * (cfg.kv_lora_rank + cfg.qk_rope_dim)
    if f == "hybrid":
        n_groups = cfg.n_layers // cfg.shared_attn_every
        return 2 * n_groups * page_size * cfg.n_kv_heads * cfg.d_head
    if f in ("dense", "vlm", "moe"):
        return 2 * cfg.n_layers * page_size * cfg.n_kv_heads * cfg.d_head
    raise ValueError(f"family {f!r} has no paged KV cache")


def chunk_spans(n_tokens: int, budget: int) -> list[tuple[int, int]]:
    """Reference chunked-prefill schedule for a FIXED budget: ``(start,
    length)`` spans of at most ``budget`` tokens tiling the prompt.  The
    engine derives each span live instead (the budget is a per-step policy
    decision, free to adapt); this helper is the oracle the bit-identity
    tests walk — ``models.model.prefill_chunk_into_slot`` guarantees the
    same logits for EVERY split, so any schedule is a pure pacing choice."""
    if budget <= 0:
        raise ValueError(f"chunk budget must be positive, got {budget}")
    return [(s, min(budget, n_tokens - s))
            for s in range(0, n_tokens, budget)]


def prefill_bucket(n_tokens: int, floor: int = 8) -> int:
    """Pad single-slot prefill lengths to power-of-two buckets so the jitted
    prefill retraces O(log max_seq) times instead of once per prompt length."""
    b = floor
    while b < n_tokens:
        b *= 2
    return b
