"""Host-side page management for the paged per-slot KV cache.

The device arrays (page pool, block table, length vector) live in the cache
dict built by ``models.model.init_paged_cache``; admission/free decisions are
control flow, so the free list stays host-side in the engine.  Page 0 is the
reserved null page (inactive slots park their writes there) and is never
handed out.

This split is deliberate: the allocator is the seam where flash-resident KV
(KVNAND-style page spill to the NAND dies) plugs in later — the block table
already gives every slot location-independence.
"""

from __future__ import annotations

import dataclasses


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class PageAllocator:
    """Free-list allocator over ``num_pages`` pages; page 0 is reserved."""

    num_pages: int

    def __post_init__(self):
        self._free = list(range(self.num_pages - 1, 0, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free):
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        return out

    def free(self, pids: list[int]) -> None:
        for p in pids:
            if p == 0:
                raise ValueError("page 0 is the reserved null page")
            self._free.append(p)


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def prefill_bucket(n_tokens: int, floor: int = 8) -> int:
    """Pad single-slot prefill lengths to power-of-two buckets so the jitted
    prefill retraces O(log max_seq) times instead of once per prompt length."""
    b = floor
    while b < n_tokens:
        b *= 2
    return b
