"""Host-side page management for the paged / tiered per-slot KV cache.

The device arrays (page pool, block table, length vector) live in the cache
dict built by ``models.model.init_paged_cache``; admission/free decisions are
control flow, so the free list stays host-side in the engine.  Page 0 is the
reserved null page (inactive slots park their writes there) and is never
handed out.

Two allocators:

* :class:`PageAllocator` — the flat free-list over the *hot* (NPU-DRAM
  resident) page pool.
* :class:`TieredPageAllocator` — the two-tier store: the hot pool above plus
  a *cold* flash tier (the simulated NAND dies of the paper's chiplet).  It
  tracks per-(slot, page) residency, keeps an LRU queue of eviction-eligible
  hot pages (oldest non-tail pages of suspended/idle slots first), and holds
  the spilled page payloads so the engine can prefetch a slot's pages back
  before its next decode step.  This is the KVNAND-style seam the block table
  was built for: KV capacity scales past NPU DRAM exactly like the weights
  do, with spill/prefetch bytes riding the Slice Control channel bubbles
  (see ``core/schedule.py`` and the "Flash-resident KV pages" design note in
  ROADMAP.md for the bubble accounting).

The allocator is pure host bookkeeping (payloads are opaque to it — the
engine hands it numpy page blobs); all device data movement goes through
``models.model.swap_out_pages`` / ``swap_in_pages``.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Hashable

import numpy as np

from repro import _sanitize


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class PageAllocator:
    """Free-list allocator over ``num_pages`` pages; page 0 is reserved.

    Pages carry a *slot* refcount for prefix sharing: ``alloc`` hands a page
    out with refcount 1 (sole owner — the legacy contract), ``incref`` adds a
    sharer, ``decref`` drops one.  A refcount of 0 means "allocated but
    unreferenced" — a cached prefix page parked in the index, reclaimable via
    ``free`` — NOT free-list membership; ``decref`` never auto-frees.  The
    double-free guard extends to the decref path: ``free`` accepts refcounts
    of 0 (idle cached page) or 1 (sole owner) but raises if any sharer
    remains, so releasing a slot can never free a page another slot still
    reads.
    """

    num_pages: int

    def __post_init__(self):
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._free_set = set(self._free)
        self._refs: dict[int, int] = {}
        san = _sanitize.load()
        if san is not None:
            san.attach_page_shadow(self)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free):
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        for p in out:
            self._refs[p] = 1
        return out

    def free(self, pids: list[int]) -> None:
        seen: set[int] = set()
        for p in pids:  # validate the whole batch before applying any of it
            if p == 0:
                raise ValueError("page 0 is the reserved null page")
            if p in self._free_set or p in seen or not 0 < p < self.num_pages:
                # a double-freed id would be handed out to two slots and
                # silently corrupt both KV streams
                raise ValueError(f"page {p} freed twice (or never allocated)")
            if self._refs.get(p, 0) > 1:
                # the refcount extension of the same guard: a shared page
                # freed out from under its other readers corrupts them all
                raise ValueError(
                    f"page {p} freed with refcount {self._refs[p]} > 1")
            seen.add(p)
        self._free.extend(pids)
        self._free_set.update(pids)
        for p in pids:
            self._refs.pop(p, None)

    # ------------------------------------------------------- refcounts
    def refcount(self, pid: int) -> int:
        if pid in self._free_set or pid not in self._refs:
            raise ValueError(f"page {pid} is not allocated")
        return self._refs[pid]

    def incref(self, pid: int) -> int:
        """Add a sharer to an allocated page; returns the new refcount."""
        self._refs[pid] = self.refcount(pid) + 1
        return self._refs[pid]

    def decref(self, pid: int) -> int:
        """Drop one sharer; returns the new refcount.  At 0 the page stays
        allocated (an idle cached prefix page) until explicitly freed."""
        n = self.refcount(pid)
        if n <= 0:
            raise ValueError(f"page {pid} decref below zero")
        self._refs[pid] = n - 1
        return self._refs[pid]


PageKey = Hashable  # engine uses (slot, page_idx)


class TieredPageAllocator:
    """Two-tier page store: hot device pool + cold flash tier.

    Residency bookkeeping only — the engine performs the device gather /
    scatter and hands page payloads (opaque host blobs) in and out:

    * ``mark_evictable(key, pid)`` — a hot page becomes an eviction candidate
      (call in LRU order: oldest page of the least-recently-suspended slot
      first, tail pages last).
    * ``pop_evictable(n, exclude)`` — up to ``n`` LRU candidates to spill.
    * ``store(key, payload)`` / ``fetch(key)`` — the cold store proper.
    * ``cold_keys(match)`` — cold pages of one slot, for prefetch before its
      next decode step.

    ``flash_pages`` bounds the cold tier (None = the NAND dies dwarf the KV
    working set, the paper's regime).
    """

    def __init__(self, num_pages: int, flash_pages: int | None = None):
        self.hot = PageAllocator(num_pages)
        self.flash_pages = flash_pages
        self._cold: dict[PageKey, object] = {}
        self._evictable: OrderedDict[PageKey, int] = OrderedDict()
        san = _sanitize.load()
        if san is not None:
            san.attach_tier_shadow(self)

    # -------------------------------------------------------- hot pool
    @property
    def available(self) -> int:
        return self.hot.available

    def alloc(self, n: int = 1) -> list[int]:
        return self.hot.alloc(n)

    def free(self, pids: list[int]) -> None:
        self.hot.free(pids)

    def refcount(self, pid: int) -> int:
        return self.hot.refcount(pid)

    def incref(self, pid: int) -> int:
        return self.hot.incref(pid)

    def decref(self, pid: int) -> int:
        return self.hot.decref(pid)

    # -------------------------------------------------------- residency
    @property
    def cold_count(self) -> int:
        return len(self._cold)

    @property
    def flash_available(self) -> int | None:
        """Free cold-tier pages (None = unbounded)."""
        if self.flash_pages is None:
            return None
        return self.flash_pages - len(self._cold)

    @property
    def evictable_count(self) -> int:
        return len(self._evictable)

    def mark_evictable(self, key: PageKey, pid: int) -> None:
        if key in self._evictable or key in self._cold:
            raise ValueError(f"page {key!r} already evictable/cold")
        self._evictable[key] = pid

    def pop_evictable(self, n: int,
                      exclude=None) -> list[tuple[PageKey, int]]:
        """Up to ``n`` oldest candidates ``(key, hot pid)``, removed from the
        queue; the caller must spill each one (``store``) and free its pid.
        ``exclude(key) -> bool`` shields a slot's own pages (used when making
        room to prefetch that very slot)."""
        out = []
        for key in list(self._evictable):
            if len(out) >= n:
                break
            if exclude is not None and exclude(key):
                continue
            out.append((key, self._evictable.pop(key)))
        return out

    # -------------------------------------------------------- cold store
    def store(self, key: PageKey, payload) -> None:
        if key in self._cold:
            raise ValueError(f"page {key!r} already cold")
        if (self.flash_pages is not None
                and len(self._cold) >= self.flash_pages):
            raise OutOfPages(f"flash tier full ({self.flash_pages} pages)")
        self._cold[key] = payload

    def fetch(self, key: PageKey):
        """Pop one cold page's payload (the engine scatters it back into a
        freshly allocated hot page and remaps the block table)."""
        return self._cold.pop(key)

    def peek(self, key: PageKey):
        """Read one cold page's payload WITHOUT removing it — the
        non-destructive snapshot path (periodic fleet checkpoints must
        leave the tier intact while the slot keeps running)."""
        return self._cold[key]

    def cold_keys(self, match) -> list[PageKey]:
        """Cold pages with ``match(key)`` true, in insertion (spill) order."""
        return [k for k in self._cold if match(k)]

    def unmark_slot(self, match) -> None:
        """Withdraw a resumed slot's remaining eviction candidates (every
        page of a decoding slot must stay hot until its next suspension)."""
        for k in [k for k in self._evictable if match(k)]:
            del self._evictable[k]

    def drop_slot(self, match) -> None:
        """Forget every page of a finished slot (cold payloads and eviction
        candidates; the engine frees the hot pids itself)."""
        for k in [k for k in self._cold if match(k)]:
            del self._cold[k]
        for k in [k for k in self._evictable if match(k)]:
            del self._evictable[k]


_CHAIN_SEED = b"\x00" * 32


def _chain(prev: bytes, span: np.ndarray) -> bytes:
    h = hashlib.sha256(prev)
    h.update(span.tobytes())
    return h.digest()


@dataclasses.dataclass
class PageEntry:
    """One cached full page of prefix KV: chain key -> physical residency.

    ``pid`` is the hot page id (meaningless while ``cold``); the pid's
    allocator refcount counts the *slots* currently mapping this entry, so
    refcount 0 == idle (reclaimable / spillable) and the entry itself holds
    no reference.
    """

    key: bytes
    pid: int
    cold: bool = False


@dataclasses.dataclass
class ResumeEntry:
    """Exact-prompt resume point: everything needed to admit an identical
    prompt with ZERO prefill dispatches — the shared full pages (by chain
    key, lazily validated at hit time), a private copy of the partial tail
    page, the prefill's final-position logits (sampling replays from these
    bits, so the first token is bit-identical for any sampling params), and
    the post-prefill recurrent state for stateful (hybrid) families."""

    page_keys: list[bytes]
    tail: object          # gathered tail-page payload, or None if aligned
    tail_len: int         # prompt tokens in the tail page (0 = page-aligned)
    logits: np.ndarray    # [vocab] last-row prefill logits, native dtype
    length: int           # cache length after prefill (prompt + extras)
    ssm: object = None    # checkpoint_slot_state payload (hybrid), or None


class PrefixIndex:
    """Content-addressed index over prefix KV pages of ONE engine's pool.

    Keys are a sha256 rolling hash over page-aligned token spans:
    ``key_j = sha256(key_{j-1} || tokens[j*P:(j+1)*P])`` — so a page's key
    commits to the whole prefix behind it and equal keys imply bit-identical
    page contents (prefill is deterministic and position-wise independent of
    bucketing/chunking, the contract ``tests/test_chunked_prefill.py`` pins).

    Only PREFILL-written pages are ever registered.  Decode-written KV may
    differ bitwise from a prefill of the same tokens (prefill/decode numerics
    are only guaranteed to agree on the flash tier — see the requeue caveat
    in ``serving/core.py``), so registering decode output would silently
    break the warm-vs-cold bit-identity oracle on reuse.

    The index holds NO page references itself: an entry whose pid refcount
    is 0 sits on the idle LRU, reclaimable (engine frees the pid, drops the
    entry) or — under a tiered allocator — spillable to flash under the
    ``("px", key)`` cold key and prefetched back on the next hit.  Resume
    entries are capped by ``resume_cap`` (LRU) and die lazily when any page
    entry they cite disappears.
    """

    def __init__(self, page_size: int, resume_cap: int = 512):
        self.page_size = page_size
        self.resume_cap = resume_cap
        self._pages: dict[bytes, PageEntry] = {}
        self._idle: OrderedDict[bytes, None] = OrderedDict()
        self._resume: OrderedDict[bytes, ResumeEntry] = OrderedDict()

    # ------------------------------------------------------------ hashing
    def page_keys(self, tokens) -> list[bytes]:
        """Chain keys of every FULL page span of ``tokens``."""
        arr = np.asarray(tokens, np.int64)
        ps = self.page_size
        keys, prev = [], _CHAIN_SEED
        for j in range(len(arr) // ps):
            prev = _chain(prev, arr[j * ps:(j + 1) * ps])
            keys.append(prev)
        return keys

    def resume_key(self, tokens) -> bytes:
        """Whole-prompt key: the page chain extended over the tail span plus
        a domain marker (so an aligned prompt's resume key never collides
        with a page key)."""
        arr = np.asarray(tokens, np.int64)
        ps = self.page_size
        keys = self.page_keys(arr)
        prev = keys[-1] if keys else _CHAIN_SEED
        h = hashlib.sha256(prev)
        h.update(arr[(len(arr) // ps) * ps:].tobytes())
        h.update(b"resume")
        return h.digest()

    # ------------------------------------------------------- page entries
    def __len__(self) -> int:
        return len(self._pages)

    @property
    def n_idle(self) -> int:
        return len(self._idle)

    @property
    def n_idle_hot(self) -> int:
        return sum(1 for k in self._idle if not self._pages[k].cold)

    def get(self, key: bytes) -> PageEntry | None:
        return self._pages.get(key)

    def match(self, keys: list[bytes]) -> int:
        """Longest cached prefix: count of LEADING keys present."""
        n = 0
        for k in keys:
            if k not in self._pages:
                break
            n += 1
        return n

    def insert(self, key: bytes, pid: int) -> None:
        """Register a prefill-written hot page (the registering slot already
        holds the pid's single reference)."""
        if key in self._pages:
            raise ValueError("prefix page already registered")
        self._pages[key] = PageEntry(key, pid)

    def park(self, key: bytes) -> None:
        """Entry's refcount hit 0: append to the idle LRU."""
        self._idle[key] = None

    def unpark(self, key: bytes) -> None:
        """Entry acquired again (refcount 0 -> 1)."""
        self._idle.pop(key, None)

    def pop_idle_hot(self, n: int) -> list[tuple[bytes, int]]:
        """Remove up to ``n`` LRU idle HOT entries from the index entirely,
        returning ``(key, pid)`` for the engine to free."""
        out = []
        for key in list(self._idle):
            if len(out) >= n:
                break
            ent = self._pages[key]
            if ent.cold:
                continue
            del self._idle[key]
            del self._pages[key]
            out.append((key, ent.pid))
        return out

    def cold_idle_keys(self, n: int) -> list[bytes]:
        """Up to ``n`` cold entries' keys, LRU order.  Cold prefix pages are
        always idle (a slot acquiring one prefetches it hot first)."""
        out = []
        for key in self._idle:
            if len(out) >= n:
                break
            if self._pages[key].cold:
                out.append(key)
        return out

    def mark_cold(self, key: bytes) -> None:
        ent = self._pages[key]
        ent.cold, ent.pid = True, 0

    def mark_hot(self, key: bytes, pid: int) -> None:
        ent = self._pages[key]
        ent.cold, ent.pid = False, pid
        self._idle.pop(key, None)

    def drop(self, key: bytes) -> None:
        self._idle.pop(key, None)
        del self._pages[key]

    # ------------------------------------------------------ resume entries
    @property
    def n_resume(self) -> int:
        return len(self._resume)

    def put_resume(self, rkey: bytes, entry: ResumeEntry) -> None:
        self._resume[rkey] = entry
        self._resume.move_to_end(rkey)
        while len(self._resume) > self.resume_cap:
            self._resume.popitem(last=False)

    def get_resume(self, rkey: bytes) -> ResumeEntry | None:
        ent = self._resume.get(rkey)
        if ent is not None:
            self._resume.move_to_end(rkey)
        return ent

    def peek_resume(self, rkey: bytes) -> ResumeEntry | None:
        """LRU-neutral lookup (router scoring must not perturb eviction)."""
        return self._resume.get(rkey)

    def drop_resume(self, rkey: bytes) -> None:
        self._resume.pop(rkey, None)

    def clear_resume(self) -> None:
        self._resume.clear()


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def kv_page_elems(cfg, page_size: int) -> int:
    """Elements one KV page holds across ALL its layer-stacked pools — the
    single source of truth for per-family page-byte accounting (the engine's
    ``kv_page_bytes`` and the simulator's tier pricing both derive from it).

    * dense/vlm/moe: K + V rows, every layer — 2 * L * page * Hkv * Dh.
    * mla_moe: the page carries COMPRESSED [page, d_ckv + d_krope] rows
      (ckv + krope pools), every layer — spilled bytes shrink with the
      cache, which is what makes flash-resident KV cheapest per token here.
    * hybrid: only the shared-attention applications carry KV — 2 *
      (L // shared_attn_every) * page * Hkv * Dh; the Mamba state never
      pages (it lives in the slot-indexed state pool).
    """
    f = cfg.family
    if f == "mla_moe":
        return cfg.n_layers * page_size * (cfg.kv_lora_rank + cfg.qk_rope_dim)
    if f == "hybrid":
        n_groups = cfg.n_layers // cfg.shared_attn_every
        return 2 * n_groups * page_size * cfg.n_kv_heads * cfg.d_head
    if f in ("dense", "vlm", "moe"):
        return 2 * cfg.n_layers * page_size * cfg.n_kv_heads * cfg.d_head
    raise ValueError(f"family {f!r} has no paged KV cache")


def kv_page_scale_elems(cfg, page_size: int) -> int:
    """f32 scale elements one int8 KV page carries next to its payload —
    one symmetric scale per page row per head (GQA pools) or per compressed
    row (MLA's ckv + krope), i.e. the pool shapes minus their last axis.
    ``models.model.kv_page_bytes`` prices an int8 page as
    ``kv_page_elems * 1 + kv_page_scale_elems * 4``."""
    f = cfg.family
    if f == "mla_moe":
        return 2 * cfg.n_layers * page_size
    if f == "hybrid":
        n_groups = cfg.n_layers // cfg.shared_attn_every
        return 2 * n_groups * page_size * cfg.n_kv_heads
    if f in ("dense", "vlm", "moe"):
        return 2 * cfg.n_layers * page_size * cfg.n_kv_heads
    raise ValueError(f"family {f!r} has no paged KV cache")


def chunk_spans(n_tokens: int, budget: int) -> list[tuple[int, int]]:
    """Reference chunked-prefill schedule for a FIXED budget: ``(start,
    length)`` spans of at most ``budget`` tokens tiling the prompt.  The
    engine derives each span live instead (the budget is a per-step policy
    decision, free to adapt); this helper is the oracle the bit-identity
    tests walk — ``models.model.prefill_chunk_into_slot`` guarantees the
    same logits for EVERY split, so any schedule is a pure pacing choice."""
    if budget <= 0:
        raise ValueError(f"chunk budget must be positive, got {budget}")
    return [(s, min(budget, n_tokens - s))
            for s in range(0, n_tokens, budget)]


def prefill_bucket(n_tokens: int, floor: int = 8) -> int:
    """Pad single-slot prefill lengths to power-of-two buckets so the jitted
    prefill retraces O(log max_seq) times instead of once per prompt length."""
    b = floor
    while b < n_tokens:
        b *= 2
    return b
