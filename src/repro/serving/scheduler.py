"""Pluggable request-scheduling policies for the serving engine.

The paper's single-batch, bandwidth-starved regime makes *which* request
gets the NPU's scarce pages and FLOPs the first-order serving decision: the
hot KV pool is sized below demand (the flash tier absorbs the overflow, see
``serving/kv_cache.py``), so admission order, preemption choice, and
prefill pacing decide every request's TTFT.  This module is the policy
layer the engine consults at each of those three seams — the on-device
serving surveys (On-Device Language Models, arXiv 2409.00088; Network Edge
Inference for LLMs) both single out request scheduling and latency-SLO
policy as the lever that turns a fast kernel stack into a usable
multi-user edge service.

The :class:`Scheduler` protocol has three decision points:

* ``admit(queue, slots, free_pages) -> AdmitPlan`` — which queued requests
  enter free slots this step, in what order, and whether a running slot
  should be preempted to make room (the plan's ``preempt`` list).
* ``victim(slots) -> int`` — which active slot gives up its pages when the
  hot pool runs dry (the engine suspends it and spills its pages to the
  flash tier).  This is deliberately the same seam a multi-host page
  migration will use to pick which slot moves to a hot spare.
* ``prefill_budget(slot) -> int`` — how many prompt tokens a slot may
  prefill per engine step (chunked prefill): long prompts are split into
  fixed token-budget chunks interleaved with decode steps, so they never
  stall active decode slots.  Logit math is bit-identical to one-shot
  prefill (``models.model.prefill_chunk_into_slot``).

Shipped policies, each mapped to its motivation in the edge-serving
setting:

* :class:`FCFSScheduler` — arrival order; the baseline the paper's
  single-user scenario implies, and the fairest under homogeneous load.
* :class:`PriorityScheduler` — strict priorities with preemption: an
  interactive (high-priority) request arriving at a full batch evicts the
  lowest-priority slot via ``victim()`` instead of queueing behind it —
  the latency-SLO policy of the edge surveys.  Priority inversion is
  pinned by tests/test_scheduler.py.
* :class:`SJFScheduler` — shortest estimated service (prompt + remaining
  decode tokens) first: minimizes mean latency when the NPU is the
  bottleneck, at the cost of long-job starvation under sustained load.
* :class:`DRRScheduler` — deficit round robin across priority classes
  (the flow id is ``Request.priority``): each class earns a token quantum
  per serviced round and admits its FCFS head while the deficit covers the
  head's estimated cost, so no class is starved and bandwidth splits
  proportionally — the classic fair-queueing answer to SJF's starvation.
* :class:`EDFScheduler` — earliest deadline first over ``Request.deadline_s``
  (the SLO budget from arrival): admission by absolute deadline, and the
  pool-pressure ``victim()`` suspends the SLACKEST slot, so urgent requests
  keep both their slot and their pages.  ``bench_serving --trace policy``
  reports each policy's deadline-miss rate.

Policies are host-side control flow only — they never touch device state,
so swapping one in changes *which* jitted calls run, never their traces.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling contract (``serving.sampler.sample_batch``).

    ``temperature <= 0`` is greedy (the default); ``seed`` pins the
    request's sample stream — the key for output index i is
    ``fold_in(PRNGKey(seed), i)``, so a preempt-restart regenerates
    exactly the same continuation.  ``seed=None`` falls back to the
    request id.
    """

    temperature: float = 0.0
    top_k: int = 0        # 0 = disabled
    top_p: float = 1.0    # 1.0 = disabled
    seed: int | None = None


@dataclasses.dataclass(frozen=True)
class SlotView:
    """Immutable snapshot of one engine slot, handed to policy decisions."""

    index: int
    rid: int
    priority: int
    arrival_s: float
    seq_len: int        # current cache length (pages ~ seq_len / page_size)
    n_out: int          # tokens emitted so far
    remaining: int      # max_new_tokens - n_out
    prefilling: bool    # still mid chunked-prefill
    suspended: bool     # pages (partially) spilled to the flash tier
    # ABSOLUTE deadline (arrival_s + Request.deadline_s, same clock as
    # arrival_s); None = no SLO — EDF treats it as infinitely slack
    deadline_s: float | None = None


@dataclasses.dataclass
class AdmitPlan:
    """One admission round's decisions.

    ``order``: queued requests to admit now, in priority order — the engine
    admits a prefix of it (as many as free slots and pages allow; the rest
    keep their queue spot).  ``preempt``: slot indices to preempt-restart
    FIRST (their requests fold generated tokens into the prompt and
    requeue), freeing slots for the head of ``order``.
    """

    order: list = dataclasses.field(default_factory=list)
    preempt: list = dataclasses.field(default_factory=list)


def _service_cost(req) -> int:
    """Estimated whole-lifetime service demand, in tokens."""
    return len(req.prompt) + req.max_new_tokens


_NO_BUDGET = 1 << 30  # "no chunking": any prompt prefills in one shot


class Scheduler:
    """Policy protocol + FCFS defaults.

    ``chunk_tokens`` (all policies): per-step chunked-prefill token budget;
    ``None`` disables chunking (prompts prefill in one shot).
    """

    name = "fcfs"

    def __init__(self, chunk_tokens: int | None = None):
        self.chunk_tokens = chunk_tokens

    # -- admission ---------------------------------------------------------
    def admit(self, queue: list, slots: list, free_pages: int) -> AdmitPlan:
        """queue: waiting Requests (engine order); slots: SlotView | None
        per engine slot; free_pages: hot pages currently allocatable."""
        return AdmitPlan(order=list(queue))

    # -- preemption --------------------------------------------------------
    def victim(self, slots: list) -> int:
        """Pick the slot that gives up its pages under pool pressure.
        Default: the longest sequence — it frees the most pages at once."""
        return max(slots, key=lambda s: s.seq_len).index

    # -- prefill pacing ----------------------------------------------------
    def prefill_budget(self, slot) -> int:
        """Prompt tokens this slot may prefill this engine step."""
        # reprolint: ok boolean-select-trap — 0 and None both mean "no chunking" (chunk_spans rejects budget <= 0)
        return self.chunk_tokens or _NO_BUDGET


class FCFSScheduler(Scheduler):
    """First come, first served — the engine's historical inline policy."""

    name = "fcfs"


class PriorityScheduler(Scheduler):
    """Strict priorities (higher ``Request.priority`` wins), preemptive.

    Admission sorts by (priority desc, arrival, rid).  When the batch is
    full and the queue head outranks the lowest-priority running slot, the
    plan preempt-restarts that slot (at most one per step, so preemption
    pressure stays bounded); under ``kv_tier="flash"`` pool pressure the
    ``victim()`` seam also evicts lowest-priority first, so a high-priority
    arrival is never stalled behind a low-priority slot's pages.
    """

    name = "priority"

    def __init__(self, chunk_tokens: int | None = None,
                 preemptive: bool = True):
        super().__init__(chunk_tokens)
        self.preemptive = preemptive

    @staticmethod
    def _key(req):
        return (-req.priority, req.arrival_s, req.rid)

    def admit(self, queue, slots, free_pages):
        order = sorted(queue, key=self._key)
        preempt: list[int] = []
        if (self.preemptive and order
                and not any(s is None for s in slots)):
            cands = [s for s in slots if s is not None and not s.suspended]
            if cands:
                worst = min(cands, key=lambda s: (s.priority, -s.seq_len))
                if order[0].priority > worst.priority:
                    preempt = [worst.index]
        return AdmitPlan(order=order, preempt=preempt)

    def victim(self, slots):
        return min(slots, key=lambda s: (s.priority, -s.seq_len)).index


class SJFScheduler(Scheduler):
    """Shortest estimated job first (prompt + max_new tokens).

    Minimizes mean latency/TTFT under backlog; long jobs can starve — pair
    with DRR when that matters.  Pool-pressure victims stay the default
    (longest sequence): evicting the biggest footprint frees the most
    pages per suspended request.
    """

    name = "sjf"

    def admit(self, queue, slots, free_pages):
        return AdmitPlan(order=sorted(
            queue, key=lambda r: (_service_cost(r), r.arrival_s, r.rid)))


class DRRScheduler(Scheduler):
    """Deficit round robin across priority classes (flow id =
    ``Request.priority``).

    Every admission round with at least one free slot, the class under the
    round-robin pointer earns ``quantum`` deficit tokens and admits its
    FCFS head while the deficit covers the head's estimated service cost
    (prompt + max_new tokens); unspent deficit carries while the class is
    backlogged and resets when it empties (standard DRR).  Classes with
    cheap requests therefore admit more of them per round — token
    bandwidth, not request count, is what's shared fairly.
    """

    name = "drr"

    def __init__(self, quantum: int = 64, chunk_tokens: int | None = None):
        super().__init__(chunk_tokens)
        self.quantum = quantum
        self._deficit: dict[int, int] = {}
        self._ring: list[int] = []  # round-robin order of backlogged flows
        self._ptr = 0
        # (flow, cost, req) charged last round — refunded if the engine
        # could not actually admit the request (it is still in the queue).
        # Holding the request itself (not just its id) makes the identity
        # check safe against id reuse after garbage collection.
        self._charged: list[tuple[int, int, object]] = []

    def admit(self, queue, slots, free_pages):
        # a plan entry the engine failed to admit (OutOfPages) reappears in
        # the queue: refund its cost so the flow is not charged twice for
        # service it never received.  Settled on the VERY NEXT call — even
        # one that early-returns — so an admitted request that re-enters
        # the queue much later via preempt-restart is never mistaken for a
        # failed admission.
        qids = {id(r) for r in queue}
        for f, cost, req in self._charged:
            if id(req) in qids:
                self._deficit[f] = self._deficit.get(f, 0) + cost
        self._charged = []
        n_free = sum(1 for s in slots if s is None)
        if not queue or n_free == 0:
            return AdmitPlan()
        flows: dict[int, list] = {}
        for r in queue:
            flows.setdefault(r.priority, []).append(r)
        for fl in flows.values():
            fl.sort(key=lambda r: (r.arrival_s, r.rid))
        for f in sorted(flows):
            if f not in self._ring:
                self._ring.append(f)
        self._ring = [f for f in self._ring if f in flows]
        for f in [f for f in self._deficit if f not in flows]:
            del self._deficit[f]  # emptied flow: deficit resets
        want = min(len(queue), n_free)
        order: list = []
        while len(order) < want and self._ring:
            self._ptr %= len(self._ring)
            f = self._ring[self._ptr]
            self._deficit[f] = self._deficit.get(f, 0) + self.quantum
            fl = flows[f]
            while (fl and len(order) < want
                   and self._deficit[f] >= _service_cost(fl[0])):
                r = fl.pop(0)
                self._deficit[f] -= _service_cost(r)
                self._charged.append((f, _service_cost(r), r))
                order.append(r)
            if not fl:
                del flows[f]
                self._deficit.pop(f, None)
                self._ring.remove(f)  # ptr now points at the next flow
            else:
                self._ptr += 1
        return AdmitPlan(order=order)


_NO_DEADLINE = float("inf")


def _abs_deadline(req) -> float:
    """Absolute deadline on the arrival clock (inf = no SLO)."""
    if req.deadline_s is None:
        return _NO_DEADLINE
    arrival = 0.0 if req.arrival_s is None else req.arrival_s
    return arrival + req.deadline_s


class EDFScheduler(Scheduler):
    """Earliest deadline first — the SLO policy (``Request.deadline_s`` is
    the latency budget in seconds from arrival).

    Admission orders by absolute deadline (``arrival_s + deadline_s``;
    requests without one sort last, FCFS among themselves), the classic
    optimal single-resource deadline schedule.  The ``victim()`` seam is
    deadline-aware in the opposite direction: under pool pressure the
    SLACKEST slot (latest absolute deadline; no-deadline slots first,
    longest sequence as tie-break) gives up its pages, so an urgent
    request is never the one suspended to make room.
    """

    name = "edf"

    def admit(self, queue, slots, free_pages):
        return AdmitPlan(order=sorted(
            queue, key=lambda r: (_abs_deadline(r), r.arrival_s, r.rid)))

    def victim(self, slots):
        return max(slots, key=lambda s: (
            s.deadline_s if s.deadline_s is not None else _NO_DEADLINE,
            s.seq_len)).index


POLICIES = {c.name: c for c in
            (FCFSScheduler, PriorityScheduler, SJFScheduler, DRRScheduler,
             EDFScheduler)}


def make_scheduler(policy, **kw) -> Scheduler:
    """Build a scheduler from a policy name (or pass an instance through)."""
    if isinstance(policy, Scheduler):
        return policy
    if policy is None:
        return FCFSScheduler(**kw)
    try:
        return POLICIES[policy](**kw)
    except KeyError:
        raise ValueError(
            f"unknown policy {policy!r}; pick from {sorted(POLICIES)}")
