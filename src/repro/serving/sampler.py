"""Token samplers (greedy / temperature / top-k / top-p).

``greedy`` and ``sample`` apply one global setting to the whole batch;
``sample_batch`` is the serving path — it honors per-request
``SamplingParams`` (temperature / top-k / top-p / seed) row by row in one
vectorized call, so mixed greedy + stochastic slots share a single jitted
dispatch per decode step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_batch(logits: jax.Array, seeds: jax.Array, counts: jax.Array,
                 temperature: jax.Array, top_k: jax.Array,
                 top_p: jax.Array) -> jax.Array:
    """Per-row sampling honoring per-request ``SamplingParams``.

    logits: [B, V]; seeds / counts: int32 [B]; temperature: f32 [B] (<= 0 is
    greedy); top_k: int32 [B] (0 = disabled); top_p: f32 [B] (1.0 =
    disabled).  The key for row b is ``fold_in(PRNGKey(seeds[b]),
    counts[b])`` — deterministic per (request seed, output index), so a
    preempted request restarted with its prefix folded into the prompt
    regenerates exactly the same continuation (the requeue path's
    correctness contract, same as greedy).
    """
    b, v = logits.shape
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)[:, None]
    order = jnp.argsort(-lg, axis=-1)
    # rank of each vocab id (0 = best) by inverting the sort permutation
    # with a scatter — O(BV) instead of a second O(BV log V) argsort on the
    # per-decode-step hot path
    ranks = jnp.zeros_like(order).at[
        jnp.arange(b)[:, None], order].set(jnp.arange(v)[None, :])
    keff = jnp.where(top_k > 0, top_k, v)[:, None]
    lg = jnp.where(ranks < keff, lg, NEG_INF)
    sorted_lg = jnp.take_along_axis(lg, order, axis=-1)
    probs = jax.nn.softmax(sorted_lg, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.sum(cum < top_p[:, None], axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_lg, cutoff_idx, axis=-1)
    lg = jnp.where(lg < cutoff, NEG_INF, lg)
    keys = jax.vmap(
        lambda s, c: jax.random.fold_in(jax.random.PRNGKey(s), c)
    )(seeds, counts)
    sampled = jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy_tok)


def fused_sample(logits: jax.Array, seeds: jax.Array, counts: jax.Array,
                 temperature: jax.Array, top_k: jax.Array, top_p: jax.Array,
                 greedy_only: bool = False) -> jax.Array:
    """``sample_batch`` shaped for fusion into a jitted decode step.

    ``greedy_only`` is a STATIC flag (the engine knows host-side whether any
    batch row is stochastic): all-greedy batches trace a bare argmax instead
    of dragging the sort/top-k/top-p machinery into every decode dispatch.
    Greedy rows of a mixed batch still argmax inside ``sample_batch``, so
    both traces agree bit-for-bit on greedy rows.
    """
    if greedy_only:
        return greedy(logits)
    return sample_batch(logits, seeds, counts, temperature, top_k, top_p)


def sample(logits: jax.Array, key: jax.Array, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """logits: [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
