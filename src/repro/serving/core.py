"""EngineCore: the per-replica synchronous serving loop.

This is the bottom layer of the three-tier serving API::

    ServingClient (serving/client.py)   user-facing handles + global ids
        │ submit / stream / abort
    Router        (serving/router.py)   N replicas, routing policy,
        │                               cross-replica slot migration
    EngineCore    (this module)         ONE replica: slots, paged/tiered KV,
                                        chunked prefill, scheduler calls

The core owns a fixed-size slot table (the batch).  Requests enter a
queue, claim free slots, prefill (in one shot or in chunks), and decode
step-by-step; finished slots free immediately.  WHICH queued request claims
a slot, WHICH slot gives up its pages under pool pressure, and HOW MANY
prompt tokens a slot may prefill per step are policy decisions delegated to
a :class:`repro.serving.scheduler.Scheduler` (FCFS / priority / SJF / DRR /
EDF; ``scheduler=`` in the constructor).  The core enforces feasibility —
free slots, free pages, exhaust policy — the scheduler decides order.

Command surface (what the router drives — deliberately narrow, so a future
cross-host deployment can put it behind an RPC boundary):

* ``add_request(req)`` — enqueue; ``abort_request(rid)`` — cancel queued or
  running, emitting exactly one terminal ``finish_reason="aborted"`` event.
* ``step() -> list[RequestOutput]`` — one admit+decode round; returns the
  events it produced.  (The legacy bool-returning loop survives as
  ``_advance`` / the ``ServingEngine`` shim in ``serving/engine.py``.)
* ``snapshot_slot(rid) -> SlotSnapshot`` / ``inject_slot(snap)`` — drain a
  slot's entire serving state (request, KV page payloads, SSM checkpoint,
  sampler cursor) into host arrays and resume it on ANOTHER core,
  bit-identical.  This packages the existing tiered-KV seam
  (``swap_out_pages`` / ``swap_in_pages`` / ``checkpoint_slot_state``) into
  the wire format a cross-replica — and eventually cross-host — slot move
  ships.
* load introspection for routing: ``free_pages`` / ``queue_depth`` /
  ``n_active`` / ``n_free_slots`` / ``has_work`` / ``page_starved`` /
  ``migration_candidate()``.

Two admission modes:

* ``continuous`` (default where the family supports it) — the paged per-slot
  KV cache (block table into a shared page pool + per-slot length vector)
  lets a new request prefill into ANY free slot while the other slots keep
  decoding: single-slot prefill-into-cache, per-slot masked decode
  attention, page free on completion.  Covers dense/vlm/moe (full K/V
  pages), mla_moe (compressed ckv+krope pages), and hybrid (shared-attn KV
  pages + a slot-indexed Mamba state pool whose lanes are masked by
  ``active`` and checkpointed/restored across preempt-resume).
* ``wave`` — the legacy shared-cursor cache: one length cursor for the whole
  batch, so new requests only start when the batch drains.  Kept for the
  pure-SSM and encoder-decoder families and as the benchmark baseline.

Chunked prefill (``scheduler.chunk_tokens``): a prompt longer than the
policy's per-step budget is admitted into a slot and prefilled in
fixed-budget chunks, one chunk per engine step, interleaved with the decode
steps of the other slots — a long prompt never stalls active decode.  The
chunk math reads every key from the gathered block row exactly as decode
does, so logits are bit-identical to one-shot prefill regardless of the
chunk schedule (``models.model.prefill_chunk_into_slot``; pinned by
tests/test_chunked_prefill.py).

Streaming output contract: every emitted token appends a
:class:`RequestOutput` event (token id, per-request progress, finish reason
and scheduler stats on the final event).  Consume ``step()``'s return, or
``for out in core.stream(): ...``, or drain explicitly via
``drain_outputs()``; ``run()`` still returns aggregate ``EngineStats``.
Per-request sampling honors ``Request.sampling``
(:class:`repro.serving.scheduler.SamplingParams`): temperature / top-k /
top-p rows are sampled in one vectorized call with seed-pinned keys
(``fold_in(PRNGKey(seed), output_index)``), greedy rows stay bit-identical
to the historical global-greedy path.  Seed-pinning is also what makes a
migrated slot's stochastic continuation bit-identical: the key depends only
on (seed, output index), never on which replica or slot samples it.

Tiered KV (``kv_tier="flash"``): the hot page pool may be sized BELOW total
demand (``num_pages``); when it runs out the core preempts-by-eviction —
it suspends a victim slot (chosen by ``scheduler.victim``), spills its LRU
pages to the simulated NAND flash tier (host blobs standing in for the
dies), and prefetches them back through the Slice Control channel bubbles
before the slot's next decode step.  Spill and prefetch ride
``models.model.swap_out_pages`` / ``swap_in_pages``; the block table is
remapped to whatever hot pids the pages come back on, so decode math stays
bit-identical to the all-resident run.  The simulated bubble-bandwidth cost
of that traffic is priced by ``sim.llm_perf`` (``kv_swap_overhead_s``) from
the ``kv_spill_bytes`` / ``kv_prefetch_bytes`` counters below.

Pool-exhaustion policy without a flash tier (``exhaust_policy``):
``"requeue"`` (default) puts the starved request back in the queue (a
mid-decode slot restarts later with its generated prefix folded into the
prompt — deterministic continuation: greedy and seed-pinned sampling both
regenerate the same tokens, though near-tie argmaxes can flip where prefill
and decode numerics differ; only the flash tier preserves exact logits);
``"reject"`` fails it, the capacity-constrained baseline the tiered
benchmark compares against.  Both count ``EngineStats.pool_exhausted``
instead of crashing the engine loop.

Prefix caching (``prefix_cache=True``): finished requests leave their
PREFILL-written KV pages behind in a content-addressed
:class:`repro.serving.kv_cache.PrefixIndex` (sha256 rolling hash over
page-aligned token spans), refcounted at the allocator.  Admission matches
the longest cached prefix: an exact-prompt hit replays a stored "resume
point" — shared full pages mapped into the block table (incref), a private
copy-on-write copy of the partial tail page, the prefill's final logits
(sampling replays from the stored bits) and, for hybrid, the post-prefill
SSM checkpoint — admitting with ZERO prefill dispatches; a partial hit
(families with chunked prefill) shares the cached pages and prefills only
the uncached suffix through the chunk path, whose any-schedule bit-identity
contract makes warm output bit-identical to a cold run.  Decode-written
pages are never registered (prefill/decode numerics may differ off the
flash tier — see the requeue caveat above — and registering them would
poison the bit-identity oracle), which also means every write frontier sits
strictly beyond the shared region: the ``_ensure_pages`` COW guard exists
for safety, not for a hot path.  Idle (refcount-0) cached pages are
reclaimed LRU under pool pressure, or — under ``kv_tier="flash"`` — spilled
to the cold tier under ``("px", chain_key)`` and prefetched back on the
next hit.  Migration snapshots carry each shared page's chain key so inject
re-shares against the target's index (or re-registers the carried payload).

Overlapped decode (``overlap=True``): the synchronous loop pays two jitted
dispatches and one host sync per decode step (decode, then sample, then
``np.asarray`` on the tokens).  The overlapped loop fuses decode + per-
request sampling into ONE jitted step whose sampled tokens stay on device
and chain straight into the next dispatch (``where(use_dev, tok_dev,
tok_host)``), and reads tokens back one step LATE: step N+1 is dispatched
before step N's tokens are read, so the readback overlaps the compute.
Consequences, all bounded by the single in-flight step: finishes are
detected at the lagged drain (length/capacity are host-predicted one step
ahead and masked out of the next dispatch; an eos'd slot runs one
speculative step whose writes stay behind the lens mask and whose token a
slot-epoch check discards); host mirrors (``slot_len`` / ``last_np`` /
``out_tokens``) trail by the undrained token, so scheduler views are one
step stale; ``snapshot_slot`` drains first so the migration wire format
stays fully materialized.  Token streams are bit-identical to the
synchronous loop for every paged family, greedy and seed-pinned stochastic
(tests/test_overlap.py); incompatible with ``watchdog`` (no retained
pre-step cache to replay).

Fault hooks: per-step heartbeat timestamps; a pluggable ``watchdog`` sees
(step, wall_time) and may trigger re-dispatch — tests inject artificial
stragglers through it.  Re-dispatch replays the step from the retained
pre-step cache, so it is idempotent.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving import sampler
from repro import _sanitize
from repro.serving.kv_cache import (OutOfPages, PageAllocator, PrefixIndex,
                                    ResumeEntry, TieredPageAllocator,
                                    pages_needed, prefill_bucket)
from repro.serving.scheduler import (SamplingParams, Scheduler, SlotView,
                                     make_scheduler)


@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    priority: int = 0          # higher wins under priority/DRR policies
    # trace arrival time (any monotone clock, 0.0 is a valid instant);
    # None -> the engine stamps time.monotonic() at submit
    arrival_s: Optional[float] = None
    # SLO budget in seconds (None = no deadline).  The EDF policy orders
    # admission by arrival_s + deadline_s (relative comparisons, so any
    # shared arrival clock works); ``deadline_missed`` measures the budget
    # from SUBMISSION — identical to from-arrival in live serving (the
    # engine stamps arrival_s at submit) and in trace replay that submits
    # at arrival instants (``bench_serving.drive``)
    deadline_s: Optional[float] = None
    # session id for router affinity (requests of one conversation land on
    # the replica that already holds its context)
    session: Optional[str] = None
    sampling: Optional[SamplingParams] = None  # None -> greedy
    temperature: float = 0.0   # legacy alias, folded into ``sampling``
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False  # failed admission under exhaust_policy="reject"
    # eos | length | capacity | rejected | aborted
    finish_reason: Optional[str] = None
    n_folded: int = 0  # out_tokens already folded into prompt by restarts
    # per-request scheduler stats, surfaced on the final RequestOutput
    n_chunks: int = 0      # chunked-prefill passes run for this request
    n_preempted: int = 0   # restarts + tiered suspensions suffered
    n_migrated: int = 0    # cross-replica slot moves suffered
    # lifecycle timestamps (time.monotonic), filled by the engine
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    def __post_init__(self):
        if self.sampling is None:
            self.sampling = SamplingParams(temperature=self.temperature)

    @property
    def admission_wait_s(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def ttft_s(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def deadline_missed(self) -> bool:
        """True when the request finished more than ``deadline_s`` seconds
        after submission (False without a deadline or before completion).
        See ``deadline_s`` for the submission-vs-arrival clock contract."""
        return (self.deadline_s is not None and self.t_done > 0.0
                and self.latency_s > self.deadline_s)


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """One streamed event of a request's lifetime.

    Token events carry the freshly sampled ``token`` (``n_out`` is the
    cumulative count including it).  The final event has ``finished=True``
    with the ``finish_reason`` and the request's scheduler stats; a
    rejected or aborted request emits exactly one final event with
    ``token=None``.
    """

    rid: int
    token: Optional[int]
    n_out: int
    finished: bool = False
    finish_reason: Optional[str] = None
    # populated on the final event only
    ttft_s: Optional[float] = None
    latency_s: Optional[float] = None
    sched: Optional[dict] = None   # {"chunks", "preemptions", "wait_s"}


@dataclasses.dataclass
class SlotSnapshot:
    """One slot's entire serving state as host arrays — the migration wire
    format.

    ``pages[j]`` is the payload tuple of the slot's j-th allocated page
    exactly as ``swap_out_pages`` gathers it — one numpy array per
    ``paged_pool_keys`` component: ``(k, v)`` for bf16 pools (for MLA the
    compressed ``(ckv, krope)`` rows), and ``(k, v, k_scale, v_scale)``
    under ``kv_dtype="int8"``; ``ssm`` is the
    ``checkpoint_slot_state`` snapshot for families with per-slot recurrent
    state.  Everything here is numpy / plain python — serializing this
    struct across a socket IS the future cross-host slot move; no device
    state leaks into it.
    """

    req: Request
    slot_len: int          # valid cache length (prefill_pos mid-prefill)
    last_token: int        # next decode step's input token
    prefilling: bool       # still mid chunked-prefill
    prefill_pos: int
    pages: list            # [tuple of numpy arrays] per page (pool order)
    ssm: object            # checkpoint_slot_state payload (None if none)
    page_size: int
    family: str
    # prefix-cache chain keys of the slot's SHARED pages ({page_idx: key},
    # None when the source engine has no prefix cache): inject re-shares
    # against the target's index when it already holds the key, or registers
    # the carried payload — new fields go at the end, defaulted, so older
    # snapshots keep deserializing
    prefix_keys: Optional[dict] = None

    @property
    def n_pages(self) -> int:
        return len(self.pages)

    def to_bytes(self) -> bytes:
        """Standalone byte format (versioned header carrying the geometry
        — family, page_size, page dtype — then the encoded fields); the
        fleet transport and the failover checkpoints both speak it."""
        from repro.serving.fleet import wire
        return wire.snapshot_to_bytes(self)

    @classmethod
    def from_bytes(cls, data: bytes, expect_family: str = None,
                   expect_page_size: int = None,
                   expect_dtype: str = None) -> "SlotSnapshot":
        """Inverse of :meth:`to_bytes`.  ``expect_*`` is the geometry
        guard: a receiver that knows its own family / page_size / page
        dtype gets a ``ValueError`` on mismatch before the body decodes."""
        from repro.serving.fleet import wire
        return wire.snapshot_from_bytes(
            data, expect_family=expect_family,
            expect_page_size=expect_page_size, expect_dtype=expect_dtype)


def _batch_extras(cfg: ModelConfig, batch: int) -> dict:
    if cfg.family == "vlm":
        return {"vision_embeds": jnp.zeros(
            (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "audio":
        return {"frames": jnp.zeros(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)}
    return {}


# jitted step functions are shared per-config (ModelConfig is frozen and
# hashable) so every replica of a router — and rebuilt engines, e.g. the
# wave-vs-continuous benchmark — reuses compile caches instead of retracing
@functools.lru_cache(maxsize=None)
def _jit_decode(cfg: ModelConfig):
    return jax.jit(lambda p, t, c: model_lib.decode_step(p, cfg, t, c))


@functools.lru_cache(maxsize=None)
def _jit_decode_paged(cfg: ModelConfig):
    return jax.jit(
        lambda p, t, c, a: model_lib.decode_step_paged(p, cfg, t, c, a))


@functools.lru_cache(maxsize=None)
def _jit_prefill_slots(cfg: ModelConfig):
    return jax.jit(lambda p, toks, tls, c, ss: model_lib.prefill_into_slots(
        p, cfg, toks, tls, c, ss, _batch_extras(cfg, toks.shape[0])))


@functools.lru_cache(maxsize=None)
def _jit_prefill_chunk(cfg: ModelConfig):
    # one trace per chunk-length bucket (power-of-two, floor = page size):
    # start/chunk_len/slot are traced scalars, so the trace count stays
    # O(log max_seq) while per-chunk compute scales with the budget
    return jax.jit(
        lambda p, toks, start, clen, c, slot:
        model_lib.prefill_chunk_into_slot(p, cfg, toks, start, clen, c,
                                          slot))


@functools.lru_cache(maxsize=None)
def _jit_prefill(cfg: ModelConfig):
    return jax.jit(lambda p, toks, c, batch: model_lib.prefill(
        p, cfg, toks, c, _batch_extras(cfg, batch)),
        static_argnames=("batch",))


# swap ops retrace per page-id bucket (power-of-two padded with the null
# page), so the trace count stays O(log pool) like the prefill buckets
_jit_swap_out = jax.jit(model_lib.swap_out_pages)
_jit_swap_in = jax.jit(model_lib.swap_in_pages)
_jit_sample = jax.jit(sampler.sample_batch)


# the overlapped loop's fused decode+sample step: ONE jitted dispatch per
# decode step instead of decode followed by a separate sample dispatch.
# ``greedy_only`` is static (all-greedy batches trace a bare argmax);
# ``donate`` hands the cache buffers to XLA for in-place reuse — requested
# only off-CPU (the CPU backend ignores donation with a warning per call)
@functools.lru_cache(maxsize=None)
def _jit_decode_sample_paged(cfg: ModelConfig, donate: bool):
    def step(p, tok_host, tok_dev, use_dev, c, a, seeds, counts, temps,
             topk, topp, greedy_only):
        return model_lib.decode_and_sample_paged(
            p, cfg, tok_host, tok_dev, use_dev, c, a,
            lambda lg: sampler.fused_sample(
                lg, seeds, counts, temps, topk, topp,
                greedy_only=greedy_only))
    kw = {"donate_argnums": (4,)} if donate else {}
    return jax.jit(step, static_argnames=("greedy_only",), **kw)


@functools.lru_cache(maxsize=None)
def _jit_decode_sample(cfg: ModelConfig, donate: bool):
    def step(p, tok_host, tok_dev, use_dev, c, seeds, counts, temps,
             topk, topp, greedy_only):
        return model_lib.decode_and_sample(
            p, cfg, tok_host, tok_dev, use_dev, c,
            lambda lg: sampler.fused_sample(
                lg, seeds, counts, temps, topk, topp,
                greedy_only=greedy_only))
    kw = {"donate_argnums": (4,)} if donate else {}
    return jax.jit(step, static_argnames=("greedy_only",), **kw)


class _LazyPagePayload:
    """A spilled page's payload still on its way to the host — one array
    per ``paged_pool_keys`` component ((k, v) for bf16 pools, (k, v,
    k_scale, v_scale) under kv_dtype="int8").

    ``copy_to_host_async`` starts the device→host DMA at spill time; the
    numpy materialization happens only when the payload is actually needed
    (prefetch scatter or migration snapshot), so the spill itself never
    blocks the engine loop on a device sync.
    """

    __slots__ = ("arrays",)

    def __init__(self, *arrays):
        self.arrays = arrays
        for a in arrays:
            a.copy_to_host_async()

    def materialize(self) -> tuple[np.ndarray, ...]:
        return tuple(np.asarray(a) for a in self.arrays)


def _payload_np(payload) -> tuple[np.ndarray, ...]:
    if isinstance(payload, _LazyPagePayload):
        return payload.materialize()
    return payload


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    prefill_chunks: int = 0    # chunked-prefill passes (chunk granularity)
    decode_steps: int = 0
    # jitted dispatches attributable to decoding (decode + sample in the
    # synchronous loop = 2 per step; the overlapped fused step = 1).
    # ``decode_dispatches / decode_steps`` is the dispatches-per-decoded-
    # token figure the overlap benchmark reports.
    decode_dispatches: int = 0
    tokens_out: int = 0
    straggler_events: int = 0
    wall_decode_s: float = 0.0
    admitted: int = 0
    completed: int = 0
    mode: str = ""
    policy: str = ""
    # pool pressure / tiered KV accounting
    pool_exhausted: int = 0    # OutOfPages events absorbed (requeue/reject)
    rejected: int = 0
    aborted: int = 0           # abort_request() cancellations
    preemptions: int = 0       # slots suspended (tiered) or restarted
    resumes: int = 0           # suspended slots brought back hot
    migrated_out: int = 0      # slots drained via snapshot_slot
    migrated_in: int = 0       # slots resumed via inject_slot
    kv_spill_pages: int = 0
    kv_prefetch_pages: int = 0
    kv_spill_bytes: float = 0.0
    kv_prefetch_bytes: float = 0.0
    # prefix-cache accounting
    prefix_lookups: int = 0    # admissions that consulted the index
    prefix_hits: int = 0       # admissions served any cached prefix
    prefix_hit_pages: int = 0  # shared pages mapped instead of re-prefilled
    prefix_tokens_reused: int = 0  # prompt tokens whose prefill was skipped
    cow_copies: int = 0        # private copies made of (tail) shared pages
    # fleet health / failover accounting (populated by the FleetRouter's
    # fleet-level stats object; always 0 on a single in-process engine)
    workers_lost: int = 0      # workers declared dead (SIGKILL, hang, EOF)
    failovers: int = 0         # failover passes run (one per lost worker)
    requests_replayed: int = 0  # requests re-dispatched by failover
    tokens_replayed: int = 0   # re-decoded tokens suppressed as duplicates
    heartbeat_misses: int = 0  # reply deadlines blown (straggle signal)
    # per-request latency samples, appended at completion
    admission_wait_s: list = dataclasses.field(default_factory=list)
    ttft_s: list = dataclasses.field(default_factory=list)
    latency_s: list = dataclasses.field(default_factory=list)

    def percentiles(self, series: str = "latency_s",
                    qs: tuple = (50, 90, 99)) -> dict:
        """Per-request latency percentiles, e.g. ``percentiles("ttft_s")``."""
        xs = getattr(self, series)
        return {f"p{q}": float(np.percentile(xs, q)) if xs else 0.0
                for q in qs}

    def summary(self) -> str:
        lat = self.percentiles("latency_s")
        adm = self.percentiles("admission_wait_s")
        s = (f"[{self.mode}] policy={self.policy or 'fcfs'} "
             f"requests={self.completed} "
             f"tokens={self.tokens_out} steps={self.decode_steps} "
             f"latency p50/p90/p99="
             f"{lat['p50']:.3f}/{lat['p90']:.3f}/{lat['p99']:.3f}s "
             f"admission p50/p99={adm['p50']:.3f}/{adm['p99']:.3f}s")
        if self.prefill_chunks:
            s += f" prefill_chunks={self.prefill_chunks}"
        if self.kv_spill_pages or self.pool_exhausted or self.rejected:
            s += (f" pool_exhausted={self.pool_exhausted} "
                  f"rejected={self.rejected} preempt={self.preemptions} "
                  f"spill/prefetch pages={self.kv_spill_pages}"
                  f"/{self.kv_prefetch_pages}")
        if self.migrated_out or self.migrated_in:
            s += (f" migrated out/in={self.migrated_out}"
                  f"/{self.migrated_in}")
        if self.prefix_lookups:
            s += (f" prefix hits={self.prefix_hits}/{self.prefix_lookups}"
                  f" pages={self.prefix_hit_pages}"
                  f" tokens={self.prefix_tokens_reused}"
                  f" cow={self.cow_copies}")
        if self.workers_lost or self.failovers or self.heartbeat_misses:
            s += (f" workers_lost={self.workers_lost} "
                  f"failovers={self.failovers} replayed "
                  f"req/tok={self.requests_replayed}/{self.tokens_replayed} "
                  f"heartbeat_misses={self.heartbeat_misses}")
        return s


class EngineCore:
    """Single-replica engine over the functional model API.

    For the multi-chip case the jitted step functions are the pjit'd ones
    from launch/dryrun.build_step; here the defaults run on local devices.
    Multi-replica serving stacks a :class:`repro.serving.router.Router`
    over N of these.
    """

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 512, eos_id: int = 2,
                 watchdog: Optional[Callable[[int, float], bool]] = None,
                 straggler_timeout_s: float = 5.0, mode: str = "auto",
                 page_size: int = 16, num_pages: Optional[int] = None,
                 kv_tier: str = "none", exhaust_policy: str = "requeue",
                 flash_pages: Optional[int] = None,
                 scheduler: "Scheduler | str | None" = None,
                 overlap: bool = False, prefix_cache: bool = False,
                 kv_dtype: str = "bf16"):
        if overlap and watchdog is not None:
            raise ValueError(
                "overlap=True keeps one decode step in flight past the host "
                "readback, so the watchdog's replay-from-pre-step-cache "
                "re-dispatch contract cannot hold; use the synchronous loop "
                "with a watchdog")
        if mode == "auto":
            mode = ("continuous" if model_lib.supports_paged(cfg) else "wave")
        if mode == "continuous" and not model_lib.supports_paged(cfg):
            raise ValueError(
                f"continuous mode needs a paged KV cache; family "
                f"{cfg.family!r} has recurrent state tied to the shared "
                f"cursor — use mode='wave'")
        if kv_tier not in ("none", "flash"):
            raise ValueError(f"kv_tier {kv_tier!r} not in ('none', 'flash')")
        if kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype {kv_dtype!r} not in ('bf16', 'int8')")
        if kv_dtype == "int8" and mode != "continuous":
            raise ValueError("kv_dtype='int8' needs mode='continuous' (only "
                             "the paged pools quantize per page row)")
        if exhaust_policy not in ("requeue", "reject"):
            raise ValueError(f"exhaust_policy {exhaust_policy!r}")
        if kv_tier == "flash" and mode != "continuous":
            raise ValueError("kv_tier='flash' needs mode='continuous'")
        if prefix_cache and mode != "continuous":
            raise ValueError(
                "prefix_cache=True needs mode='continuous' (the wave cache "
                "has no page pool to share)")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.watchdog = watchdog
        self.straggler_timeout_s = straggler_timeout_s
        self.mode = mode
        self.overlap = overlap
        # overlapped-loop state: at most ONE dispatched-but-undrained fused
        # step; per-slot in-flight token counts (0 or 1) and release epochs
        # that invalidate pending rows whose slot was reassigned in between
        self._pending: Optional[dict] = None
        self._inflight: list[int] = [0] * max_batch
        self._slot_epoch: list[int] = [0] * max_batch
        self.kv_tier = kv_tier
        self.kv_dtype = kv_dtype
        self.exhaust_policy = exhaust_policy
        self.scheduler = make_scheduler(scheduler)
        self.stats = EngineStats(mode=mode, policy=self.scheduler.name)
        self.queue: list[Request] = []
        self.slots: list[Optional[Request]] = [None] * max_batch
        self._events: list[RequestOutput] = []
        self._chunk_ok = (mode == "continuous"
                          and model_lib.supports_chunked_prefill(cfg))
        self._px: Optional[PrefixIndex] = None  # set in the continuous branch
        if mode == "continuous":
            self.page_size = page_size
            self.pages_per_slot = pages_needed(max_seq, page_size)
            full_pool = max_batch * self.pages_per_slot + 1
            self.num_pages = full_pool if num_pages is None else num_pages
            self.cache = model_lib.init_paged_cache(
                cfg, max_batch, max_seq, page_size=page_size,
                num_pages=self.num_pages, kv_dtype=kv_dtype)
            self.kv_page_bytes = model_lib.kv_page_bytes(
                cfg, page_size, model_lib.paged_pool_dtype(self.cache))
            # hybrid: per-slot Mamba state checkpoints, filled on suspend
            self._has_state = model_lib.has_slot_state(cfg)
            self._ssm_ckpt: dict[int, object] = {}
            # hot-loop bookkeeping lives host-side in numpy (block table,
            # last tokens, active mask): mutating them costs nothing and they
            # ride into each jitted call as inputs, so the only per-step
            # device work is the decode step itself
            self.block = np.zeros((max_batch, self.pages_per_slot), np.int32)
            del self.cache["block"]
            self.last_np = np.zeros((max_batch,), np.int32)
            if kv_tier == "flash":
                self.allocator = TieredPageAllocator(self.num_pages,
                                                     flash_pages)
            else:
                self.allocator = PageAllocator(self.num_pages)
            # prefix cache: the content-addressed page index plus, per slot,
            # {page_idx: chain key} of the pages it maps from the index
            self._px = PrefixIndex(page_size) if prefix_cache else None
            self.slot_shared: list[dict[int, bytes]] = [
                {} for _ in range(max_batch)]
            self._px_pin: set[bytes] = set()  # keys mid-acquire (shed shield)
            # per-slot page lists mirror the block table; a 0 entry marks a
            # page currently cold (spilled to the flash tier)
            self.slot_pages: list[list[int]] = [[] for _ in range(max_batch)]
            self.slot_len: list[int] = [0] * max_batch  # host mirror of lens
            self.suspended: list[bool] = [False] * max_batch
            self.resume_order: list[int] = []  # FIFO of suspended slots
            self._resumed_now: set[int] = set()
            self._idle_steps = 0  # consecutive steps with nothing decodable
            # chunked-prefill state: a slot with prefilling=True holds a
            # request whose prompt is only prefilled up to prefill_pos
            self.prefilling: list[bool] = [False] * max_batch
            self.prefill_pos: list[int] = [0] * max_batch
            self._decode = _jit_decode_paged(cfg)
            self._prefill_slots = _jit_prefill_slots(cfg)
            self._prefill_chunk = (_jit_prefill_chunk(cfg)
                                   if self._chunk_ok else None)
        else:
            self.cache = model_lib.init_cache(cfg, max_batch, max_seq)
            self.last_token = jnp.zeros((max_batch,), jnp.int32)
            self._wave_last_np = np.zeros((max_batch,), np.int32)
            self._wave_len = 0  # host prediction of cache["len"]
            self._decode = _jit_decode(cfg)
        if overlap:
            donate = jax.default_backend() != "cpu"
            self._decode_sample = (
                _jit_decode_sample_paged(cfg, donate)
                if mode == "continuous" else _jit_decode_sample(cfg, donate))
        self._san = _sanitize.load()  # None unless REPRO_SANITIZE=1

    # ------------------------------------------------------------------
    # command surface: add / abort
    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> None:
        if self._cache_len0(req) >= self.max_seq:
            raise ValueError(f"prompt ({len(req.prompt)}) does not fit "
                             f"max_seq ({self.max_seq})")
        if self.mode == "continuous":
            # the whole-lifetime page demand of ONE request must fit the hot
            # pool, or pool-exhaustion recovery (requeue / suspend+resume)
            # could never make progress on it
            worst = min(self.max_seq,
                        self._cache_len0(req) + req.max_new_tokens)
            if pages_needed(worst, self.page_size) > self.num_pages - 1:
                raise ValueError(
                    f"request needs up to {pages_needed(worst, self.page_size)}"
                    f" pages, hot pool has {self.num_pages - 1}")
        req.t_submit = time.monotonic()
        if req.arrival_s is None:
            req.arrival_s = req.t_submit
        self.queue.append(req)

    # the historical name; Router and new code use add_request
    submit = add_request

    def abort_request(self, rid: int) -> bool:
        """Cancel a queued or running request: frees its slot/pages and
        emits exactly one terminal event with ``finish_reason="aborted"``.
        Returns False when ``rid`` is not queued or active here."""
        for req in self.queue:
            if req.rid == rid:
                self.queue.remove(req)
                self._abort(req)
                return True
        for i, req in enumerate(self.slots):
            if req is not None and req.rid == rid:
                if self.mode == "continuous":
                    self._release_slot(i)
                else:
                    self.slots[i] = None
                self._abort(req)
                return True
        return False

    def _abort(self, req: Request) -> None:
        req.done = True
        req.finish_reason = "aborted"
        req.t_done = time.monotonic()
        self.stats.aborted += 1
        self._emit(req, None, finished=True)

    def _cache_len0(self, req: Request) -> int:
        """Valid cache length right after prefill (vision tokens included)."""
        extra = (self.cfg.n_vision_tokens if self.cfg.family == "vlm" else 0)
        return len(req.prompt) + extra

    # ------------------------------------------------------------------
    # command surface: load introspection (what the router routes on)
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def n_free_slots(self) -> int:
        return sum(1 for s in self.slots if s is None)

    @property
    def free_pages(self) -> int:
        """Hot pages currently allocatable (slot-count bound in wave mode,
        where there is no page pool)."""
        if self.mode != "continuous":
            return self.n_free_slots
        return self.allocator.available

    @property
    def page_starved(self) -> bool:
        """True when this replica cannot make progress on everything it
        holds: a suspended slot is waiting for pages to come back, or
        NOTHING in the queue can claim a slot + its prefill pages (checked
        against every queued request, not just the head — admission order
        is the scheduler's, so if ANY entry fits the policy can still make
        progress).  The router uses this as the migration trigger."""
        if self.mode != "continuous":
            return False
        if any(self.suspended):
            return True
        if not self.queue:
            return False
        if self.n_free_slots == 0:
            return True
        need = min(pages_needed(self._cache_len0(r), self.page_size)
                   for r in self.queue)
        return need > self.allocator.available + self._px_reclaimable

    def migration_candidate(self) -> Optional[tuple[int, int]]:
        """``(rid, n_pages)`` of the slot this replica would rather hand to
        a peer, or None.  Suspended slots first (they are already preempted
        — moving one relieves pool pressure AND resumes it sooner); with a
        backlogged queue and no free slot, the scheduler's ``victim`` seam
        picks among active slots — deliberately the same policy decision as
        local pool-pressure eviction."""
        if self.mode != "continuous":
            return None
        if self.resume_order:
            i = self.resume_order[0]
        elif self.queue and self.n_free_slots == 0:
            views = [self._slot_view(j) for j, r in enumerate(self.slots)
                     if r is not None and not self.suspended[j]]
            if not views:
                return None
            i = self.scheduler.victim(views)
            if self.slots[i] is None:  # defensive: policy returned junk
                return None
        else:
            return None
        return self.slots[i].rid, len(self.slot_pages[i])

    def can_accept(self, n_pages: int) -> bool:
        """Whether ``inject_slot`` of an ``n_pages`` snapshot would succeed
        without evicting anyone local: a free slot plus the pages, with one
        page of growth headroom."""
        return (self.mode == "continuous" and not self.page_starved
                and self.n_free_slots > 0
                and n_pages <= self.pages_per_slot
                and (self.allocator.available + self._px_reclaimable
                     >= n_pages + 1))

    @property
    def _px_reclaimable(self) -> int:
        """Idle (refcount-0) HOT prefix-cache pages — freeable on demand, so
        pool-pressure predicates count them as available."""
        return self._px.n_idle_hot if self._px is not None else 0

    # ------------------------------------------------------------------
    # command surface: snapshot / inject (cross-replica slot migration)
    # ------------------------------------------------------------------
    def snapshot_slot(self, rid: int, release: bool = True) -> SlotSnapshot:
        """Drain request ``rid``'s slot into a :class:`SlotSnapshot` and
        release it locally (the request is NOT finished — it continues
        wherever the snapshot is injected).

        Page payloads come from the same two paths the flash tier uses:
        hot pages through one bucketed ``swap_out_pages`` gather, cold
        pages straight out of the allocator's blob store — so a partially
        spilled (suspended) slot snapshots without prefetching first.

        ``release=False`` is the CHECKPOINT variant (periodic fleet
        failover snapshots): the slot keeps running here and the cold
        store keeps its payloads — the snapshot aliases live state
        (``req``, cold payload arrays), so serialize it before the engine
        steps again.
        """
        if self.mode != "continuous":
            raise ValueError("snapshot_slot needs mode='continuous'")
        # the wire format is fully drained state: an in-flight fused step's
        # token must land in out_tokens / slot_len / last_np before they are
        # copied out, or the migrated run would drop it
        self._drain_pending()
        for i, req in enumerate(self.slots):
            if req is not None and req.rid == rid:
                break
        else:
            raise KeyError(f"request {rid} is not active in any slot")
        n_pages = len(self.slot_pages[i])
        pages: list = [None] * n_pages
        hot = [(j, pid) for j, pid in enumerate(self.slot_pages[i])
               if pid != 0]
        if hot:
            payloads = self._gather_pages([pid for _, pid in hot])
            for (j, _pid), payload in zip(hot, payloads):
                pages[j] = payload
        for j, pid in enumerate(self.slot_pages[i]):
            if pid == 0:  # cold: payload already host-side (or in DMA flight)
                pages[j] = _payload_np(self.allocator.fetch((i, j)) if release
                                       else self.allocator.peek((i, j)))
        snap = SlotSnapshot(
            req=req, slot_len=self.slot_len[i],
            last_token=int(self.last_np[i]),
            prefilling=self.prefilling[i], prefill_pos=self.prefill_pos[i],
            pages=pages,
            ssm=(model_lib.checkpoint_slot_state(self.cache, i)
                 if self._has_state else None),
            page_size=self.page_size, family=self.cfg.family,
            prefix_keys=(dict(self.slot_shared[i]) if self._px is not None
                         else None))
        if release:
            self._release_slot(i)
            req.n_migrated += 1
            self.stats.migrated_out += 1
        return snap

    def inject_slot(self, snap: SlotSnapshot) -> int:
        """Resume a snapshotted request in a free slot here; returns the
        slot index.  Decode continues bit-identical to the unmigrated run:
        the pages scatter onto fresh pids (block-table remap, exactly the
        prefetch path), ``lens`` and the sampler cursor restore from the
        snapshot, and recurrent state comes back via
        ``restore_slot_state``."""
        if self.mode != "continuous":
            raise ValueError("inject_slot needs mode='continuous'")
        if snap.family != self.cfg.family or snap.page_size != self.page_size:
            raise ValueError(
                f"snapshot ({snap.family}, page_size={snap.page_size}) does "
                f"not match replica ({self.cfg.family}, {self.page_size})")
        if snap.n_pages > self.pages_per_slot:
            raise ValueError(f"snapshot holds {snap.n_pages} pages, slots "
                             f"here cap at {self.pages_per_slot}")
        free = [i for i in range(self.max_batch) if self.slots[i] is None]
        if not free:
            raise OutOfPages("no free slot to inject into")
        i = free[0]
        # re-share against the local prefix index where it already holds a
        # carried chain key (equal keys imply bit-identical page contents —
        # both replicas prefilled the same tokens with the same params), and
        # deep-copy the rest; carried keys the index lacks REGISTER the fresh
        # copy, so a slot move spreads the cache instead of privatizing it
        shared_map = (dict(snap.prefix_keys or {})
                      if self._px is not None else {})
        reuse_idx = sorted(j for j, k in shared_map.items()
                           if j < snap.n_pages
                           and self._px.get(k) is not None)
        acquired = self._px_acquire([shared_map[j] for j in reuse_idx])
        fresh_idx = [j for j in range(snap.n_pages) if j not in set(reuse_idx)]
        try:
            fresh = self._alloc_pages(len(fresh_idx))
        except OutOfPages:
            for j in reuse_idx:
                self._px_release_key(shared_map[j])
            raise
        if fresh_idx:
            self._scatter_pages(fresh, [snap.pages[j] for j in fresh_idx])
        pids = [0] * snap.n_pages
        for j, pid in zip(reuse_idx, acquired):
            pids[j] = pid
        for j, pid in zip(fresh_idx, fresh):
            pids[j] = pid
        self.slot_shared[i] = {j: shared_map[j] for j in reuse_idx}
        if self._px is not None:
            for j, key in shared_map.items():
                if (j not in self.slot_shared[i] and j < snap.n_pages
                        and self._px.get(key) is None):
                    self._px.insert(key, pids[j])
                    self.slot_shared[i][j] = key
        self.slot_pages[i] = pids
        self.block[i, :snap.n_pages] = pids
        self.slot_len[i] = snap.slot_len
        self.cache["lens"] = self.cache["lens"].at[i].set(snap.slot_len)
        self.last_np[i] = snap.last_token
        self.prefilling[i] = snap.prefilling
        self.prefill_pos[i] = snap.prefill_pos
        if self._has_state and snap.ssm is not None:
            self.cache = model_lib.restore_slot_state(self.cache, i,
                                                      snap.ssm)
        self.slots[i] = snap.req
        self.stats.migrated_in += 1
        return i

    # ------------------------------------------------------------------
    # streaming output contract
    # ------------------------------------------------------------------
    # undelivered events are bounded: a consumer that never drains (run()/
    # bare step() loops reading Request.out_tokens + EngineStats instead)
    # must not leak one RequestOutput per generated token forever — the
    # oldest events are dropped past this cap.  Streaming consumers drain
    # every step and never get near it.
    MAX_PENDING_EVENTS = 1 << 16

    def _emit(self, req: Request, token: Optional[int],
              finished: bool = False) -> None:
        if len(self._events) >= self.MAX_PENDING_EVENTS:
            # shed the oldest half, but keep its finished=True events: the
            # lifecycle contract (every request gets a terminal event with
            # finish_reason + stats) survives overflow; only token-stream
            # events are droppable
            half = self.MAX_PENDING_EVENTS // 2
            finals = [e for e in self._events[:half] if e.finished]
            self._events = finals + self._events[half:]
        sched = None
        ttft = lat = None
        if finished:
            sched = {"chunks": req.n_chunks, "preemptions": req.n_preempted,
                     "wait_s": (req.admission_wait_s if req.t_admit
                                else None)}
            ttft = req.ttft_s if req.t_first_token else None
            lat = req.latency_s
        self._events.append(RequestOutput(
            rid=req.rid, token=token, n_out=len(req.out_tokens),
            finished=finished,
            finish_reason=req.finish_reason if finished else None,
            ttft_s=ttft, latency_s=lat, sched=sched))

    def drain_outputs(self) -> list[RequestOutput]:
        """Pop all RequestOutput events accumulated since the last drain."""
        ev, self._events = self._events, []
        return ev

    def step(self) -> list[RequestOutput]:
        """One admit + decode round; returns the events it produced.

        This is the router-facing command: the legacy bool ("was there
        work?") survives as ``_advance`` and on the ``ServingEngine``
        shim's ``step``.
        """
        self._advance()
        return self.drain_outputs()

    def stream(self, max_steps: int = 10_000):
        """Run the engine, yielding RequestOutput events as they happen."""
        steps = 0
        while self.has_work and steps < max_steps:
            if not self._advance():
                break
            steps += 1
            yield from self.drain_outputs()
        yield from self.drain_outputs()

    # ------------------------------------------------------------------
    # per-request sampling
    # ------------------------------------------------------------------
    def _sample_rows(self, logits, items: list[tuple[int, Request]]
                     ) -> np.ndarray:
        """Sample one token per (row, request) pair from logits [B, V].

        Rows not named in ``items`` return garbage (callers ignore them).
        All-greedy batches take the historical argmax path unchanged; any
        stochastic row switches the whole batch to the vectorized
        ``sampler.sample_batch`` (greedy rows still argmax inside it).
        """
        if all(it[1].sampling.temperature <= 0.0 for it in items):
            return np.asarray(sampler.greedy(logits))
        b = logits.shape[0]
        temps = np.zeros((b,), np.float32)
        seeds = np.zeros((b,), np.int32)
        counts = np.zeros((b,), np.int32)
        topk = np.zeros((b,), np.int32)
        topp = np.ones((b,), np.float32)
        for row, req in items:
            sp = req.sampling
            temps[row] = sp.temperature
            seeds[row] = sp.seed if sp.seed is not None else req.rid
            counts[row] = len(req.out_tokens)
            topk[row] = sp.top_k
            topp[row] = sp.top_p
        return np.asarray(_jit_sample(logits, seeds, counts, temps, topk,
                                      topp))

    # ------------------------------------------------------------------
    # scheduler views
    # ------------------------------------------------------------------
    def _slot_view(self, i: int) -> SlotView:
        r = self.slots[i]
        if self.mode == "continuous":
            # a mid-chunked-prefill slot already holds its WHOLE prompt's
            # pages, so victim heuristics keyed on seq_len ("longest frees
            # the most pages") must see the allocated footprint, not the
            # prefill progress
            prefilling = self.prefilling[i]
            seq = self._cache_len0(r) if prefilling else self.slot_len[i]
            suspended = self.suspended[i]
        else:
            seq = len(r.prompt) + len(r.out_tokens)
            prefilling = suspended = False
        return SlotView(index=i, rid=r.rid, priority=r.priority,
                        arrival_s=r.arrival_s, seq_len=seq,
                        n_out=len(r.out_tokens),
                        remaining=r.max_new_tokens - len(r.out_tokens),
                        prefilling=prefilling, suspended=suspended,
                        deadline_s=(r.arrival_s + r.deadline_s
                                    if r.deadline_s is not None else None))

    def _views(self) -> list[Optional[SlotView]]:
        return [self._slot_view(i) if r is not None else None
                for i, r in enumerate(self.slots)]

    # ------------------------------------------------------------------
    # tiered KV: spill / prefetch / suspend / resume
    # ------------------------------------------------------------------
    def _bucket_pids(self, pids: list[int]) -> np.ndarray:
        """Pad a page-id list to a power-of-two bucket with the null page."""
        n = prefill_bucket(len(pids), floor=1)
        return np.asarray(pids + [0] * (n - len(pids)), np.int32)

    def _gather_pages(self, pids: list[int]) -> list[tuple[np.ndarray, ...]]:
        """Gather hot pages as per-page host payload tuples (one array per
        pool component — (k, v), plus scale payloads when int8) — ONE
        bucketed ``swap_out_pages`` call; each column is copied out so a
        payload doesn't pin the whole bucket buffer.  The payload format is
        shared by the flash tier's cold store and the migration snapshot."""
        arrays = [np.asarray(a)
                  for a in _jit_swap_out(self.cache, self._bucket_pids(pids))]
        return [tuple(a[:, j].copy() for a in arrays)
                for j in range(len(pids))]

    def _scatter_pages(self, pids: list[int], payloads: list) -> None:
        """Scatter per-page payload tuples onto freshly allocated hot pids —
        ONE bucketed ``swap_in_pages`` call (null-page padded); the caller
        remaps the owning block-table row.  Shared by tier prefetch and
        migration inject."""
        payloads = [_payload_np(p) for p in payloads]
        comps = [np.stack([p[c] for p in payloads], axis=1)
                 for c in range(len(payloads[0]))]
        bpids = self._bucket_pids(pids)
        pad = len(bpids) - len(pids)
        if pad:
            def padded(a):
                widths = [(0, 0)] * a.ndim
                widths[1] = (0, pad)
                return np.pad(a, widths)
            comps = [padded(a) for a in comps]
        # device_put starts the host→device transfer asynchronously; the
        # swap_in scatter then composes with it by dataflow instead of the
        # jit call blocking on an implicit synchronous upload
        self.cache = _jit_swap_in(self.cache, bpids,
                                  *(jax.device_put(a) for a in comps))

    def _spill(self, items: list[tuple[tuple[int, int], int]]) -> int:
        """Swap ``(key=(slot, page_idx), pid)`` hot pages out to flash;
        returns how many actually moved.  With a bounded flash tier, items
        past its capacity go back on the eviction queue instead of
        half-spilling (which would leak their hot pids)."""
        room = self.allocator.flash_available
        if room is not None and len(items) > room and self._px is not None:
            # cold cached-prefix payloads are droppable (nobody references
            # them — a re-miss just re-prefills): shed LRU ones for room
            for key in self._px.cold_idle_keys(len(items) - room):
                if key in self._px_pin:
                    continue  # mid-acquire, about to prefetch
                self.allocator.drop_slot(
                    lambda k, key=key: k == ("px", key))
                self._px.drop(key)
            room = self.allocator.flash_available
        if room is not None and len(items) > room:
            for key, pid in items[room:]:
                self.allocator.mark_evictable(key, pid)
            items = items[:room]
        if not items:
            return 0
        pids = [pid for _, pid in items]
        # one bucketed gather, then per-page device columns wrapped as lazy
        # payloads: the device→host copies run asynchronously and only
        # materialize when prefetch / snapshot actually reads them, so a
        # spill never stalls the loop behind a blocking gather
        arrays = _jit_swap_out(self.cache, self._bucket_pids(pids))
        for j, (key, _pid) in enumerate(items):
            self.allocator.store(
                key, _LazyPagePayload(*(a[:, j] for a in arrays)))
            if key[0] == "px":
                # an idle cached-prefix page going cold: no block-table row
                # to clear, just the index residency flip
                self._px.mark_cold(key[1])
            else:
                slot, page_idx = key
                self.block[slot, page_idx] = 0
                self.slot_pages[slot][page_idx] = 0
        self.allocator.free(pids)
        self.stats.kv_spill_pages += len(pids)
        self.stats.kv_spill_bytes += len(pids) * self.kv_page_bytes
        return len(items)

    def _prefetch_slot(self, i: int) -> bool:
        """Bring all of slot ``i``'s cold pages back hot (before its next
        decode step); returns False when the hot pool can't take them yet."""
        keys = self.allocator.cold_keys(lambda k: k[0] == i)
        if not keys:
            return True
        need = len(keys)
        if self.allocator.available < need:
            short = need - self.allocator.available
            self._spill(self.allocator.pop_evictable(
                short, exclude=lambda k: k[0] == i))
        if self.allocator.available < need:
            return False
        keys.sort(key=lambda k: k[1])
        pids = self.allocator.alloc(need)
        self._scatter_pages(pids, [self.allocator.fetch(k) for k in keys])
        # residency-aware block-table remap: the pages came back on new pids
        for key, pid in zip(keys, pids):
            self.block[i, key[1]] = pid
            self.slot_pages[i][key[1]] = pid
        self.stats.kv_prefetch_pages += need
        self.stats.kv_prefetch_bytes += need * self.kv_page_bytes
        return True

    def _suspend(self, i: int) -> None:
        """Preempt slot ``i``: it stops decoding and its pages become LRU
        eviction candidates, oldest (lowest page index) first, tail last.
        A hybrid slot's Mamba state is checkpointed host-side so resume can
        restore it bit-identically (the state pool never pages — it is tiny
        and per-slot — but the checkpoint pins the resume contract even if
        something scribbles the lane while suspended)."""
        self.suspended[i] = True
        self.resume_order.append(i)
        self.stats.preemptions += 1
        self.slots[i].n_preempted += 1
        if self._has_state:
            self._ssm_ckpt[i] = model_lib.checkpoint_slot_state(self.cache, i)
        for page_idx, pid in enumerate(self.slot_pages[i]):
            # shared prefix pages stay pinned hot while mapped (other slots
            # may be reading them); they become spill candidates only when
            # their refcount parks at 0 in the index idle-LRU
            if pid != 0 and page_idx not in self.slot_shared[i]:
                self.allocator.mark_evictable((i, page_idx), pid)

    def _resume_suspended(self) -> None:
        """Head-of-line resume: the oldest suspended slot gets first claim on
        freed pages (with eviction assist against other suspended slots), so
        every preempted request is guaranteed to come back."""
        while self.resume_order:
            i = self.resume_order[0]
            if not self._prefetch_slot(i):
                break
            self.resume_order.pop(0)
            self.suspended[i] = False
            self.allocator.unmark_slot(lambda k, i=i: k[0] == i)
            if self._has_state and i in self._ssm_ckpt:
                self.cache = model_lib.restore_slot_state(
                    self.cache, i, self._ssm_ckpt.pop(i))
            self._resumed_now.add(i)
            self.stats.resumes += 1

    def _make_room(self, n: int, avoid: frozenset = frozenset()) -> None:
        """Free hot pages until ``n`` are available: spill LRU eviction
        candidates first, then preempt the policy's victim slot and retry
        (``scheduler.victim``; default = longest sequence).  ``avoid``
        shields slots (e.g. ones resumed this very step)."""
        while self.allocator.available < n:
            short = n - self.allocator.available
            items = self.allocator.pop_evictable(short)
            if items:
                if self._spill(items) == 0:
                    return  # flash tier full: eviction can't free anything
                continue
            victims = [i for i, r in enumerate(self.slots)
                       if r is not None and not self.suspended[i]
                       and i not in avoid]
            if not victims:
                return
            choice = self.scheduler.victim(
                [self._slot_view(i) for i in victims])
            if choice not in victims:  # defensive: policy returned junk
                choice = max(victims, key=lambda i: self.slot_len[i])
            self._suspend(choice)

    def _alloc_pages(self, n: int, avoid: frozenset = frozenset()) -> list[int]:
        if self.kv_tier == "flash" and self.allocator.available < n:
            self._make_room(n, avoid)
        if self._px is not None and self.allocator.available < n:
            # LRU-reclaim idle cached-prefix pages: live slots always beat
            # the cache (a reclaimed prefix just re-prefills on its next
            # miss; resume entries citing it die lazily at lookup)
            self._px_reclaim(n - self.allocator.available)
        return self.allocator.alloc(n)

    def _px_reclaim(self, n: int) -> None:
        ents = self._px.pop_idle_hot(n)
        if not ents:
            return
        if self.kv_tier == "flash":
            keys = {("px", key) for key, _pid in ents}
            self.allocator.unmark_slot(lambda k: k in keys)
        self.allocator.free([pid for _key, pid in ents])

    # ------------------------------------------------------------------
    # prefix cache: lookup / acquire / release / register / COW
    # ------------------------------------------------------------------
    def _key_tokens(self, req: Request) -> list[int]:
        """Token sequence the prefix hash chains over — one entry per cache
        position (``_cache_len0`` long).  vlm prepends a ``-1`` sentinel per
        vision token: the vision embeds are config-constant here, so equal
        sentinels imply equal page contents for vlm exactly like real
        tokens do for the text families."""
        if self.cfg.family == "vlm":
            return [-1] * self.cfg.n_vision_tokens + list(req.prompt)
        return list(req.prompt)

    def _px_lookup(self, req: Request, len0: int):
        """Match ``req`` against the index: ``("resume", entry)`` for an
        exact-prompt resume point (all five families — admission replays the
        stored bits with zero prefill dispatches), ``("partial", keys)`` for
        a leading run of cached full pages (chunk-capable families only —
        the uncached suffix must prefill through the chunk path), or None.
        A resume entry citing any reclaimed page entry dies lazily here."""
        if self._px is None:
            return None
        self.stats.prefix_lookups += 1
        kt = self._key_tokens(req)
        rkey = self._px.resume_key(kt)
        rent = self._px.get_resume(rkey)
        if rent is not None:
            if all(self._px.get(k) is not None for k in rent.page_keys):
                return ("resume", rent)
            self._px.drop_resume(rkey)
        if not self._chunk_ok:
            return None
        if self.kv_dtype == "int8":
            # a partial hit prefills only the uncached suffix through the
            # chunk path; under int8 pools the full-prompt one-shot prefill
            # and the chunked suffix replay agree only to quantization
            # precision, so partial reuse would break the "a prompt's pages
            # are a pure function of its tokens" sharing contract.  Resume
            # hits stay: they replay stored bits exactly.
            return None
        keys = self._px.page_keys(kt)
        # cap so at least one token remains to prefill: the suffix chunk is
        # what produces the first-token logits on a partial hit
        n = min(self._px.match(keys), (len0 - 1) // self.page_size)
        if n >= 1:
            return ("partial", keys[:n])
        return None

    def _px_acquire(self, keys: list[bytes],
                    avoid: frozenset = frozenset()) -> list[int]:
        """Map cached page entries into a slot: incref hot ones (an idle
        entry leaves the idle-LRU and withdraws its spill candidacy),
        prefetch cold ones onto fresh pids.  Returns pids in ``keys`` order;
        OutOfPages rolls the partial acquisition back completely."""
        done: list[bytes] = []
        cold: list[bytes] = []
        for k in keys:
            ent = self._px.get(k)
            if ent.cold:
                cold.append(k)
                continue
            if self.allocator.incref(ent.pid) == 1:
                self._px.unpark(k)
                if self.kv_tier == "flash":
                    self.allocator.unmark_slot(
                        lambda kk, k=k: kk == ("px", k))
            done.append(k)
        if cold:
            # pop payloads BEFORE allocating: _make_room may shed cold
            # prefix payloads for flash room, and _px_pin shields entries
            # mid-acquire from that shed
            payloads = [self.allocator.fetch(("px", k)) for k in cold]
            self._px_pin.update(cold)
            try:
                npids = self._alloc_pages(len(cold), avoid=avoid)
            except OutOfPages:
                for k, p in zip(cold, payloads):
                    self.allocator.store(("px", k), p)
                for k in done:
                    self._px_release_key(k)
                raise
            finally:
                self._px_pin.difference_update(cold)
            self._scatter_pages(npids, payloads)
            for k, pid in zip(cold, npids):
                self._px.mark_hot(k, pid)
            self.stats.kv_prefetch_pages += len(cold)
            self.stats.kv_prefetch_bytes += len(cold) * self.kv_page_bytes
        return [self._px.get(k).pid for k in keys]

    def _px_release_key(self, key: bytes) -> None:
        """Drop one slot's reference; at 0 the page parks on the idle-LRU
        (cached for the next hit) and becomes a spill candidate."""
        ent = self._px.get(key)
        if self.allocator.decref(ent.pid) == 0:
            self._px.park(key)
            if self.kv_tier == "flash":
                self.allocator.mark_evictable(("px", key), ent.pid)

    def _px_register_prompt(self, i: int, req: Request, len0: int,
                            logits_row) -> None:
        """Register slot ``i``'s freshly PREFILL-written prompt pages and an
        exact-prompt resume point.  Called right after one-shot prefill and
        at chunked-prefill completion — never for decode-written pages
        (their bits may differ from a prefill of the same tokens, which
        would break warm-vs-cold bit-identity on reuse)."""
        kt = self._key_tokens(req)
        keys = self._px.page_keys(kt)
        shared = self.slot_shared[i]
        for j, key in enumerate(keys):
            if j in shared:
                continue  # a partial hit already maps the index's page here
            if self._px.get(key) is None:
                self._px.insert(key, self.slot_pages[i][j])
                shared[j] = key
            # else: an identical page is registered under another pid (e.g.
            # twin prompts admitted in one group); ours stays exclusive
        rkey = self._px.resume_key(kt)
        if self._px.peek_resume(rkey) is None:
            tail_len = len0 - len(keys) * self.page_size
            tail = (self._gather_pages([self.slot_pages[i][len(keys)]])[0]
                    if tail_len else None)
            self._px.put_resume(rkey, ResumeEntry(
                page_keys=keys, tail=tail, tail_len=tail_len,
                logits=np.asarray(logits_row).copy(), length=len0,
                ssm=(model_lib.checkpoint_slot_state(self.cache, i)
                     if self._has_state else None)))

    def _px_cow(self, i: int, pj: int) -> None:
        """Copy-on-write: give slot ``i`` a private copy of its shared page
        ``pj`` before a write dirties it.  Registration only ever covers
        full prefill-written pages strictly behind every write frontier, so
        this is a safety net rather than a hot path — but any future flow
        that writes into the shared span goes through here, never through
        an in-place write."""
        key = self.slot_shared[i][pj]
        payload = self._gather_pages([self.slot_pages[i][pj]])[0]
        pid = self._alloc_pages(
            1, avoid=frozenset({i}) | self._resumed_now)[0]
        self._scatter_pages([pid], [payload])
        del self.slot_shared[i][pj]
        self._px_release_key(key)
        self.slot_pages[i][pj] = pid
        self.block[i, pj] = pid
        self.stats.cow_copies += 1

    def _admit_resume_hit(self, i: int, req: Request, len0: int,
                          rent: ResumeEntry, now: float) -> None:
        """Admit an exact-prompt hit with ZERO prefill dispatches: map the
        shared full pages, scatter the stored tail-page copy onto a private
        page (the COW copy of the partially filled shared span), restore
        recurrent state, and sample the first token from the stored prefill
        logits — bit-identical to a cold admission for any sampling params
        because every consumed bit is the cold run's bit."""
        avoid = frozenset(self._resumed_now)
        shared = self._px_acquire(rent.page_keys, avoid=avoid)
        tail: list[int] = []
        if rent.tail_len:
            try:
                tail = self._alloc_pages(1, avoid=avoid)
            except OutOfPages:
                for k in rent.page_keys:
                    self._px_release_key(k)
                raise
            self._scatter_pages(tail, [rent.tail])
            self.stats.cow_copies += 1
        pids = shared + tail
        self.slot_pages[i] = pids
        self.block[i, :len(pids)] = pids
        self.slot_shared[i] = dict(enumerate(rent.page_keys))
        self.slot_len[i] = rent.length
        self.cache["lens"] = self.cache["lens"].at[i].set(rent.length)
        self.prefilling[i] = False
        self.prefill_pos[i] = 0
        if self._has_state and rent.ssm is not None:
            self.cache = model_lib.restore_slot_state(self.cache, i,
                                                      rent.ssm)
        self.slots[i] = req
        self.stats.admitted += 1
        self.stats.prefix_hits += 1
        self.stats.prefix_hit_pages += len(shared)
        self.stats.prefix_tokens_reused += rent.length
        tok = int(self._sample_rows(rent.logits[None], [(0, req)])[0])
        t1 = time.monotonic()
        if req.t_admit == 0.0:  # restarts keep their first-admit times
            req.t_admit = now
            req.t_first_token = t1
        req.out_tokens.append(tok)
        self.stats.tokens_out += 1
        self.last_np[i] = tok
        reason = self._finish_reason_for(req, tok, rent.length)
        if reason is not None:
            self._finish(i, req, reason, token=tok)
        else:
            self._emit(req, tok)

    def _admit_partial_hit(self, i: int, req: Request, len0: int,
                           keys: list[bytes], now: float) -> None:
        """Admit a partial hit: map the cached leading pages, allocate the
        rest, and enter the chunked-prefill path at the cached length — the
        suffix prefills through ``_prefill_chunks`` whose any-schedule
        bit-identity contract keeps warm output equal to a cold one-shot."""
        avoid = frozenset(self._resumed_now)
        cached = len(keys) * self.page_size
        shared = self._px_acquire(keys, avoid=avoid)
        try:
            fresh = self._alloc_pages(
                pages_needed(len0, self.page_size) - len(keys), avoid=avoid)
        except OutOfPages:
            for k in keys:
                self._px_release_key(k)
            raise
        pids = shared + fresh
        self.slot_pages[i] = pids
        self.block[i, :len(pids)] = pids
        self.slot_shared[i] = dict(enumerate(keys))
        self.slots[i] = req
        self.prefilling[i] = True
        self.prefill_pos[i] = cached
        self.slot_len[i] = cached
        self.cache["lens"] = self.cache["lens"].at[i].set(cached)
        if req.t_admit == 0.0:
            req.t_admit = now
        self.stats.admitted += 1
        self.stats.prefix_hits += 1
        self.stats.prefix_hit_pages += len(keys)
        self.stats.prefix_tokens_reused += cached

    def prefix_hit_estimate(self, req: Request) -> int:
        """Prompt tokens this replica's prefix cache could serve ``req``
        without prefilling — the router folds this into ``least_loaded``
        scoring and ``session_affinity`` placement.  LRU-neutral (scoring N
        replicas must not perturb any cache's eviction order)."""
        if self.mode != "continuous" or self._px is None:
            return 0
        kt = self._key_tokens(req)
        rent = self._px.peek_resume(self._px.resume_key(kt))
        if rent is not None and all(
                self._px.get(k) is not None for k in rent.page_keys):
            return rent.length
        if not self._chunk_ok:
            return 0
        n = min(self._px.match(self._px.page_keys(kt)),
                (self._cache_len0(req) - 1) // self.page_size)
        return n * self.page_size

    def clear_prefix_cache(self) -> int:
        """Drop every IDLE cached prefix page (hot and cold) and all resume
        entries; pages still mapped by live slots stay shared.  Returns the
        number of page entries dropped — after a full drain this returns
        the whole index and the pool recycles completely (tests pin that)."""
        if self._px is None:
            return 0
        ents = self._px.pop_idle_hot(1 << 30)
        if ents:
            if self.kv_tier == "flash":
                keys = {("px", k) for k, _pid in ents}
                self.allocator.unmark_slot(lambda kk: kk in keys)
            self.allocator.free([pid for _k, pid in ents])
        cold = self._px.cold_idle_keys(1 << 30)
        for key in cold:
            self.allocator.drop_slot(lambda k, key=key: k == ("px", key))
            self._px.drop(key)
        self._px.clear_resume()
        return len(ents) + len(cold)

    # ------------------------------------------------------------------
    # continuous admission: prefill requests into free slots (one batched
    # pass for one-shot prompts; chunked slots claim now, prefill over the
    # following steps) while the rest of the batch keeps decoding
    # ------------------------------------------------------------------
    def _release_slot(self, i: int) -> None:
        # a pending fused-step row for this slot is now stale: the epoch
        # bump makes the lagged drain skip it (the slot may already host a
        # different request by then)
        self._slot_epoch[i] += 1
        self._inflight[i] = 0
        self.slots[i] = None
        # shared pages decref (at 0 they park on the index idle-LRU, cached
        # for the next hit); exclusively owned pages free outright — the
        # allocator's refcount guard makes a misclassified shared page a
        # loud ValueError, never a silent corruption
        own = [p for j, p in enumerate(self.slot_pages[i])
               if p != 0 and j not in self.slot_shared[i]]
        self.allocator.free(own)
        for j in self.slot_shared[i]:
            self._px_release_key(self.slot_shared[i][j])
        self.slot_shared[i] = {}
        if self.kv_tier == "flash":
            self.allocator.drop_slot(lambda k, i=i: k[0] == i)
            if self.suspended[i]:
                self.suspended[i] = False
                self.resume_order.remove(i)
        self.slot_pages[i] = []
        self.slot_len[i] = 0
        self.prefilling[i] = False
        self.prefill_pos[i] = 0
        self.block[i] = 0
        self._ssm_ckpt.pop(i, None)
        self.cache["lens"] = self.cache["lens"].at[i].set(0)

    def _finish(self, i: int, req: Request, reason: str,
                token: Optional[int] = None) -> None:
        now = time.monotonic()
        req.done = True
        req.finish_reason = reason
        req.t_done = now
        self.stats.completed += 1
        self.stats.admission_wait_s.append(req.admission_wait_s)
        self.stats.ttft_s.append(req.ttft_s)
        self.stats.latency_s.append(req.latency_s)
        if self.mode == "continuous":
            self._release_slot(i)
        else:
            self.slots[i] = None
        self._emit(req, token, finished=True)

    def _reject(self, req: Request) -> None:
        req.done = True
        req.rejected = True
        req.finish_reason = "rejected"
        req.t_done = time.monotonic()
        self.stats.rejected += 1
        self._emit(req, None, finished=True)

    def _preempt_restart(self, i: int, req: Request) -> None:
        """Pool exhausted mid-decode without a flash tier (or a priority
        preemption): fold the generated prefix into the prompt and requeue —
        greedy decode and seed-pinned sampling are both deterministic, so
        the request's final ``out_tokens`` are unchanged."""
        self.stats.preemptions += 1
        req.n_preempted += 1
        req.prompt = req.prompt + req.out_tokens[req.n_folded:]
        req.n_folded = len(req.out_tokens)
        self._release_slot(i)
        self.queue.insert(0, req)

    def _finish_reason_for(self, req: Request, tok: int, seq_len: int) -> \
            Optional[str]:
        if tok == self.eos_id:
            return "eos"
        if len(req.out_tokens) >= req.max_new_tokens:
            return "length"
        if seq_len >= self.max_seq - 1:
            return "capacity"
        return None

    def _admit_continuous(self) -> None:
        """Admit queued requests into free slots in the scheduler's order:
        one-shot prompts prefill together in ONE batched prefill-into-cache
        pass (right-padded, per-row 0-based positions); prompts longer than
        the policy's chunk budget claim their slot and pages now and
        prefill chunk-by-chunk over the following steps.  Occupied slots
        keep their decode state untouched throughout."""
        plan = self.scheduler.admit(list(self.queue), self._views(),
                                    self.allocator.available)
        head = next((r for r in plan.order if r in self.queue), None)
        for vi in plan.preempt:
            if (not 0 <= vi < self.max_batch or self.slots[vi] is None
                    or self.suspended[vi]):
                continue
            if head is not None and self.kv_tier != "flash":
                # futility gate: without a flash tier, restart-preempting a
                # victim whose freed pages still don't cover the arrival's
                # prefill just throws the victim's progress away (the slot
                # would sit idle on OutOfPages); the tiered path can always
                # _make_room by spilling, so it skips the gate
                victim_hot = sum(1 for p in self.slot_pages[vi] if p != 0)
                need = pages_needed(self._cache_len0(head), self.page_size)
                if self.allocator.available + victim_hot < need:
                    continue
            self._preempt_restart(vi, self.slots[vi])
        free = [i for i in range(self.max_batch) if self.slots[i] is None]
        group = []
        now = time.monotonic()
        for req in plan.order:
            if not free:
                break
            if req not in self.queue:  # defensive: stale plan entry
                continue
            i = free[0]
            len0 = self._cache_len0(req)
            hit = self._px_lookup(req, len0)
            try:
                if hit is not None and hit[0] == "resume":
                    self._admit_resume_hit(i, req, len0, hit[1], now)
                    free.pop(0)
                    self.queue.remove(req)
                    continue
                if hit is not None and hit[0] == "partial":
                    self._admit_partial_hit(i, req, len0, hit[1], now)
                    free.pop(0)
                    self.queue.remove(req)
                    continue
                pids = self._alloc_pages(
                    pages_needed(len0, self.page_size),
                    avoid=frozenset(self._resumed_now))
            except OutOfPages:
                self.stats.pool_exhausted += 1
                if self.exhaust_policy == "reject":
                    self.queue.remove(req)
                    self._reject(req)
                    continue
                break  # starved request keeps its queue spot for next step
            free.pop(0)
            self.queue.remove(req)
            self.slot_pages[i] = pids
            self.block[i, :len(pids)] = pids
            budget = self.scheduler.prefill_budget(SlotView(
                index=i, rid=req.rid, priority=req.priority,
                arrival_s=req.arrival_s, seq_len=0, n_out=0,
                remaining=req.max_new_tokens, prefilling=True,
                suspended=False,
                deadline_s=(req.arrival_s + req.deadline_s
                            if req.deadline_s is not None else None)))
            if self._chunk_ok and budget < len0:
                # chunked admission: slot + pages claimed, prompt prefills
                # in budget-sized chunks interleaved with decode steps
                self.slots[i] = req
                self.prefilling[i] = True
                self.prefill_pos[i] = 0
                self.slot_len[i] = 0
                if req.t_admit == 0.0:
                    req.t_admit = now
                self.stats.admitted += 1
            else:
                group.append((i, req, len0))
        if not group:
            return
        # common bucket for the group, capped so bucket + vision tokens still
        # fits a slot's block-table row (tail-pad pages beyond an allocation
        # fall on the null page, but the row itself must not overflow)
        extra = max(len0 - len(req.prompt) for i, req, len0 in group)
        cap = self.pages_per_slot * self.page_size - extra
        bucket = min(max(prefill_bucket(len(req.prompt))
                         for i, req, len0 in group), cap)
        # pad the group to max_batch rows by REPEATING row 0 (its duplicate
        # scatters write identical values, so the result is deterministic):
        # the jitted prefill then only ever sees (max_batch, bucket) shapes,
        # one trace per bucket instead of one per group size
        rows = group + [group[0]] * (self.max_batch - len(group))
        toks = np.asarray(
            [req.prompt + [0] * (bucket - len(req.prompt))
             for i, req, len0 in rows], np.int32)
        slot_ids = np.asarray([i for i, req, len0 in rows], np.int32)
        true_lens = np.asarray([len0 for i, req, len0 in rows], np.int32)
        logits, out_cache = self._prefill_slots(
            self.params, toks, true_lens, {**self.cache, "block": self.block},
            slot_ids)
        out_cache.pop("block")  # authoritative copy stays host-side
        self.cache = out_cache
        self.stats.prefills += 1
        self.stats.admitted += len(group)
        toks_out = self._sample_rows(
            logits, [(row, req) for row, (i, req, len0) in enumerate(group)])
        logits_np = np.asarray(logits) if self._px is not None else None
        t1 = time.monotonic()
        for row, ((i, req, len0), tok) in enumerate(zip(group, toks_out)):
            tok = int(tok)
            if req.t_admit == 0.0:  # restarts keep their first-admit times
                req.t_admit = now
                req.t_first_token = t1
            req.out_tokens.append(tok)
            self.stats.tokens_out += 1
            self.last_np[i] = tok
            self.slot_len[i] = len0
            self.slots[i] = req
            if self._px is not None:
                # register BEFORE any finish below: the pages must outlive
                # the slot as cached entries even for one-token requests
                self._px_register_prompt(i, req, len0, logits_np[row])
            reason = self._finish_reason_for(req, tok, len0)
            if reason is not None:
                self._finish(i, req, reason, token=tok)
            else:
                self._emit(req, tok)

    def _prefill_chunks(self) -> int:
        """Run one prefill chunk for every mid-prefill slot (the policy's
        per-step token budget each).  A slot whose prompt completes samples
        its first token and joins decode from the next lane mask on."""
        ran = 0
        for i in range(self.max_batch):
            req = self.slots[i]
            if req is None or not self.prefilling[i] or self.suspended[i]:
                continue
            len0 = self._cache_len0(req)
            pos = self.prefill_pos[i]
            budget = max(1, int(self.scheduler.prefill_budget(
                self._slot_view(i))))
            clen = min(budget, len0 - pos)
            cap = self.pages_per_slot * self.page_size
            # pad the chunk to a power-of-two bucket (floor = page size):
            # per-step compute scales with the BUDGET, not the slot
            # capacity, and the trace count stays O(log max_seq) like the
            # group-prefill buckets.  Bit-identity is per-position, so the
            # bucket shape is free to vary (tests/test_chunked_prefill.py
            # pins identity across differently-bucketed schedules).
            cb = min(prefill_bucket(clen, floor=self.page_size), cap)
            toks = np.zeros((cb,), np.int32)
            toks[:clen] = req.prompt[pos:pos + clen]
            logits, out_cache = self._prefill_chunk(
                self.params, toks, np.int32(pos), np.int32(clen),
                {**self.cache, "block": self.block}, np.int32(i))
            out_cache.pop("block")
            self.cache = out_cache
            req.n_chunks += 1
            self.stats.prefill_chunks += 1
            ran += 1
            pos += clen
            self.prefill_pos[i] = pos
            self.slot_len[i] = pos
            if pos >= len0:
                self.prefilling[i] = False
                if self._px is not None:
                    self._px_register_prompt(i, req, len0,
                                             np.asarray(logits))
                tok = int(self._sample_rows(
                    jnp.asarray(logits)[None], [(0, req)])[0])
                if req.t_first_token == 0.0:
                    req.t_first_token = time.monotonic()
                req.out_tokens.append(tok)
                self.stats.tokens_out += 1
                self.last_np[i] = tok
                reason = self._finish_reason_for(req, tok, pos)
                if reason is not None:
                    self._finish(i, req, reason, token=tok)
                else:
                    self._emit(req, tok)
        return ran

    def _ensure_pages(self) -> None:
        """Allocate the page each active slot's next write lands in; on a dry
        pool, preempt (tiered: suspend + spill; untiered: requeue/reject)."""
        for i in range(self.max_batch):
            req = self.slots[i]
            if req is None or self.suspended[i] or self.prefilling[i]:
                continue
            # the next write position counts the in-flight token the host
            # has not drained yet (slot_len is the DRAINED length)
            pj = (self.slot_len[i] + self._inflight[i]) // self.page_size
            try:
                if pj < len(self.slot_pages[i]):
                    if self._px is not None and pj in self.slot_shared[i]:
                        # the next decode write lands in a SHARED page:
                        # copy-on-write before it can dirty other readers
                        self._px_cow(i, pj)
                    continue
                pid = self._alloc_pages(
                    1, avoid=frozenset({i}) | self._resumed_now)[0]
            except OutOfPages:
                self.stats.pool_exhausted += 1
                if self.kv_tier == "flash":
                    self._suspend(i)
                elif self.exhaust_policy == "reject":
                    self._reject(req)
                    self._release_slot(i)
                else:
                    self._preempt_restart(i, req)
                continue
            self.slot_pages[i].append(pid)
            self.block[i, pj] = pid

    # ------------------------------------------------------------------
    # overlapped decode: dispatch step N+1 before reading step N's tokens
    # ------------------------------------------------------------------
    def _sampling_rows(self, items: list[tuple[int, Request]],
                       lag: Callable[[int], int]
                       ) -> tuple[bool, tuple[np.ndarray, ...]]:
        """Per-row sampling parameter arrays for a fused dispatch.

        ``lag(i)`` is how many of slot i's tokens are still in flight: the
        sampler cursor (``counts``) must index the token ABOUT to be
        sampled, which trails ``len(out_tokens)`` by the undrained ones.
        """
        b = self.max_batch
        temps = np.zeros((b,), np.float32)
        seeds = np.zeros((b,), np.int32)
        counts = np.zeros((b,), np.int32)
        topk = np.zeros((b,), np.int32)
        topp = np.ones((b,), np.float32)
        for i, req in items:
            sp = req.sampling
            temps[i] = sp.temperature
            seeds[i] = sp.seed if sp.seed is not None else req.rid
            counts[i] = len(req.out_tokens) + lag(i)
            topk[i] = sp.top_k
            topp[i] = sp.top_p
        greedy_only = all(r.sampling.temperature <= 0.0 for _, r in items)
        return greedy_only, (seeds, counts, temps, topk, topp)

    def _drain_pending(self) -> None:
        """Read back and account the in-flight fused step (no-op without).

        This is the lagged finish point: eos shows up here one engine call
        after the token was computed, and the speculative extra step a
        to-be-finished slot may have run in between is discarded via the
        slot-epoch check when its release bumps the epoch.
        """
        pend, self._pending = self._pending, None
        if pend is not None:
            self._drain_rows(pend)

    def _drain_rows(self, pend: dict) -> None:
        if self._san is not None and pend.get("san") is not None:
            # the step is about to be read back: its numpy args must be
            # bit-identical to what was dispatched (aliasing guard)
            self._san.check_drain(pend["san"])
        tok_np = np.asarray(pend["tok"])  # blocks on THIS step only; any
        # younger dispatch keeps running behind it
        if self.mode == "continuous":
            for i, req, seq_after, epoch in pend["rows"]:
                if (req.done or self.slots[i] is not req
                        or self._slot_epoch[i] != epoch):
                    continue  # slot reassigned/released since dispatch
                self._inflight[i] -= 1
                t = int(tok_np[i])
                self.last_np[i] = t
                req.out_tokens.append(t)
                self.stats.tokens_out += 1
                self.slot_len[i] = seq_after
                reason = self._finish_reason_for(req, t, seq_after)
                if reason is not None:
                    self._finish(i, req, reason, token=t)
                else:
                    self._emit(req, t)
        else:
            for i, req, seq_after in pend["rows"]:
                if req.done or self.slots[i] is not req:
                    continue
                t = int(tok_np[i])
                self._wave_last_np[i] = t
                req.out_tokens.append(t)
                self.stats.tokens_out += 1
                reason = None
                if t == self.eos_id:
                    reason = "eos"
                elif len(req.out_tokens) >= req.max_new_tokens:
                    reason = "length"
                elif seq_after >= self.max_seq - 1:
                    reason = "capacity"
                if reason is not None:
                    self._finish(i, req, reason, token=t)
                else:
                    self._emit(req, t)

    def _overlap_round_continuous(self, active_list: list[bool]) -> None:
        """One overlapped round: fused-dispatch the next decode step, THEN
        drain the previous one — its host readback runs concurrently with
        the compute just enqueued, so the device never waits on the host
        between steps."""
        items = [(i, self.slots[i]) for i in range(self.max_batch)
                 if active_list[i]]
        greedy_only, sp_rows = self._sampling_rows(
            items, lag=lambda i: self._inflight[i])
        use_dev = np.asarray([n > 0 for n in self._inflight])
        old, self._pending = self._pending, None
        tok_dev = (old["tok"] if old is not None
                   else np.zeros((self.max_batch,), np.int32))
        t0 = time.monotonic()
        # numpy args MUST be snapshotted: on the CPU backend jit wraps host
        # buffers zero-copy, so the async-executing step would otherwise read
        # ``last_np`` / ``block`` concurrently with the in-place mutations
        # the drain / spill below performs (a real, observed data race)
        last_np = self.last_np.copy()
        block = self.block.copy()
        active = np.asarray(active_list)
        tok, cache = self._decode_sample(
            self.params, last_np, tok_dev, use_dev,
            {**self.cache, "block": block},
            active, *sp_rows, greedy_only=greedy_only)
        # wall_decode_s measures DISPATCH time here (the compute itself is
        # deliberately not awaited); bench wall clocks stay end-to-end
        self.stats.wall_decode_s += time.monotonic() - t0
        san = None
        if self._san is not None:
            san = self._san.guard_dispatch(
                self.stats.decode_steps, last_np=last_np, block=block,
                use_dev=use_dev, active=active, seeds=sp_rows[0],
                counts=sp_rows[1], temps=sp_rows[2], topk=sp_rows[3],
                topp=sp_rows[4])
            self._san.check_retrace(self._decode_sample, "decode_sample")
        cache.pop("block")  # authoritative copy stays host-side
        self.cache = cache
        self.stats.decode_steps += 1
        self.stats.decode_dispatches += 1
        rows = []
        for i, req in items:
            seq_after = self.slot_len[i] + self._inflight[i] + 1
            rows.append((i, req, seq_after, self._slot_epoch[i]))
            self._inflight[i] += 1
        self._pending = {"tok": tok, "rows": rows, "san": san}
        if old is not None:
            self._drain_rows(old)

    def _mask_predicted_finishes(self, active_list: list[bool]) -> None:
        """Exclude slots whose undrained token already finishes them.

        Length and capacity are host-predictable one step ahead, so those
        slots must not run a wasted extra step; eos is only discoverable at
        drain — an eos'd slot runs one speculative step whose writes land
        beyond its lens mask (or are re-prefilled by the next occupant) and
        whose token the epoch check discards."""
        for i in range(self.max_batch):
            if not active_list[i] or not self._inflight[i]:
                continue
            req = self.slots[i]
            if (len(req.out_tokens) + self._inflight[i]
                    >= req.max_new_tokens
                    or self.slot_len[i] + self._inflight[i]
                    >= self.max_seq - 1):
                active_list[i] = False

    def _step_continuous(self) -> bool:
        self._resumed_now = set()
        if self.kv_tier == "flash":
            self._resume_suspended()
        self._admit_continuous()
        chunks_ran = self._prefill_chunks()
        if all(s is None for s in self.slots):
            self._drain_pending()  # discard a stale speculative step
            return bool(self.queue)
        self._ensure_pages()
        active_list = [self.slots[i] is not None and not self.suspended[i]
                       and not self.prefilling[i]
                       for i in range(self.max_batch)]
        if self.overlap:
            self._mask_predicted_finishes(active_list)
        if not any(active_list):
            had_pending = self._pending is not None
            self._drain_pending()  # lagged finishes still need to land
            if chunks_ran or had_pending:
                self._idle_steps = 0  # chunk/drain progress is progress
                return True
            # everything suspended and nothing resumed: with an unbounded
            # flash tier the head-of-line resume always succeeds within one
            # step (eviction assist reaches every other suspended slot), but
            # a FULL bounded tier can wedge — no spill room, no free hot
            # pages.  After a second consecutive zero-progress step, escape
            # by restarting the head slot, which frees its pages outright.
            self._idle_steps += 1
            if self.resume_order and self._idle_steps >= 2:
                i = self.resume_order[0]
                self.stats.pool_exhausted += 1
                self._preempt_restart(i, self.slots[i])
                self._idle_steps = 0
            return True
        self._idle_steps = 0
        if self.overlap:
            self._overlap_round_continuous(active_list)
            return True
        active = np.asarray(active_list)
        pre_cache = {**self.cache, "block": self.block}  # for re-dispatch
        t0 = time.monotonic()
        logits, cache = self._decode(self.params, self.last_np, pre_cache,
                                     active)
        dt = time.monotonic() - t0
        if self.watchdog is not None and self.watchdog(
                self.stats.decode_steps, dt):
            self.stats.straggler_events += 1
            logits, cache = self._decode(self.params, self.last_np,
                                         pre_cache, active)
        cache.pop("block")  # authoritative copy stays host-side
        self.cache = cache
        self.stats.decode_steps += 1
        self.stats.decode_dispatches += 2  # decode + separate sample
        self.stats.wall_decode_s += dt
        tok_np = self._sample_rows(  # one sync per step
            logits, [(i, r) for i, r in enumerate(self.slots)
                     if r is not None and active_list[i]])
        for i, req in enumerate(self.slots):
            if req is None or not active_list[i]:
                continue
            t = int(tok_np[i])
            self.last_np[i] = t
            req.out_tokens.append(t)
            self.stats.tokens_out += 1
            self.slot_len[i] += 1
            reason = self._finish_reason_for(req, t, self.slot_len[i])
            if reason is not None:
                self._finish(i, req, reason, token=t)
            else:
                self._emit(req, t)
        return True

    # ------------------------------------------------------------------
    # legacy wave admission over the shared-cursor cache
    # ------------------------------------------------------------------
    def _admit_wave(self) -> None:
        """The shared length cursor (cache["len"]) forces lockstep decode, so
        new requests only start when the whole batch drains.  The scheduler
        still orders the wave (preemption does not apply: there is no
        per-slot cache to evict)."""
        if any(s is not None for s in self.slots):
            return
        # with overlap, the call that drains a wave's last tokens has
        # already dispatched one speculative step; all its rows are stale
        # now (every request finished) — retire it before re-priming
        self._drain_pending()
        if not self.queue:
            return
        plan = self.scheduler.admit(list(self.queue), self._views(),
                                    1 << 30)
        order = [r for r in plan.order if r in self.queue]
        wave = order[:self.max_batch]
        for r in wave:
            self.queue.remove(r)
        if not wave:
            return
        now = time.monotonic()
        # right-align prompts to a common prefill length
        plen = max(len(r.prompt) for r in wave)
        toks = jnp.array(
            [([0] * (plen - len(r.prompt)) + r.prompt) for r in wave]
            + [[0] * plen] * (self.max_batch - len(wave)), jnp.int32)
        self.cache = model_lib.init_cache(self.cfg, self.max_batch,
                                          self.max_seq)
        logits, self.cache = _jit_prefill(self.cfg)(
            self.params, toks, self.cache, self.max_batch)
        self.stats.prefills += 1
        self.stats.admitted += len(wave)
        tok_np = self._sample_rows(
            logits, [(row, r) for row, r in enumerate(wave)])
        self.last_token = jnp.asarray(tok_np)
        self._wave_last_np = np.asarray(tok_np, np.int32).copy()
        self._wave_len = plen  # host prediction of cache["len"]
        t1 = time.monotonic()
        for i, r in enumerate(wave):
            self.slots[i] = r
            r.t_admit = now
            r.t_first_token = t1
            tok = int(tok_np[i])
            r.out_tokens.append(tok)
            self.stats.tokens_out += 1
            reason = self._finish_reason_for(r, tok, len(r.prompt))
            if reason == "capacity":
                reason = None  # wave cursor checked against cache len below
            if reason is not None:
                self._finish(i, r, reason, token=tok)
            else:
                self._emit(r, tok)

    def _overlap_round_wave(self) -> None:
        """Wave-mode overlapped round: same dispatch-then-drain shape as
        continuous, minus slot churn (no admission mid-wave, no epochs —
        row liveness is just ``slots[i] is req``)."""
        items = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        old, self._pending = self._pending, None
        live = set()
        if old is not None:
            for i, req, _sa in old["rows"]:
                if self.slots[i] is req and not req.done:
                    live.add(i)
        if self._wave_len >= self.max_seq - 1:
            # shared cursor at capacity: nothing more may be dispatched (a
            # write at max_seq would overflow the cache); the drain below
            # capacity-finishes every surviving row
            if old is not None:
                self._drain_rows(old)
            return
        greedy_only, sp_rows = self._sampling_rows(
            items, lag=lambda i: 1 if i in live else 0)
        use_dev = np.asarray([i in live for i in range(self.max_batch)])
        tok_dev = (old["tok"] if old is not None
                   else np.zeros((self.max_batch,), np.int32))
        t0 = time.monotonic()
        # snapshot: CPU jit aliases numpy inputs zero-copy and the drain
        # below mutates ``_wave_last_np`` while this step is still running
        wave_last = self._wave_last_np.copy()
        tok, cache = self._decode_sample(
            self.params, wave_last, tok_dev, use_dev,
            self.cache, *sp_rows, greedy_only=greedy_only)
        self.stats.wall_decode_s += time.monotonic() - t0
        san = None
        if self._san is not None:
            san = self._san.guard_dispatch(
                self.stats.decode_steps, wave_last=wave_last,
                use_dev=use_dev, seeds=sp_rows[0], counts=sp_rows[1],
                temps=sp_rows[2], topk=sp_rows[3], topp=sp_rows[4])
            self._san.check_retrace(self._decode_sample, "decode_sample")
        self.cache = cache
        self.stats.decode_steps += 1
        self.stats.decode_dispatches += 1
        self._wave_len += 1
        self._pending = {"tok": tok, "san": san,
                         "rows": [(i, r, self._wave_len) for i, r in items]}
        if old is not None:
            self._drain_rows(old)

    def _step_wave(self) -> bool:
        self._admit_wave()
        if all(s is None for s in self.slots):
            return bool(self.queue)
        if self.overlap:
            self._overlap_round_wave()
            return True
        pre_cache = self.cache
        t0 = time.monotonic()
        logits, cache = self._decode(self.params, self.last_token, pre_cache)
        dt = time.monotonic() - t0
        if self.watchdog is not None and self.watchdog(
                self.stats.decode_steps, dt):
            self.stats.straggler_events += 1
            logits, cache = self._decode(self.params, self.last_token,
                                         pre_cache)
        self.cache = cache
        self.stats.decode_steps += 1
        self.stats.decode_dispatches += 2  # decode + separate sample
        self.stats.wall_decode_s += dt
        tok_np = self._sample_rows(
            logits, [(i, r) for i, r in enumerate(self.slots)
                     if r is not None])
        self.last_token = jnp.asarray(tok_np)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            t = int(tok_np[i])
            r.out_tokens.append(t)
            self.stats.tokens_out += 1
            reason = None
            if t == self.eos_id:
                reason = "eos"
            elif len(r.out_tokens) >= r.max_new_tokens:
                reason = "length"
            elif int(self.cache["len"]) >= self.max_seq - 1:
                reason = "capacity"
            if reason is not None:
                self._finish(i, r, reason, token=t)
            else:
                self._emit(r, t)
        return True

    # ------------------------------------------------------------------
    def _advance(self) -> bool:
        """Admit + one decode step over the active batch; True if any work."""
        if self.mode == "continuous":
            return self._step_continuous()
        return self._step_wave()

    def run(self, max_steps: int = 10_000) -> EngineStats:
        steps = 0
        while self.has_work and steps < max_steps:
            if not self._advance():
                break
            steps += 1
        return self.stats
