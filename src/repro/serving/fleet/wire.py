"""Wire codec + framing for the fleet transport.

Everything a FleetRouter says to a worker — and everything that comes
back — is one *frame*::

    +-------+---------+-------+-------------------+----------------+
    | magic | version | flags | payload length u32 | payload bytes |
    |  2 B  |   1 B   |  1 B  |    big-endian      |               |
    +-------+---------+-------+-------------------+----------------+

and every payload is one *value* in a tagged self-describing binary
encoding (:func:`encode` / :func:`decode`): None / bool / int / float /
str / bytes / list / tuple / dict / numpy ndarray (bfloat16 included —
raw bytes plus the dtype name), plus the four serving dataclasses
(``Request``, ``SamplingParams``, ``RequestOutput``, ``SlotSnapshot``)
encoded as field-name → value maps, so a decoder can skip fields it
does not know about (forward compatibility: new fields go at the end,
defaulted).

:class:`FrameDecoder` is the incremental receive side: feed it byte
chunks exactly as ``recv`` produced them — partial headers, frames
split across reads, many frames in one read — and it yields complete
payloads.  A wrong magic, an unsupported version, or a payload length
past the cap raises :class:`ProtocolError` instead of hanging or
swallowing garbage.

``snapshot_to_bytes`` / ``snapshot_from_bytes`` give ``SlotSnapshot``
its standalone byte format (used by the periodic failover checkpoints
as well as the transport): a versioned header carrying the geometry —
family, page_size, page dtype, page count — that a receiver can guard
on *before* decoding the body.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

MAGIC = b"\xf1\x37"          # frame magic ("fleet")
WIRE_VERSION = 1
MAX_PAYLOAD = 1 << 28        # 256 MiB: far above any snapshot, below insanity
_HEADER = struct.Struct(">2sBBI")   # magic, version, flags, payload length
HEADER_SIZE = _HEADER.size

SNAP_MAGIC = b"KVSN"         # SlotSnapshot byte-format magic
SNAP_VERSION = 1


class ProtocolError(RuntimeError):
    """Malformed frame or payload: wrong magic, bad version, truncated or
    oversized data, unknown tag.  Never raised for well-formed messages
    the receiver merely dislikes — those are application errors."""


# The golden list of fields each serving dataclass puts on the wire.  The
# codec itself is generic (``dataclasses.fields``), so a field added to a
# dataclass ships automatically — but a *receiver* built from an older
# checkout silently drops it (unknown-field skip, by design).  This
# manifest makes that drift checkable: reprolint's ``wire-field-drift``
# rule diffs it against the dataclass definitions statically, and
# ``REPRO_SANITIZE=1`` re-checks at registry build time.  When you add a
# dataclass field, add it HERE too (last, defaulted) — that is the review
# speed-bump forcing the forward-compat question to be asked.
WIRE_FIELDS = {
    "Request": (
        "rid", "prompt", "max_new_tokens", "priority", "arrival_s",
        "deadline_s", "session", "sampling", "temperature", "out_tokens",
        "done", "rejected", "finish_reason", "n_folded", "n_chunks",
        "n_preempted", "n_migrated", "t_submit", "t_admit",
        "t_first_token", "t_done",
    ),
    "SamplingParams": ("temperature", "top_k", "top_p", "seed"),
    "RequestOutput": (
        "rid", "token", "n_out", "finished", "finish_reason",
        "ttft_s", "latency_s", "sched",
    ),
    "SlotSnapshot": (
        "req", "slot_len", "last_token", "prefilling", "prefill_pos",
        "pages", "ssm", "page_size", "family", "prefix_keys",
    ),
}


# ----------------------------------------------------------------------
# value codec
# ----------------------------------------------------------------------
def _serving_types():
    # imported lazily: core imports this module from SlotSnapshot.to_bytes,
    # so a top-level import either way would be circular
    from repro.serving.core import Request, RequestOutput, SlotSnapshot
    from repro.serving.scheduler import SamplingParams
    return {b"Q": Request, b"P": SamplingParams, b"O": RequestOutput,
            b"S": SlotSnapshot}


_TAG_OF: dict[type, bytes] = {}
_TYPE_OF: dict[bytes, type] = {}


def _registry() -> dict[type, bytes]:
    if not _TAG_OF:
        _TYPE_OF.update(_serving_types())
        _TAG_OF.update({t: tag for tag, t in _TYPE_OF.items()})
        from repro import _sanitize
        san = _sanitize.load()
        if san is not None:
            san.check_wire_manifest(
                WIRE_FIELDS, {t.__name__: t for t in _TAG_OF})
    return _TAG_OF


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":   # np.dtype() does not resolve the name itself
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    try:
        return np.dtype(name)
    except TypeError as e:
        raise ProtocolError(f"unknown array dtype {name!r}") from e


def _enc_str(s: str, out: bytearray) -> None:
    b = s.encode("utf-8")
    out += struct.pack(">I", len(b))
    out += b


def _enc(obj, out: bytearray) -> None:
    if obj is None:
        out += b"N"
    elif obj is True:
        out += b"T"
    elif obj is False:
        out += b"F"
    elif isinstance(obj, np.bool_):
        out += b"T" if obj else b"F"
    elif isinstance(obj, (int, np.integer)):
        out += b"i"
        out += struct.pack(">q", int(obj))
    elif isinstance(obj, (float, np.floating)):
        out += b"f"
        out += struct.pack(">d", float(obj))
    elif isinstance(obj, str):
        out += b"s"
        _enc_str(obj, out)
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        b = bytes(obj)
        out += b"y"
        out += struct.pack(">I", len(b))
        out += b
    elif isinstance(obj, np.ndarray):
        a = np.ascontiguousarray(obj)
        raw = a.tobytes()
        out += b"a"
        _enc_str(a.dtype.name, out)
        out += struct.pack(">B", a.ndim)
        out += struct.pack(f">{a.ndim}I", *a.shape)
        out += struct.pack(">I", len(raw))
        out += raw
    elif type(obj) in _registry():
        fields = dataclasses.fields(obj)
        out += _TAG_OF[type(obj)]
        out += struct.pack(">I", len(fields))
        for f in fields:
            _enc_str(f.name, out)
            _enc(getattr(obj, f.name), out)
    elif isinstance(obj, (list, tuple)):
        out += b"l" if isinstance(obj, list) else b"u"
        out += struct.pack(">I", len(obj))
        for v in obj:
            _enc(v, out)
    elif isinstance(obj, dict):
        out += b"d"
        out += struct.pack(">I", len(obj))
        for k, v in obj.items():
            _enc(k, out)
            _enc(v, out)
    else:
        raise ProtocolError(
            f"cannot encode {type(obj).__name__} on the fleet wire")


def encode(obj) -> bytes:
    """Serialize one value (commands, replies, snapshots) to bytes."""
    out = bytearray()
    _enc(obj, out)
    return bytes(out)


class _Reader:
    __slots__ = ("data", "off")

    def __init__(self, data: bytes, off: int = 0):
        self.data = data
        self.off = off

    def take(self, n: int) -> bytes:
        if self.off + n > len(self.data):
            raise ProtocolError(
                f"truncated payload: wanted {n} bytes at offset {self.off}, "
                f"have {len(self.data) - self.off}")
        b = self.data[self.off:self.off + n]
        self.off += n
        return b

    def u32(self) -> int:
        return struct.unpack(">I", self.take(4))[0]

    def str_(self) -> str:
        return self.take(self.u32()).decode("utf-8")


def _dec(r: _Reader):
    tag = r.take(1)
    if tag == b"N":
        return None
    if tag == b"T":
        return True
    if tag == b"F":
        return False
    if tag == b"i":
        return struct.unpack(">q", r.take(8))[0]
    if tag == b"f":
        return struct.unpack(">d", r.take(8))[0]
    if tag == b"s":
        return r.str_()
    if tag == b"y":
        return r.take(r.u32())
    if tag == b"a":
        dtype = _np_dtype(r.str_())
        ndim = struct.unpack(">B", r.take(1))[0]
        shape = struct.unpack(f">{ndim}I", r.take(4 * ndim))
        raw = r.take(r.u32())
        a = np.frombuffer(raw, dtype=dtype)
        if a.size != int(np.prod(shape, dtype=np.int64)):
            raise ProtocolError(
                f"array payload {a.size} elements does not fill {shape}")
        # frombuffer views are read-only; engines write into injected state
        return a.reshape(shape).copy()
    if tag in (b"l", b"u"):
        n = r.u32()
        vals = [_dec(r) for _ in range(n)]
        return vals if tag == b"l" else tuple(vals)
    if tag == b"d":
        n = r.u32()
        return {_dec(r): _dec(r) for _ in range(n)}
    _registry()
    cls = _TYPE_OF.get(tag)
    if cls is not None:
        n = r.u32()
        kv = {}
        for _ in range(n):
            name = r.str_()
            kv[name] = _dec(r)
        known = {f.name for f in dataclasses.fields(cls) if f.init}
        # unknown names are a NEWER sender's trailing fields: skip them
        return cls(**{k: v for k, v in kv.items() if k in known})
    raise ProtocolError(f"unknown wire tag {tag!r}")


def decode(data: bytes):
    """Deserialize one :func:`encode`-d value; the whole buffer must be
    consumed (trailing garbage is a framing bug, not padding)."""
    r = _Reader(data)
    obj = _dec(r)
    if r.off != len(data):
        raise ProtocolError(
            f"{len(data) - r.off} trailing bytes after decoded value")
    return obj


# ----------------------------------------------------------------------
# framing
# ----------------------------------------------------------------------
def frame(payload: bytes, max_payload: int = MAX_PAYLOAD) -> bytes:
    """Wrap one encoded payload in a length-prefixed, versioned frame."""
    if len(payload) > max_payload:
        raise ProtocolError(
            f"payload of {len(payload)} bytes exceeds the "
            f"{max_payload}-byte frame cap")
    return _HEADER.pack(MAGIC, WIRE_VERSION, 0, len(payload)) + payload


class FrameDecoder:
    """Incremental frame reassembly over an arbitrary chunking of the byte
    stream.  ``feed`` returns the payloads of every frame completed by the
    chunk (possibly none, possibly several) and keeps partial frames
    buffered for the next call."""

    def __init__(self, max_payload: int = MAX_PAYLOAD):
        self._buf = bytearray()
        self.max_payload = max_payload

    def feed(self, data: bytes) -> list[bytes]:
        self._buf += data
        out = []
        while len(self._buf) >= HEADER_SIZE:
            magic, version, _flags, n = _HEADER.unpack_from(self._buf)
            if magic != MAGIC:
                raise ProtocolError(
                    f"bad frame magic {bytes(magic)!r} (expected {MAGIC!r}) "
                    f"— stream is corrupt or not a fleet peer")
            if version != WIRE_VERSION:
                raise ProtocolError(
                    f"unsupported wire version {version} "
                    f"(speaking {WIRE_VERSION})")
            if n > self.max_payload:
                raise ProtocolError(
                    f"frame announces {n} payload bytes, cap is "
                    f"{self.max_payload}")
            if len(self._buf) < HEADER_SIZE + n:
                break
            out.append(bytes(self._buf[HEADER_SIZE:HEADER_SIZE + n]))
            del self._buf[:HEADER_SIZE + n]
        return out


# ----------------------------------------------------------------------
# SlotSnapshot byte format
# ----------------------------------------------------------------------
def _snap_dtype(snap) -> str:
    return snap.pages[0][0].dtype.name if snap.pages else ""


def snapshot_to_bytes(snap) -> bytes:
    """``SlotSnapshot`` → bytes: geometry header + encoded field map."""
    body = encode({f.name: getattr(snap, f.name)
                   for f in dataclasses.fields(snap)})
    fam = snap.family.encode("utf-8")
    dt = _snap_dtype(snap).encode("utf-8")
    return b"".join([
        SNAP_MAGIC, struct.pack(">H", SNAP_VERSION),
        struct.pack(">B", len(fam)), fam,
        struct.pack(">I", int(snap.page_size)),
        struct.pack(">B", len(dt)), dt,
        struct.pack(">I", len(snap.pages)),
        body,
    ])


def peek_snapshot_header(data: bytes) -> tuple[dict, int]:
    """Parse just the geometry header; returns (header dict, body offset).
    This is what a receiver guards on before paying for the body decode."""
    r = _Reader(data)
    magic = r.take(4)
    if magic != SNAP_MAGIC:
        raise ProtocolError(f"bad snapshot magic {magic!r}")
    version = struct.unpack(">H", r.take(2))[0]
    if version != SNAP_VERSION:
        raise ProtocolError(f"unsupported snapshot version {version}")
    fam = r.take(struct.unpack(">B", r.take(1))[0]).decode("utf-8")
    page_size = r.u32()
    dt = r.take(struct.unpack(">B", r.take(1))[0]).decode("utf-8")
    n_pages = r.u32()
    return ({"family": fam, "page_size": page_size, "dtype": dt,
             "n_pages": n_pages, "version": version}, r.off)


def snapshot_from_bytes(data: bytes, expect_family: str | None = None,
                        expect_page_size: int | None = None,
                        expect_dtype: str | None = None):
    """bytes → ``SlotSnapshot``, with the geometry guard: a caller that
    knows its own family / page_size / page dtype passes them as
    ``expect_*`` and gets a ``ValueError`` on mismatch *before* the body
    is decoded (the same contract as ``EngineCore.inject_slot``)."""
    from repro.serving.core import SlotSnapshot

    hdr, off = peek_snapshot_header(data)
    for key, want in (("family", expect_family),
                      ("page_size", expect_page_size),
                      ("dtype", expect_dtype)):
        if want is not None and hdr[key] != want:
            raise ValueError(
                f"snapshot {key}={hdr[key]!r} does not match the "
                f"receiver's {key}={want!r}")
    fields = decode(data[off:])
    if not isinstance(fields, dict):
        raise ProtocolError("snapshot body is not a field map")
    known = {f.name for f in dataclasses.fields(SlotSnapshot) if f.init}
    snap = SlotSnapshot(**{k: v for k, v in fields.items() if k in known})
    if snap.family != hdr["family"] or snap.page_size != hdr["page_size"]:
        raise ProtocolError("snapshot header disagrees with its body")
    return snap
