"""FleetRouter: the Router surface over remote workers, with failover.

Same duck-typed surface the in-process :class:`repro.serving.router.Router`
offers (``submit`` / ``step`` / ``abort`` / ``has_work`` / ``stats`` /
``summary``), so a :class:`repro.serving.client.ServingClient` plugs in
unchanged — but the replicas are :class:`WorkerHandle`\\ s behind a
:mod:`transport <repro.serving.fleet.transport>`, each hosting one
EngineCore in (potentially) another process.

Health state machine (per worker, driven by every reply)::

    ALIVE ──reply deadline blown──► SUSPECT ──misses > limit──► DEAD
      ▲                               │                           ▲
      └────────late reply arrives─────┘      EOF / reset / kill ──┘

ALIVE workers get one ``step`` command per router step; a SUSPECT
worker is only polled for its outstanding late reply (never sent new
work) until it recovers or crosses the miss limit.  Every reply
piggybacks the worker's load vector — the heartbeat that routing and
migration read.

Failover re-dispatches every request owned by a DEAD worker:

* requests still queued on it replay **from the client's request
  record** — a fresh clone with the ORIGINAL prompt (the mirror is
  never folded or mutated by worker-side restarts);
* in-flight slots restore from the last periodic checkpoint — every
  ``checkpoint_every`` steps each worker returns non-destructive
  ``SlotSnapshot.to_bytes()`` blobs of its active slots, persisted
  through ``distributed/checkpoint.py``'s atomic-write machinery (and
  re-read through it at failover) — injected into a surviving worker or
  a promoted hot spare, which then re-decodes the few tokens generated
  since the checkpoint.

The replay invariant: re-decoded tokens the client already saw are
suppressed, but each one is **verified byte-equal** against the
delivered stream before being dropped (counted in ``tokens_replayed``)
— per-request streams are batch-composition-invariant and sampling is
seed-pinned per request, so the recovered stream is bit-identical to an
undisturbed run, and any divergence is a loud RuntimeError instead of a
silent wrong answer.
"""

from __future__ import annotations

import copy
import shutil
import tempfile
import time
import zlib
from collections import deque
from typing import Iterable, Optional

import numpy as np

from repro.distributed.checkpoint import restore_checkpoint, save_checkpoint
from repro.serving.core import (EngineCore, EngineStats, Request,
                                RequestOutput, SlotSnapshot)
from repro.serving.fleet.transport import (LoopbackTransport, RemoteError,
                                           Transport, TransportError,
                                           TransportTimeout, spawn_worker,
                                           unwrap)
from repro.serving.router import ROUTE_POLICIES

ALIVE, SUSPECT, DEAD = "alive", "suspect", "dead"


class WorkerHandle:
    """One remote EngineCore: transport + the router's view of its health."""

    def __init__(self, name: str, transport: Transport, spare: bool = False):
        self.name = name
        self.transport = transport
        self.spare = spare
        self.state = ALIVE
        self.load: dict = {}
        self.misses = 0          # consecutive blown reply deadlines
        self.pending: Optional[str] = None   # method awaiting its reply
        self.last_stats = EngineStats()

    @property
    def alive(self) -> bool:
        return self.state != DEAD

    def __repr__(self):
        return f"<worker {self.name} {self.state}>"


class FleetRouter:
    """Routes requests over transport-attached workers; detects death and
    straggle by heartbeat/reply deadlines; fails over with bit-identical
    recovered streams.  See the module docstring for the contract."""

    def __init__(self, workers: Iterable[Transport | WorkerHandle],
                 spares: Iterable[Transport | WorkerHandle] = (),
                 policy: str = "least_loaded", migrate: bool = True,
                 checkpoint_every: int = 8, ckpt_dir: Optional[str] = None,
                 reply_timeout_s: float = 60.0,
                 suspect_poll_s: float = 0.05, miss_limit: int = 3):
        if policy not in ROUTE_POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; pick "
                             f"from {ROUTE_POLICIES}")
        self._active = [w if isinstance(w, WorkerHandle)
                        else WorkerHandle(f"w{i}", w)
                        for i, w in enumerate(workers)]
        if not self._active:
            raise ValueError("fleet needs at least one worker")
        self._spares = [w if isinstance(w, WorkerHandle)
                        else WorkerHandle(f"s{i}", w, spare=True)
                        for i, w in enumerate(spares)]
        self.policy = policy
        self.migrate = migrate
        self.migrations = 0
        self.checkpoint_every = checkpoint_every
        self.reply_timeout_s = reply_timeout_s
        self.suspect_poll_s = suspect_poll_s
        self.miss_limit = miss_limit
        self._own_ckpt_dir = ckpt_dir is None
        self.ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="fleet_ckpt_")
        # fleet-level counters (the satellite fields of EngineStats)
        self.fleet = EngineStats(mode="fleet", policy=policy)
        self._reqs: dict[int, Request] = {}      # live client-side mirrors
        self._owner: dict[int, WorkerHandle] = {}
        self._backlog: deque[Request] = deque()  # clones awaiting dispatch
        self._ckpt: dict[int, bytes] = {}        # freshest snapshot blobs
        self._saved: dict[int, bytes] = {}       # what the last save wrote
        self._replay_until: dict[int, int] = {}  # rid -> delivered hwm
        self._out_buffer: list[RequestOutput] = []
        self.recovery_s: list[float] = []        # per-failover wall seconds
        self._rid_hwm = -1
        self._rr = 0
        self._step_n = 0

    # ------------------------------------------------------------------
    # construction helpers
    # ------------------------------------------------------------------
    @classmethod
    def build_loopback(cls, cfg, params, workers: int = 2, spares: int = 0,
                       policy: str = "least_loaded", migrate: bool = True,
                       **kw) -> "FleetRouter":
        """N in-process EngineCores behind byte-faithful loopback
        transports.  ``kw`` splits into EngineCore kwargs and FleetRouter
        kwargs (``checkpoint_every`` etc.)."""
        from repro.serving.fleet.worker import WorkerHost

        router_kw = {k: kw.pop(k) for k in
                     ("checkpoint_every", "ckpt_dir", "reply_timeout_s",
                      "suspect_poll_s", "miss_limit") if k in kw}

        def mk(name, spare):
            ekw = dict(kw)
            if ekw.get("scheduler") is not None:   # stateful: never shared
                ekw["scheduler"] = copy.deepcopy(ekw["scheduler"])
            core = EngineCore(cfg, params, **ekw)
            return WorkerHandle(name, LoopbackTransport(
                WorkerHost(core, name=name)), spare=spare)

        return cls([mk(f"w{i}", False) for i in range(workers)],
                   spares=[mk(f"s{i}", True) for i in range(spares)],
                   policy=policy, migrate=migrate, **router_kw)

    @classmethod
    def build_socket(cls, arch: str, workers: int = 2, spares: int = 0,
                     policy: str = "least_loaded", migrate: bool = True,
                     checkpoint_every: int = 8,
                     ckpt_dir: Optional[str] = None,
                     reply_timeout_s: float = 120.0, miss_limit: int = 3,
                     sched_policy: str = "fcfs", **spawn_kw) -> "FleetRouter":
        """Spawn ``workers + spares`` subprocess workers (concurrently —
        param init dominates startup) and wire them up.  ``policy`` is
        the fleet ROUTING policy; the per-worker SCHEDULER policy rides
        as ``sched_policy`` (the names collide on the worker CLI)."""
        from concurrent.futures import ThreadPoolExecutor

        n = workers + spares
        with ThreadPoolExecutor(max_workers=n) as ex:
            transports = list(ex.map(
                lambda _: spawn_worker(arch, policy=sched_policy,
                                       **spawn_kw), range(n)))
        return cls([WorkerHandle(f"w{i}", t)
                    for i, t in enumerate(transports[:workers])],
                   spares=[WorkerHandle(f"s{i}", t, spare=True)
                           for i, t in enumerate(transports[workers:])],
                   policy=policy, migrate=migrate,
                   checkpoint_every=checkpoint_every, ckpt_dir=ckpt_dir,
                   reply_timeout_s=reply_timeout_s, miss_limit=miss_limit)

    # ------------------------------------------------------------------
    # reply plumbing + health bookkeeping
    # ------------------------------------------------------------------
    def _process_reply(self, w: WorkerHandle, rep: dict):
        """Book a received reply: heartbeat, health recovery, and the
        method-specific payload (step events / checkpoint blobs)."""
        if isinstance(rep.get("load"), dict):
            w.load = rep["load"]
        w.misses = 0
        if w.state == SUSPECT:
            w.state = ALIVE
        method, w.pending = w.pending, None
        result = unwrap(rep)
        if method == "step":
            self._deliver(result["events"])
        elif method == "checkpoint":
            self._note_checkpoint(result["snaps"])
        return result

    def _call(self, w: WorkerHandle, method: str, args: dict | None = None):
        """Synchronous auxiliary call (submit / inject / migration /
        stats).  A timeout here is treated as death, not straggle: unlike
        ``step``, these calls have side effects we cannot leave in limbo
        (did the add_request land?) — closing the worker makes the answer
        irrelevant."""
        try:
            w.transport.send(method, args or {})
            w.pending = method
            rep = w.transport.recv(self.reply_timeout_s)
        except TransportTimeout:
            self.fleet.heartbeat_misses += 1
            self._failover(w)
            raise
        except TransportError:
            self._failover(w)
            raise
        return self._process_reply(w, rep)

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def _routable(self) -> list[WorkerHandle]:
        return [w for w in self._active
                if w.state == ALIVE and w.pending is None]

    def _pick(self, req: Request) -> Optional[WorkerHandle]:
        ws = self._routable()
        if not ws:
            return None
        if self.policy == "session_affinity" and req.session is not None:
            # remote prefix estimates would cost one RPC per worker per
            # submit; the stable-hash fallback keeps a conversation pinned
            # to one worker, which is the property the policy sells
            h = zlib.crc32(str(req.session).encode())
            return ws[h % len(ws)]
        if self.policy == "least_loaded":
            return min(ws, key=lambda w: (
                w.load.get("queue_depth", 0) + w.load.get("n_active", 0),
                -w.load.get("free_pages", 0)))
        w = ws[self._rr % len(ws)]
        self._rr += 1
        return w

    @staticmethod
    def _clone(req: Request) -> Request:
        """A fresh submission-grade copy from the client's record: the
        ORIGINAL prompt, no generated tokens — what a worker receives at
        first dispatch and what from-scratch failover replays."""
        return Request(rid=req.rid, prompt=list(req.prompt),
                       max_new_tokens=req.max_new_tokens,
                       priority=req.priority, arrival_s=req.arrival_s,
                       deadline_s=req.deadline_s, session=req.session,
                       sampling=req.sampling)

    def submit(self, req: Request) -> Optional[str]:
        """Route one request; returns the worker name it landed on (None
        while it waits in the local backlog).  The caller's Request object
        becomes the client-side mirror — the failover record and the
        stream the replay verifier checks against."""
        if req.rid in self._reqs or req.rid <= self._rid_hwm:
            raise ValueError(
                f"request id {req.rid} already submitted — ids must be "
                f"globally unique and strictly increasing across the fleet "
                f"(use ServingClient, which allocates them)")
        self._rid_hwm = max(self._rid_hwm, req.rid)
        self._reqs[req.rid] = req
        self._backlog.append(self._clone(req))
        self._flush_backlog()
        w = self._owner.get(req.rid)
        return w.name if w is not None else None

    def _flush_backlog(self) -> None:
        while self._backlog:
            req = self._backlog[0]
            mirror = self._reqs.get(req.rid)
            if mirror is None or mirror.done:   # aborted while queued
                self._backlog.popleft()
                continue
            w = self._pick(req)
            if w is None:
                if not any(x.alive for x in self._active) \
                        and not self._spares:
                    raise RuntimeError(
                        "fleet has no live workers and no spares left")
                return   # try again next step
            try:
                self._call(w, "add_request", {"req": req})
            except RemoteError as e:
                # the worker executed and REJECTED it (e.g. prompt does not
                # fit max_seq) — a terminal verdict, not a routing failure
                mirror.done = True
                mirror.rejected = True
                mirror.finish_reason = "rejected"
                self.fleet.rejected += 1
                self._emit_local(mirror, "rejected", str(e))
                self._backlog.popleft()
                continue
            except TransportError:
                continue   # worker failed over; try the next candidate
            self._owner[req.rid] = w
            self._backlog.popleft()

    def abort(self, rid: int) -> bool:
        for req in self._backlog:
            if req.rid == rid:
                self._backlog.remove(req)
                mirror = self._reqs.get(rid, req)
                mirror.done = True
                mirror.finish_reason = "aborted"
                self.fleet.aborted += 1
                self._emit_local(mirror, "aborted")
                return True
        w = self._owner.get(rid)
        if w is None or not w.alive or w.pending is not None:
            return False
        try:
            return bool(self._call(w, "abort", {"rid": rid}))
        except TransportError:
            return False

    def _emit_local(self, req: Request, reason: str,
                    detail: str | None = None) -> None:
        self._out_buffer.append(RequestOutput(
            rid=req.rid, token=None, n_out=len(req.out_tokens),
            finished=True, finish_reason=reason,
            sched={"chunks": 0, "preemptions": 0, "wait_s": None}))
        self._finish_bookkeeping(req.rid)

    def _finish_bookkeeping(self, rid: int) -> None:
        self._reqs.pop(rid, None)
        self._owner.pop(rid, None)
        self._ckpt.pop(rid, None)
        self._replay_until.pop(rid, None)

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._backlog) or bool(self._reqs) \
            or bool(self._out_buffer)

    def step(self) -> list[RequestOutput]:
        """One fleet round: flush the backlog, step every ALIVE worker
        (polling SUSPECT ones for their late reply), collect periodic
        checkpoints, maybe migrate one slot."""
        self._step_n += 1
        self._flush_backlog()
        for w in list(self._active):
            if not w.alive:
                continue
            try:
                if w.pending is None:
                    w.transport.send("step", {})
                    w.pending = "step"
                    rep = w.transport.recv(self.reply_timeout_s)
                else:   # SUSPECT: only poll for the outstanding reply
                    rep = w.transport.recv(self.suspect_poll_s)
            except TransportTimeout:
                w.misses += 1
                self.fleet.heartbeat_misses += 1
                w.state = SUSPECT
                if w.misses > self.miss_limit:
                    self._failover(w)
                continue
            except TransportError:
                self._failover(w)
                continue
            self._process_reply(w, rep)
        if self.checkpoint_every \
                and self._step_n % self.checkpoint_every == 0:
            self._checkpoint()
        if self.migrate:
            self._maybe_migrate()
        outs, self._out_buffer = self._out_buffer, []
        return outs

    def _deliver(self, events: list[RequestOutput]) -> None:
        for ev in events:
            req = self._reqs.get(ev.rid)
            if req is None:
                continue   # finished/aborted mirror: stale event
            until = self._replay_until.get(ev.rid, 0)
            if ev.token is not None:
                if ev.n_out <= until:
                    # failover replay: the re-decoded token must equal the
                    # one already delivered — THE bit-identity oracle
                    if req.out_tokens[ev.n_out - 1] != ev.token:
                        raise RuntimeError(
                            f"failover replay diverged for rid {ev.rid} at "
                            f"token {ev.n_out}: delivered "
                            f"{req.out_tokens[ev.n_out - 1]}, replayed "
                            f"{ev.token}")
                    self.fleet.tokens_replayed += 1
                    if not ev.finished:
                        continue   # duplicate: suppress, client saw it
                elif ev.n_out != len(req.out_tokens) + 1:
                    raise RuntimeError(
                        f"rid {ev.rid}: token event n_out={ev.n_out} does "
                        f"not extend the delivered stream of "
                        f"{len(req.out_tokens)}")
                else:
                    req.out_tokens.append(ev.token)
            if ev.finished:
                req.done = True
                req.finish_reason = ev.finish_reason
                self.fleet.completed += 1
                self._finish_bookkeeping(ev.rid)
            self._out_buffer.append(ev)

    # ------------------------------------------------------------------
    # periodic checkpoints (the failover source)
    # ------------------------------------------------------------------
    def _checkpoint(self) -> None:
        for w in self._active:
            if w.state != ALIVE or w.pending is not None:
                continue
            if not w.load.get("n_active"):
                continue   # nothing in slots — nothing to snapshot
            try:
                w.transport.send("checkpoint", {})
                w.pending = "checkpoint"
                rep = w.transport.recv(self.reply_timeout_s)
            except TransportTimeout:
                w.misses += 1
                self.fleet.heartbeat_misses += 1
                w.state = SUSPECT
                continue    # the late blob is booked when it arrives
            except TransportError:
                self._failover(w)
                continue
            try:
                self._process_reply(w, rep)
            except RemoteError:
                pass   # a failed snapshot is a missed checkpoint, not death
        self._persist()

    def _note_checkpoint(self, snaps: dict) -> None:
        for rid, blob in snaps.items():
            if rid in self._reqs:
                self._ckpt[rid] = blob

    def _persist(self) -> None:
        """Write the blob set through the atomic-write checkpoint
        machinery (tmp dir + rename, keep-last-K) — snapshot bytes ride as
        uint8 leaves keyed by rid."""
        if not self._ckpt:
            return
        tree = {str(rid): np.frombuffer(blob, dtype=np.uint8)
                for rid, blob in self._ckpt.items()}
        try:
            save_checkpoint(self.ckpt_dir, self._step_n, tree, keep=2)
        except OSError:
            return   # disk trouble: in-memory blobs still cover failover
        self._saved = dict(self._ckpt)

    def _restore_saved(self) -> dict[int, bytes]:
        """Re-read the last persisted blob set from disk — failover
        restores through the same machinery an operator would after a
        full router restart.  Falls back to the in-memory copy."""
        if not self._saved:
            return {}
        like = {str(rid): np.zeros(len(blob), np.uint8)
                for rid, blob in self._saved.items()}
        try:
            tree, _ = restore_checkpoint(self.ckpt_dir, like)
        except Exception:
            return dict(self._saved)
        return {int(rid): arr.tobytes() for rid, arr in tree.items()}

    # ------------------------------------------------------------------
    # failover
    # ------------------------------------------------------------------
    def _failover(self, w: WorkerHandle) -> None:
        """Declare ``w`` dead and re-dispatch everything it owned."""
        if w.state == DEAD:
            return
        t0 = time.monotonic()
        w.state = DEAD
        w.pending = None
        w.transport.close()
        self.fleet.workers_lost += 1
        self.fleet.failovers += 1
        self._promote_spare()
        victims = [rid for rid, own in self._owner.items() if own is w]
        disk = self._restore_saved() if victims else {}
        for rid in victims:
            self._owner.pop(rid, None)
            req = self._reqs.get(rid)
            if req is None or req.done:
                continue
            self.fleet.requests_replayed += 1
            blob = disk.get(rid, self._ckpt.get(rid))
            if blob is None or not self._recover_from_snapshot(rid, blob):
                # queued (never checkpointed) or nowhere to inject:
                # replay from the client's request record, from scratch
                self._replay_until[rid] = len(req.out_tokens)
                self._backlog.append(self._clone(req))
        self.recovery_s.append(time.monotonic() - t0)

    def _promote_spare(self) -> None:
        while self._spares:
            s = self._spares.pop(0)
            try:
                unwrap(s.transport.call("ping", {}, self.reply_timeout_s))
            except TransportError:
                s.state = DEAD
                s.transport.close()
                continue
            s.spare = False
            s.state = ALIVE
            self._active.append(s)
            return

    def _recover_from_snapshot(self, rid: int, blob: bytes) -> bool:
        """Inject a checkpointed slot into a surviving worker; reconcile
        the mirror with tokens the checkpoint holds but the client never
        saw (decoded between the last delivery and the snapshot)."""
        req = self._reqs[rid]
        try:
            snap = SlotSnapshot.from_bytes(blob)
        except Exception:
            return False
        snap_toks = list(snap.req.out_tokens)
        common = min(len(snap_toks), len(req.out_tokens))
        if snap_toks[:common] != req.out_tokens[:common]:
            raise RuntimeError(
                f"checkpoint for rid {rid} diverges from the delivered "
                f"stream within the first {common} tokens")
        # order candidates: most free pages first (same spirit as the
        # in-process Router's donor choice)
        cands = sorted(self._routable(),
                       key=lambda w: -w.load.get("free_pages", 0))
        for w in cands:
            try:
                self._call(w, "inject_slot", {"snap": snap})
            except RemoteError:
                continue          # no slot / OutOfPages there: next
            except TransportError:
                continue          # that worker just failed over too
            self._owner[rid] = w
            # checkpoint tokens the client never saw are first deliveries,
            # not replays: emit them now so the stream stays gapless
            for n in range(len(req.out_tokens) + 1, len(snap_toks) + 1):
                req.out_tokens.append(snap_toks[n - 1])
                self._out_buffer.append(RequestOutput(
                    rid=rid, token=snap_toks[n - 1], n_out=n))
            self._replay_until[rid] = len(req.out_tokens)
            return True
        return False

    # ------------------------------------------------------------------
    # migration (the in-process Router's rebalance, over the wire)
    # ------------------------------------------------------------------
    def _maybe_migrate(self) -> None:
        ws = self._routable()
        if len(ws) < 2:
            return
        for src in ws:
            if not src.load.get("page_starved"):
                continue
            try:
                cand = self._call(src, "migration_candidate")
            except TransportError:
                return
            if cand is None:
                continue
            rid, n_pages = cand
            donor = None
            for d in sorted((x for x in ws if x is not src),
                            key=lambda x: -x.load.get("free_pages", 0)):
                try:
                    if self._call(d, "can_accept", {"n_pages": n_pages}):
                        donor = d
                        break
                except TransportError:
                    continue
            if donor is None:
                continue
            try:
                snap = self._call(src, "snapshot_slot", {"rid": rid})
            except (RemoteError, TransportError):
                return
            try:
                self._call(donor, "inject_slot", {"snap": snap})
                self._owner[rid] = donor
            except (RemoteError, TransportError):
                # donor raced out of room or died holding nothing: the
                # source just freed these pages, so it takes the slot back
                try:
                    self._call(src, "inject_slot", {"snap": snap})
                except (RemoteError, TransportError):
                    # source gone too — the snapshot in hand IS a fresh
                    # checkpoint: stash it and let failover place it
                    self._ckpt[rid] = snap.to_bytes()
                    if self._owner.get(rid) is not None:
                        self._owner.pop(rid, None)
                    req = self._reqs.get(rid)
                    if req is not None and not req.done:
                        self.fleet.requests_replayed += 1
                        if not self._recover_from_snapshot(
                                rid, self._ckpt[rid]):
                            self._replay_until[rid] = len(req.out_tokens)
                            self._backlog.append(self._clone(req))
                return
            self.migrations += 1
            return   # at most one move per step

    # ------------------------------------------------------------------
    # drive helpers + stats (the Router surface)
    # ------------------------------------------------------------------
    def stream(self, max_steps: int = 10_000):
        steps = 0
        while self.has_work and steps < max_steps:
            yield from self.step()
            steps += 1

    def run(self, max_steps: int = 10_000) -> list[EngineStats]:
        steps = 0
        while self.has_work and steps < max_steps:
            self.step()
            steps += 1
        return self.stats

    @property
    def workers(self) -> list[WorkerHandle]:
        return list(self._active)

    @property
    def spares_left(self) -> int:
        return len(self._spares)

    @property
    def stats(self) -> list[EngineStats]:
        """Per-worker EngineStats (last known for DEAD/SUSPECT workers),
        index-aligned with ``workers``."""
        out = []
        for w in self._active:
            if w.state == ALIVE and w.pending is None:
                try:
                    d = self._call(w, "stats")
                    known = {f.name for f in
                             EngineStats.__dataclass_fields__.values()}
                    w.last_stats = EngineStats(
                        **{k: v for k, v in d.items() if k in known})
                except (TransportError, RemoteError):
                    pass
            out.append(w.last_stats)
        return out

    def summary(self) -> str:
        stats = self.stats
        lines = [f"fleet: {len(self._active)} worker(s) "
                 f"policy={self.policy} spares_left={self.spares_left} "
                 f"migrations={self.migrations} "
                 f"workers_lost={self.fleet.workers_lost} "
                 f"failovers={self.fleet.failovers} replayed req/tok="
                 f"{self.fleet.requests_replayed}"
                 f"/{self.fleet.tokens_replayed} "
                 f"heartbeat_misses={self.fleet.heartbeat_misses}"]
        if self.recovery_s:
            lines[0] += (f" recovery p50="
                         f"{float(np.median(self.recovery_s)):.3f}s")
        for w, s in zip(self._active, stats):
            lines.append(f"  [{w.name} {w.state}] {s.summary()}")
        return "\n".join(lines)

    def close(self) -> None:
        """Shut every worker down (best effort) and drop the checkpoint
        dir if this router created it."""
        for w in self._active + self._spares:
            if w.alive:
                try:
                    w.transport.call("shutdown", {}, 5.0)
                except TransportError:
                    pass
            w.transport.close()
            if hasattr(w.transport, "terminate"):
                w.transport.terminate()
        if self._own_ckpt_dir:
            shutil.rmtree(self.ckpt_dir, ignore_errors=True)
