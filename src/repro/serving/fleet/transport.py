"""Transport seam: how a FleetRouter reaches a worker's EngineCore.

One transport = one worker.  The conversation is strictly
request/reply — the router is the only client and keeps at most ONE
call outstanding per worker — so the surface is three methods::

    send(method, args)     # frame + ship one command
    recv(timeout_s) -> rep # one decoded reply dict (may time out)
    call(method, args, timeout_s)  # send + recv

A reply is ``{"id", "ok", "r" | "e", "load"}``: ``id`` echoes the
command id, ``ok=False`` carries the worker-side exception as
``{"type", "msg"}`` (surfaced here as :class:`RemoteError`), and every
reply piggybacks the worker's load vector — the heartbeat the router's
health tracking runs on.

Failure taxonomy (what the router's health state machine keys on):

* :class:`TransportTimeout` — no reply inside the deadline.  The call
  is still outstanding; ``recv`` again later and the late reply (if the
  worker was merely straggling) is delivered intact.
* :class:`TransportClosed` — the peer is gone (EOF, ECONNRESET, kill):
  grounds for immediate failover.
* :class:`RemoteError` — the worker executed the command and raised; a
  normal application error (e.g. ``OutOfPages`` from ``inject_slot``).

Two implementations:

* :class:`LoopbackTransport` — the worker lives in-process, but every
  command and reply still round-trips through the frame codec
  byte-faithfully, so the fast tests exercise the real wire format.
  Test hooks: ``kill()`` (peer death) and ``stall(n)`` (the next ``n``
  recvs time out, then the buffered replies arrive — a straggler).
* :class:`SocketTransport` — a TCP connection to a subprocess worker
  (see :func:`spawn_worker` / :mod:`repro.serving.fleet.worker`).
"""

from __future__ import annotations

import os
import select
import socket
import subprocess
import sys
import time
from collections import deque
from typing import Optional

from repro.serving.fleet import wire


class TransportError(RuntimeError):
    """Base class for transport-level failures."""


class TransportClosed(TransportError):
    """The peer is gone: EOF, connection reset, or killed."""


class TransportTimeout(TransportError):
    """No reply within the deadline; the call remains outstanding."""


class RemoteError(TransportError):
    """The worker executed the command and raised ``etype``: ``msg``."""

    def __init__(self, etype: str, msg: str):
        super().__init__(f"{etype}: {msg}")
        self.etype = etype


def unwrap(rep: dict):
    """Reply dict → result, raising :class:`RemoteError` on ``ok=False``."""
    if rep.get("ok"):
        return rep.get("r")
    e = rep.get("e") or {}
    raise RemoteError(e.get("type", "Error"), e.get("msg", "?"))


class Transport:
    def send(self, method: str, args: dict) -> None:
        raise NotImplementedError

    def recv(self, timeout_s: Optional[float] = None) -> dict:
        raise NotImplementedError

    def call(self, method: str, args: dict | None = None,
             timeout_s: Optional[float] = None) -> dict:
        self.send(method, args or {})
        return self.recv(timeout_s)

    def close(self) -> None:
        pass


class LoopbackTransport(Transport):
    """In-process worker behind the full wire codec (byte-faithful)."""

    def __init__(self, host, max_payload: int = wire.MAX_PAYLOAD):
        self.host = host
        self._alive = True
        self._replies: deque[bytes] = deque()   # framed, undelivered
        self._stalled = 0
        self._rx = wire.FrameDecoder(max_payload)
        self._tx = wire.FrameDecoder(max_payload)
        self._next_id = 0
        self.max_payload = max_payload

    def send(self, method: str, args: dict) -> None:
        if not self._alive:
            raise TransportClosed("loopback worker is gone")
        msg = {"id": self._next_id, "m": method, "a": args}
        self._next_id += 1
        # the command round-trips through frame + codec before the worker
        # sees it — the loopback's whole point is byte-faithfulness
        payloads = self._tx.feed(wire.frame(wire.encode(msg),
                                            self.max_payload))
        assert len(payloads) == 1
        rep = self.host.handle(wire.decode(payloads[0]))
        self._replies.append(wire.frame(wire.encode(rep), self.max_payload))

    def recv(self, timeout_s: Optional[float] = None) -> dict:
        if not self._alive:
            raise TransportClosed("loopback worker is gone")
        if self._stalled > 0:
            self._stalled -= 1
            raise TransportTimeout("injected straggle")
        if not self._replies:
            raise TransportTimeout("no reply outstanding")
        payloads = self._rx.feed(self._replies.popleft())
        assert len(payloads) == 1
        return wire.decode(payloads[0])

    # ------------------------------------------------------ test hooks
    def kill(self) -> None:
        """Simulate worker death: every later send/recv raises
        :class:`TransportClosed` (undelivered replies are lost)."""
        self._alive = False

    def stall(self, n: int) -> None:
        """The next ``n`` recvs time out; replies stay buffered and are
        delivered after — a recoverable straggler."""
        self._stalled += n

    def close(self) -> None:
        self._alive = False


class SocketTransport(Transport):
    """TCP connection to a subprocess worker.  ``proc`` (when this side
    spawned the worker) is exposed so chaos tests can SIGKILL it."""

    def __init__(self, sock: socket.socket,
                 proc: Optional[subprocess.Popen] = None,
                 max_payload: int = wire.MAX_PAYLOAD):
        self.sock = sock
        self.proc = proc
        self._rx = wire.FrameDecoder(max_payload)
        self._ready: deque[bytes] = deque()
        self._next_id = 0
        self._closed = False
        self.max_payload = max_payload
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    @property
    def pid(self) -> Optional[int]:
        return self.proc.pid if self.proc is not None else None

    def send(self, method: str, args: dict) -> None:
        if self._closed:
            raise TransportClosed("transport closed")
        msg = {"id": self._next_id, "m": method, "a": args}
        self._next_id += 1
        try:
            self.sock.sendall(wire.frame(wire.encode(msg), self.max_payload))
        except OSError as e:
            raise TransportClosed(f"send failed: {e}") from e

    def recv(self, timeout_s: Optional[float] = None) -> dict:
        if self._closed:
            raise TransportClosed("transport closed")
        deadline = (None if timeout_s is None
                    else time.monotonic() + timeout_s)
        while not self._ready:
            if deadline is None:
                self.sock.settimeout(None)
            else:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TransportTimeout(
                        f"no reply within {timeout_s:.3f}s")
                self.sock.settimeout(remaining)
            try:
                data = self.sock.recv(1 << 16)
            except socket.timeout as e:   # subclass of OSError: catch first
                raise TransportTimeout(
                    f"no reply within {timeout_s:.3f}s") from e
            except OSError as e:
                raise TransportClosed(f"recv failed: {e}") from e
            if not data:
                raise TransportClosed("worker closed the connection")
            # partial frames stay buffered in the decoder across recvs
            self._ready.extend(self._rx.feed(data))
        return wire.decode(self._ready.popleft())

    def close(self) -> None:
        self._closed = True
        try:
            self.sock.close()
        except OSError:
            pass

    def terminate(self, timeout_s: float = 5.0) -> None:
        """Close the connection and reap the subprocess (if ours)."""
        self.close()
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            try:
                self.proc.wait(timeout=timeout_s)
            except subprocess.TimeoutExpired:
                pass


READY_PREFIX = "FLEET-WORKER-READY port="


def spawn_worker(arch: str, *, reduced: bool = True, max_batch: int = 4,
                 max_seq: int = 128, page_size: int = 16, eos_id: int = -1,
                 num_pages: int = 0, kv_tier: str = "none",
                 overlap: bool = False, policy: str = "fcfs",
                 chunk_prefill: int = 0, seed: int = 0,
                 kv_dtype: str = "bf16", quant: str = "none",
                 startup_timeout_s: float = 300.0) -> SocketTransport:
    """Launch ``python -m repro.serving.fleet.worker`` and connect to it.

    The worker rebuilds its params deterministically from
    ``(arch, reduced, seed, max_seq)`` — ``init_params`` is deterministic
    on a fixed backend, so nothing heavy ships over the wire and every
    worker of a fleet holds bit-identical weights.
    """
    import repro
    # repro is a namespace package (no __init__.py): locate src/ via
    # __path__, not __file__ (which is None)
    src_dir = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.serving.fleet.worker",
           "--arch", arch, "--reduced", str(int(reduced)),
           "--port", "0", "--max-batch", str(max_batch),
           "--max-seq", str(max_seq), "--page-size", str(page_size),
           "--eos-id", str(eos_id), "--num-pages", str(num_pages),
           "--kv-tier", kv_tier, "--policy", policy,
           "--chunk-prefill", str(chunk_prefill), "--seed", str(seed),
           "--kv-dtype", kv_dtype, "--quant", quant]
    if overlap:
        cmd.append("--overlap")
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, env=env)
    deadline = time.monotonic() + startup_timeout_s
    lines: list[str] = []
    port = None
    while port is None:
        if proc.poll() is not None:
            raise TransportError(
                f"worker exited with {proc.returncode} before ready:\n"
                + "".join(lines[-20:]))
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            proc.kill()
            raise TransportError(
                f"worker not ready within {startup_timeout_s}s:\n"
                + "".join(lines[-20:]))
        r, _, _ = select.select([proc.stdout], [], [], min(remaining, 1.0))
        if not r:
            continue
        line = proc.stdout.readline()
        if not line:
            continue
        lines.append(line)
        if line.startswith(READY_PREFIX):
            port = int(line[len(READY_PREFIX):].strip())
    sock = socket.create_connection(("127.0.0.1", port), timeout=30.0)
    return SocketTransport(sock, proc=proc)
