"""Fleet worker: one EngineCore behind the wire protocol.

:class:`WorkerHost` is the transport-independent half — it maps decoded
command messages onto the EngineCore command surface and wraps every
reply with the worker's load vector (the heartbeat).  The loopback
transport calls ``handle`` directly; :func:`serve` is the socket server
loop around it; :func:`main` is the standalone entry point::

    python -m repro.serving.fleet.worker --arch smollm-360m --port 0

The worker prints ``FLEET-WORKER-READY port=<n>`` once it is listening
(``--port 0`` picks an ephemeral port), then serves one router
connection until EOF or a ``shutdown`` command.

Command surface (mirrors EngineCore; see fleet/README.md for the wire
protocol):

==================  ====================================================
``ping``            liveness probe; returns the worker name
``add_request``     ``{"req": Request}`` → True
``abort``           ``{"rid"}`` → bool (terminal event follows via step)
``step``            one admit+decode round → ``{"events": [...]}``
``snapshot_slot``   ``{"rid"}`` → SlotSnapshot (slot released: migration)
``inject_slot``     ``{"snap": SlotSnapshot}`` → slot index
``checkpoint``      non-destructive snapshots of every active slot →
                    ``{"snaps": {rid: bytes}}`` (the failover souce)
``migration_candidate`` / ``can_accept`` — the router's migration probes
``stats``           EngineStats as a field map
``shutdown``        stop serving after this reply
==================  ====================================================

Params are rebuilt locally from ``(arch, reduced, seed, max_seq)`` via
``init_params`` — deterministic on a fixed backend, so every worker of
a fleet holds bit-identical weights without shipping them.
"""

from __future__ import annotations

import argparse
import dataclasses
import socket

from repro.serving.core import EngineCore
from repro.serving.fleet import wire


class WorkerHost:
    """One EngineCore behind the command protocol (transport-agnostic)."""

    def __init__(self, core: EngineCore, name: str = "worker"):
        self.core = core
        self.name = name
        self.shutdown_requested = False

    # ------------------------------------------------------------------
    def load(self) -> dict:
        """The load vector piggybacked on every reply — what the router
        routes and health-checks on."""
        c = self.core
        return {"queue_depth": c.queue_depth, "n_active": c.n_active,
                "n_free_slots": c.n_free_slots, "free_pages": c.free_pages,
                "page_starved": c.page_starved, "has_work": c.has_work}

    def handle(self, msg) -> dict:
        """Decoded command message → reply dict (ready to encode)."""
        if not isinstance(msg, dict) or "m" not in msg:
            return {"id": -1, "ok": False,
                    "e": {"type": "ProtocolError",
                          "msg": f"malformed command {type(msg).__name__}"},
                    "load": self.load()}
        try:
            rep = {"id": msg.get("id", -1), "ok": True,
                   "r": self._dispatch(msg["m"], msg.get("a") or {})}
        except Exception as e:   # ships to the router as a RemoteError
            rep = {"id": msg.get("id", -1), "ok": False,
                   "e": {"type": type(e).__name__, "msg": str(e)}}
        rep["load"] = self.load()
        return rep

    def _dispatch(self, method: str, args: dict):
        core = self.core
        if method == "ping":
            return self.name
        if method == "add_request":
            core.add_request(args["req"])
            return True
        if method == "abort":
            return core.abort_request(args["rid"])
        if method == "step":
            # mirror Router.step's per-replica round: advance only with
            # work, but always drain (an abort's terminal may be queued)
            if core.has_work:
                core._advance()
            return {"events": core.drain_outputs()}
        if method == "snapshot_slot":
            return core.snapshot_slot(args["rid"])
        if method == "inject_slot":
            return core.inject_slot(args["snap"])
        if method == "checkpoint":
            return {"snaps": self._checkpoint()}
        if method == "migration_candidate":
            return core.migration_candidate()
        if method == "can_accept":
            return core.can_accept(args["n_pages"])
        if method == "stats":
            return {f.name: getattr(core.stats, f.name)
                    for f in dataclasses.fields(core.stats)}
        if method == "shutdown":
            self.shutdown_requested = True
            return True
        raise ValueError(f"unknown fleet command {method!r}")

    def _checkpoint(self) -> dict:
        """Non-destructive snapshot of every active slot, serialized —
        what the router persists and replays from on failover."""
        snaps = {}
        if self.core.mode != "continuous":
            return snaps
        for req in list(self.core.slots):
            if req is not None:
                snaps[req.rid] = self.core.snapshot_slot(
                    req.rid, release=False).to_bytes()
        return snaps


def serve(host: WorkerHost, port: int = 0,
          max_payload: int = wire.MAX_PAYLOAD) -> None:
    """Blocking socket server: one router connection, frames in/out."""
    srv = socket.create_server(("127.0.0.1", port))
    print(f"FLEET-WORKER-READY port={srv.getsockname()[1]}", flush=True)
    conn, _ = srv.accept()
    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    dec = wire.FrameDecoder(max_payload)
    try:
        while not host.shutdown_requested:
            data = conn.recv(1 << 16)
            if not data:
                break
            for payload in dec.feed(data):
                try:
                    msg = wire.decode(payload)
                except wire.ProtocolError as e:
                    rep = {"id": -1, "ok": False,
                           "e": {"type": "ProtocolError", "msg": str(e)},
                           "load": host.load()}
                else:
                    rep = host.handle(msg)
                conn.sendall(wire.frame(wire.encode(rep), max_payload))
    finally:
        conn.close()
        srv.close()


def build_core(arch: str, *, reduced: bool = True, max_batch: int = 4,
               max_seq: int = 128, page_size: int = 16, eos_id: int = -1,
               num_pages: int = 0, kv_tier: str = "none",
               overlap: bool = False, policy: str = "fcfs",
               chunk_prefill: int = 0, seed: int = 0,
               kv_dtype: str = "bf16", quant: str = "none") -> EngineCore:
    import jax

    from repro.configs.registry import get_arch
    from repro.models import model as model_lib
    from repro.serving.scheduler import make_scheduler

    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(seed),
                                   max_seq=max_seq)
    if quant != "none":
        # quantize AFTER the deterministic init so every worker of a fleet
        # derives bit-identical quantized weights from (arch, seed)
        from repro.quant.convert import quantize_params
        params = quantize_params(params, mode=quant)
    return EngineCore(
        cfg, params, max_batch=max_batch, max_seq=max_seq, eos_id=eos_id,
        page_size=page_size, num_pages=num_pages or None, kv_tier=kv_tier,
        overlap=overlap, kv_dtype=kv_dtype,
        scheduler=make_scheduler(policy, chunk_tokens=chunk_prefill or None))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral; the bound port is announced on "
                         "stdout as FLEET-WORKER-READY port=<n>")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--num-pages", type=int, default=0)
    ap.add_argument("--kv-tier", default="none", choices=("none", "flash"))
    ap.add_argument("--kv-dtype", default="bf16", choices=("bf16", "int8"))
    ap.add_argument("--quant", default="none",
                    choices=("none", "w8a8", "w4a16"))
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--policy", default="fcfs")
    ap.add_argument("--chunk-prefill", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0,
                    help="param init seed — must match the fleet's")
    ap.add_argument("--name", default="worker")
    args = ap.parse_args(argv)
    core = build_core(
        args.arch, reduced=bool(args.reduced), max_batch=args.max_batch,
        max_seq=args.max_seq, page_size=args.page_size, eos_id=args.eos_id,
        num_pages=args.num_pages, kv_tier=args.kv_tier,
        overlap=args.overlap, policy=args.policy,
        chunk_prefill=args.chunk_prefill, seed=args.seed,
        kv_dtype=args.kv_dtype, quant=args.quant)
    serve(WorkerHost(core, name=args.name), port=args.port)


if __name__ == "__main__":
    main()
