"""Fleet serving: EngineCore workers behind a wire protocol.

The step from "multi-replica" to "fleet" (ROADMAP): the in-process
Router seam goes across process boundaries.  Three pieces:

* :mod:`repro.serving.fleet.wire` — the length-prefixed, versioned
  binary codec every command and reply travels through, including the
  :class:`repro.serving.core.SlotSnapshot` byte format.
* :mod:`repro.serving.fleet.transport` — the transport seam: an
  in-process loopback (byte-faithful — every payload round-trips
  through the codec) and a socket transport driving real subprocess
  workers (:mod:`repro.serving.fleet.worker`).
* :mod:`repro.serving.fleet.router` — the FleetRouter: routing, health
  detection (heartbeat misses / reply deadlines), periodic snapshot
  checkpoints, and failover that re-dispatches a dead worker's requests
  with a bit-identical recovered token stream.
"""

from repro.serving.fleet.router import FleetRouter  # noqa: F401
from repro.serving.fleet.transport import (  # noqa: F401
    LoopbackTransport, RemoteError, SocketTransport, TransportClosed,
    TransportError, TransportTimeout, spawn_worker)
from repro.serving.fleet.wire import FrameDecoder, ProtocolError  # noqa: F401
