"""Router: N EngineCore replicas behind one request stream.

The middle layer of the serving split (see ``serving/engine.py`` and the
ROADMAP design note).  The router owns the replicas, decides WHERE a
request runs, and keeps the fleet balanced::

      submit(req) ── routing policy ──► EngineCore[k].add_request
      step()      ── every replica  ──► merged list[RequestOutput]
      page-starved replica? ──► snapshot_slot ──► inject_slot on a donor

Routing policies (``policy=``):

* ``round_robin`` — cycle through replicas; the stateless baseline.
* ``least_loaded`` — send to the replica with the smallest
  (queue depth + active slots), discounted by the fraction of the prompt
  the replica's prefix cache could serve without prefilling
  (``EngineCore.prefix_hit_estimate``, 0 when prefix caching is off) and
  breaking ties toward the most free pages; the sensible default under
  heterogeneous request sizes.
* ``session_affinity`` — the replica whose prefix cache already holds the
  most of this request's prompt wins outright (that is where the session's
  pages physically live); with no cached pages anywhere — or prefix
  caching off — it falls back to hashing ``Request.session`` so a
  conversation keeps landing on one replica (``session=None`` falls back
  to round robin).

Request ids must be GLOBALLY unique across the fleet — the router
enforces it at submit, and :class:`repro.serving.client.ServingClient`
is the single place that allocates them (and derives sampling seeds from
them, so no two replicas ever reuse a sample stream).

Slot migration: after each step, if a replica is page-starved (a
suspended slot waiting on pages, or a backlogged queue it cannot admit)
and another replica has headroom (free slot + the snapshot's pages + one
page of growth room), the router drains the starved replica's candidate
slot via ``snapshot_slot`` and resumes it on the donor via
``inject_slot``.  The snapshot rides the tiered-KV swap seam
(``swap_out_pages`` / ``swap_in_pages`` / ``checkpoint_slot_state``), so
a migrated request's decode logits are bit-identical to the unmigrated
run — for every paged family, pinned by tests/test_router.py.  At most
one migration per router step keeps the balancing pressure bounded.
"""

from __future__ import annotations

import copy
import zlib
from typing import Iterable, Optional

from repro.serving.core import EngineCore, EngineStats, Request, \
    RequestOutput

ROUTE_POLICIES = ("round_robin", "least_loaded", "session_affinity")


class Router:
    """Owns N homogeneous :class:`EngineCore` replicas.

    Replicas must share family, page size, max_seq, and eos id — a
    migrated snapshot must mean the same thing everywhere (enforced at
    construction).  Params may differ in principle (the router never
    looks at them) but identical params are what makes migration
    bit-identical; ``Router.build`` constructs replicas from one
    (cfg, params) pair, which is the intended use.
    """

    def __init__(self, cores: Iterable[EngineCore],
                 policy: str = "round_robin", migrate: bool = True):
        self.cores: list[EngineCore] = list(cores)
        if not self.cores:
            raise ValueError("router needs at least one replica")
        if policy not in ROUTE_POLICIES:
            raise ValueError(
                f"unknown routing policy {policy!r}; pick from "
                f"{ROUTE_POLICIES}")
        head = self.cores[0]
        for c in self.cores[1:]:
            same = (c.cfg.family == head.cfg.family
                    and c.max_seq == head.max_seq
                    and c.eos_id == head.eos_id
                    and c.mode == head.mode
                    and getattr(c, "page_size", None)
                    == getattr(head, "page_size", None)
                    and getattr(c, "kv_dtype", None)
                    == getattr(head, "kv_dtype", None))
            if not same:
                raise ValueError(
                    "replicas must be homogeneous "
                    "(family/max_seq/eos_id/mode/page_size/kv_dtype)")
        self.policy = policy
        self.migrate = migrate and head.mode == "continuous"
        self.migrations = 0
        self._rr = 0
        self._home: dict[int, EngineCore] = {}   # rid -> owning replica
        # duplicate-id guard with bounded memory: live rids are in _home,
        # finished ones are covered by the high-water mark (ServingClient
        # allocates strictly increasing ids; direct submitters must too)
        self._rid_hwm = -1

    @classmethod
    def build(cls, cfg, params, replicas: int = 1,
              policy: str = "round_robin", migrate: bool = True,
              **engine_kw) -> "Router":
        """N identical replicas over one (cfg, params) pair.  The jitted
        step functions are shared per-config, so extra replicas cost slot
        bookkeeping and KV pool memory, not compilations."""
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        cores = []
        for _ in range(replicas):
            kw = dict(engine_kw)
            # stateful schedulers (DRR's deficit ring, EDF/priority are
            # stateless but uniform treatment is free) must not be shared:
            # interleaved admit() calls from different replicas would
            # corrupt their per-queue bookkeeping
            if kw.get("scheduler") is not None:
                kw["scheduler"] = copy.deepcopy(kw["scheduler"])
            cores.append(EngineCore(cfg, params, **kw))
        return cls(cores, policy=policy, migrate=migrate)

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def _pick(self, req: Request) -> EngineCore:
        if self.policy == "session_affinity" and req.session is not None:
            # "the session's replica" is wherever its KV pages actually
            # live: the largest cached-prefix estimate wins (max is stable,
            # so equal estimates keep the lowest replica — deterministic)
            hits = [c.prefix_hit_estimate(req) for c in self.cores]
            if max(hits) > 0:
                return self.cores[hits.index(max(hits))]
            # no replica holds anything (cold session / prefix off):
            # deterministic across processes (python's str hash is salted)
            h = zlib.crc32(str(req.session).encode())
            return self.cores[h % len(self.cores)]
        if self.policy == "least_loaded":
            # discount load by the prompt fraction already cached: a busier
            # replica that can skip the whole prefill is often the cheaper
            # place to land (hit fraction is in [0, 1], so it acts as a
            # tie-shader between integer load levels, not an override)
            len0 = max(1, len(req.prompt))
            return min(self.cores,
                       key=lambda c: (c.queue_depth + c.n_active
                                      - c.prefix_hit_estimate(req) / len0,
                                      -c.free_pages))
        core = self.cores[self._rr % len(self.cores)]
        self._rr += 1
        return core

    def submit(self, req: Request) -> EngineCore:
        """Route one request; returns the replica it landed on."""
        if req.rid in self._home or req.rid <= self._rid_hwm:
            raise ValueError(
                f"request id {req.rid} already submitted — ids must be "
                f"globally unique and strictly increasing across replicas "
                f"(use ServingClient, which allocates them)")
        core = self._pick(req)
        core.add_request(req)
        self._rid_hwm = max(self._rid_hwm, req.rid)
        self._home[req.rid] = core
        return core

    def abort(self, rid: int) -> bool:
        core = self._home.get(rid)
        return core is not None and core.abort_request(rid)

    def replica_of(self, rid: int) -> Optional[int]:
        """Index of the replica currently holding ``rid`` (None once it
        finished or was never submitted)."""
        core = self._home.get(rid)
        return None if core is None else self.cores.index(core)

    # ------------------------------------------------------------------
    # fleet stepping + migration
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return any(c.has_work for c in self.cores)

    def step(self) -> list[RequestOutput]:
        """One round across the fleet: every replica with work advances,
        then at most one starved→donor slot migration rebalances pages."""
        outs: list[RequestOutput] = []
        for core in self.cores:
            if core.has_work:
                # _advance + drain rather than core.step(): identical for
                # an EngineCore, but also correct for the ServingEngine
                # shim (whose step() keeps the legacy bool return), so any
                # EngineCore subclass can serve as a replica
                core._advance()
            # an idle replica can still hold pending events: an abort of
            # its last request leaves the terminal event queued
            outs.extend(core.drain_outputs())
        if self.migrate and len(self.cores) > 1:
            self._maybe_migrate()
        for e in outs:
            if e.finished:
                self._home.pop(e.rid, None)
        return outs

    def _maybe_migrate(self) -> None:
        for src in self.cores:
            if not src.page_starved:
                continue
            cand = src.migration_candidate()
            if cand is None:
                continue
            rid, n_pages = cand
            donors = [c for c in self.cores
                      if c is not src and c.can_accept(n_pages)]
            if not donors:
                continue
            donor = max(donors, key=lambda c: (c.free_pages,
                                               c.n_free_slots))
            snap = src.snapshot_slot(rid)
            try:
                donor.inject_slot(snap)
                self._home[rid] = donor
            except Exception:
                # donor raced out of room between the check and the inject:
                # the source just freed the snapshot's pages, so it can
                # always take its own slot back — the request is never lost
                src.inject_slot(snap)
                raise
            self.migrations += 1
            return  # at most one move per step

    # ------------------------------------------------------------------
    # drive helpers (mirror the EngineCore surface)
    # ------------------------------------------------------------------
    def stream(self, max_steps: int = 10_000):
        steps = 0
        while self.has_work and steps < max_steps:
            yield from self.step()
            steps += 1

    def run(self, max_steps: int = 10_000) -> list[EngineStats]:
        steps = 0
        while self.has_work and steps < max_steps:
            self.step()
            steps += 1
        return self.stats

    @property
    def stats(self) -> list[EngineStats]:
        """Per-replica stats, index-aligned with ``cores``."""
        return [c.stats for c in self.cores]

    def summary(self) -> str:
        lines = [f"router: {len(self.cores)} replica(s) "
                 f"policy={self.policy} migrations={self.migrations}"]
        for k, c in enumerate(self.cores):
            lines.append(f"  [replica {k}] {c.stats.summary()}")
        return "\n".join(lines)
