"""ServingClient: the user-facing handle over a Router of EngineCores.

Top layer of the serving split.  A frontend (CLI driver, notebook, or a
future network server) talks ONLY to this surface::

    client = ServingClient(cfg, params, replicas=2, route="least_loaded",
                           max_batch=4, max_seq=128)
    h = client.submit([1, 2, 3], max_new_tokens=16,
                      sampling=SamplingParams(temperature=0.8))
    for tok in h.tokens():          # per-request incremental stream
        ...
    for out in client.stream():     # or: merged fleet-wide event stream
        ...
    client.abort(h.rid)

The client is the SINGLE place global request ids are allocated — and
therefore the single place sampling seeds are derived (``seed_base +
rid`` when the caller didn't pin one).  The old per-driver ``base +
local-rid`` scheme silently collides the moment two replicas each hand
out rid 0; routing through the client makes the id, and every stream
keyed on it, globally unique by construction.

``submit`` is non-blocking: it routes the request and returns a
:class:`RequestHandle`.  Progress happens when somebody pumps the fleet
— ``handle.tokens()`` / ``handle.result()`` / ``client.stream()`` /
``client.run()`` all do — and events are fanned out to every live
handle, so interleaved consumers each see exactly their own stream.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator, Optional

from repro.serving.core import Request, RequestOutput
from repro.serving.router import Router
from repro.serving.scheduler import SamplingParams


class RequestHandle:
    """One submitted request's live view: buffered events, incremental
    token iteration, and abort."""

    def __init__(self, client: "ServingClient", req: Request):
        self._client = client
        self.request = req
        self.rid = req.rid
        self.events: deque[RequestOutput] = deque()
        self.finished = False
        self.finish_reason: Optional[str] = None

    def _push(self, ev: RequestOutput) -> None:
        self.events.append(ev)
        if ev.finished:
            self.finished = True
            self.finish_reason = ev.finish_reason

    def tokens(self) -> Iterator[int]:
        """Yield this request's token ids as they are generated, pumping
        the fleet while other requests make progress too."""
        while True:
            while self.events:
                ev = self.events.popleft()
                if ev.token is not None:
                    yield ev.token
            if self.finished:
                return
            # a pump can legitimately produce zero events (a chunked-prefill
            # step emits nothing) — only an IDLE fleet ends the wait
            if not self._client.pump() and not self._client.has_work:
                return

    def result(self) -> Request:
        """Drive the fleet until this request finishes; returns it."""
        for _ in self.tokens():
            pass
        return self.request

    def abort(self) -> bool:
        return self._client.abort(self.rid)


class ServingClient:
    """User-facing serving surface over N engine replicas.

    Either wrap an existing router (``router=`` — an in-process
    :class:`Router` or a :class:`repro.serving.fleet.router.FleetRouter`,
    both speak the same surface) or let the client build one:
    ``replicas`` / ``route`` / ``migrate`` plus any
    :class:`repro.serving.core.EngineCore` keyword (``max_batch``,
    ``max_seq``, ``scheduler``, ``kv_tier``, ...).  ``workers=N`` builds
    a loopback FleetRouter instead — N workers behind the fleet wire
    codec with ``spares=K`` hot spares and snapshot-based failover; for
    subprocess workers build ``FleetRouter.build_socket(...)`` yourself
    and pass it as ``router=`` (socket workers rebuild params from the
    arch name, which the client does not assume it knows).
    """

    def __init__(self, cfg=None, params=None, *, router: Router = None,
                 replicas: int = 1, route: str = "round_robin",
                 migrate: bool = True, seed_base: int = 0,
                 workers: int = 0, transport: str = "loopback",
                 spares: int = 0, **engine_kw):
        if router is None:
            if cfg is None or params is None:
                raise ValueError("pass (cfg, params) or a prebuilt router=")
            if workers:
                if transport != "loopback":
                    raise ValueError(
                        "ServingClient builds loopback fleets only; for "
                        "socket workers use FleetRouter.build_socket(...) "
                        "and pass router=")
                from repro.serving.fleet.router import FleetRouter
                router = FleetRouter.build_loopback(
                    cfg, params, workers=workers, spares=spares,
                    policy=route, migrate=migrate, **engine_kw)
            else:
                router = Router.build(cfg, params, replicas=replicas,
                                      policy=route, migrate=migrate,
                                      **engine_kw)
        self.router = router
        self.seed_base = seed_base
        self._next_rid = 0
        self._handles: dict[int, RequestHandle] = {}

    # ------------------------------------------------------------------
    def submit(self, prompt: list[int], max_new_tokens: int = 32,
               priority: int = 0, deadline_s: Optional[float] = None,
               session: Optional[str] = None,
               sampling: Optional[SamplingParams] = None,
               arrival_s: Optional[float] = None) -> RequestHandle:
        """Route one request; returns its handle (non-blocking).

        The rid is allocated here, globally unique across replicas; a
        stochastic request without a pinned seed gets ``seed_base + rid``
        so no two requests — wherever they land — share a sample stream.
        """
        rid = self._next_rid
        self._next_rid += 1
        if (sampling is not None and sampling.temperature > 0.0
                and sampling.seed is None):
            sampling = dataclasses.replace(sampling,
                                           seed=self.seed_base + rid)
        req = Request(rid=rid, prompt=list(prompt),
                      max_new_tokens=max_new_tokens, priority=priority,
                      deadline_s=deadline_s, session=session,
                      sampling=sampling, arrival_s=arrival_s)
        self.router.submit(req)
        handle = RequestHandle(self, req)
        self._handles[rid] = handle
        return handle

    def abort(self, rid: int) -> bool:
        return self.router.abort(rid)

    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return self.router.has_work

    def pump(self) -> list[RequestOutput]:
        """One fleet step; fans events out to their handles and returns
        them.  With an idle fleet this still drains straggler events (an
        abort's terminal) and then returns []."""
        outs = self.router.step()
        for ev in outs:
            h = self._handles.get(ev.rid)
            if h is not None:
                h._push(ev)
                if ev.finished:
                    del self._handles[ev.rid]
        return outs

    def stream(self, max_steps: int = 10_000) -> Iterator[RequestOutput]:
        """Merged fleet-wide event stream (every request, every replica),
        until the fleet drains."""
        steps = 0
        while steps < max_steps:
            outs = self.pump()
            yield from outs
            if not outs and not self.router.has_work:
                return
            steps += 1

    def run(self, max_steps: int = 10_000) -> None:
        """Drive the fleet to completion (handles stay consumable)."""
        for _ in self.stream(max_steps):
            pass

    def summary(self) -> str:
        return self.router.summary()
