"""Serving engine: continuous-batched decode with straggler mitigation hooks.

The engine owns a fixed-size slot table (the batch). Requests enter a queue,
claim free slots, prefill once, and decode step-by-step; finished slots free
immediately (continuous batching — the single-batch edge scenario of the
paper is batch=1, the server scenario batches up to ``max_batch``).

Fault hooks: per-step heartbeat timestamps; a pluggable ``watchdog`` sees
(step, wall_time) and may trigger re-dispatch — tests inject artificial
stragglers through it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving import sampler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    straggler_events: int = 0
    wall_decode_s: float = 0.0


class ServingEngine:
    """Single-host engine over the functional model API.

    For the multi-chip case the jitted step functions are the pjit'd ones
    from launch/dryrun.build_step; here the defaults run on local devices.
    """

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 512, eos_id: int = 2,
                 watchdog: Optional[Callable[[int, float], bool]] = None,
                 straggler_timeout_s: float = 5.0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.watchdog = watchdog
        self.straggler_timeout_s = straggler_timeout_s
        self.stats = EngineStats()
        self.queue: list[Request] = []
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.slot_pos = jnp.zeros((max_batch,), jnp.int32)
        self.cache = model_lib.init_cache(cfg, max_batch, max_seq)
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self._decode = jax.jit(
            lambda p, t, c: model_lib.decode_step(p, cfg, t, c))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Claim free slots.  NOTE: the per-slot cache model here decodes one
        shared length cursor (cache["len"]); to keep admission simple the
        engine admits waves — new requests only start when the batch drains.
        A paged per-slot KV cache is the natural extension."""
        if any(s is not None for s in self.slots):
            return
        if not self.queue:
            return
        wave = self.queue[:self.max_batch]
        self.queue = self.queue[self.max_batch:]
        # right-align prompts to a common prefill length
        plen = max(len(r.prompt) for r in wave)
        toks = jnp.array(
            [([0] * (plen - len(r.prompt)) + r.prompt) for r in wave]
            + [[0] * plen] * (self.max_batch - len(wave)), jnp.int32)
        self.cache = model_lib.init_cache(self.cfg, self.max_batch,
                                          self.max_seq)
        extras = self._extras(self.max_batch)
        logits, self.cache = model_lib.prefill(self.params, self.cfg, toks,
                                               self.cache, extras)
        self.stats.prefills += 1
        tok = sampler.greedy(logits)
        self.last_token = tok
        for i, r in enumerate(wave):
            self.slots[i] = r
            r.out_tokens.append(int(tok[i]))

    def _extras(self, batch: int) -> dict:
        cfg = self.cfg
        if cfg.family == "vlm":
            return {"vision_embeds": jnp.zeros(
                (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "audio":
            return {"frames": jnp.zeros(
                (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)}
        return {}

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One decode step over the active batch. Returns True if any work."""
        self._admit()
        if all(s is None for s in self.slots):
            return False
        t0 = time.monotonic()
        logits, self.cache = self._decode(self.params, self.last_token,
                                          self.cache)
        dt = time.monotonic() - t0
        if self.watchdog is not None and self.watchdog(
                self.stats.decode_steps, dt):
            # straggler detected: re-issue the step (idempotent on donated
            # caches because we retained the pre-step token; in multi-host
            # deployments this re-dispatches to a hot-spare shard)
            self.stats.straggler_events += 1
            logits, self.cache = self._decode(self.params, self.last_token,
                                              self.cache)
        self.stats.decode_steps += 1
        self.stats.wall_decode_s += dt
        tok = sampler.greedy(logits)
        self.last_token = tok
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            t = int(tok[i])
            r.out_tokens.append(t)
            self.stats.tokens_out += 1
            if t == self.eos_id or len(r.out_tokens) >= r.max_new_tokens \
                    or int(self.cache["len"]) >= self.max_seq - 1:
                r.done = True
                self.slots[i] = None
        return True

    def run(self, max_steps: int = 10_000) -> EngineStats:
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return self.stats
