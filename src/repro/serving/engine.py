"""Serving engine: continuous-batched decode with straggler mitigation hooks.

The engine owns a fixed-size slot table (the batch).  Requests enter a
queue, claim free slots, prefill once, and decode step-by-step; finished
slots free immediately.

Two admission modes:

* ``continuous`` (default where the family supports it) — the paged per-slot
  KV cache (block table into a shared page pool + per-slot length vector)
  lets a new request prefill into ANY free slot while the other slots keep
  decoding: single-slot prefill-into-cache, per-slot masked decode
  attention, page free on completion.  This is the serving lever the
  on-device LLM literature (continuous batching / paged KV à la KVNAND)
  identifies on top of the paper's single-batch NPU+flash scenario.
* ``wave`` — the legacy shared-cursor cache: one length cursor for the whole
  batch, so new requests only start when the batch drains.  Kept for
  recurrent-state families and as the benchmark baseline.

Fault hooks: per-step heartbeat timestamps; a pluggable ``watchdog`` sees
(step, wall_time) and may trigger re-dispatch — tests inject artificial
stragglers through it.  Re-dispatch replays the step from the retained
pre-step cache, so it is idempotent.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving import sampler
from repro.serving.kv_cache import PageAllocator, pages_needed, prefill_bucket


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle timestamps (time.monotonic), filled by the engine
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def admission_wait_s(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def ttft_s(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


def _batch_extras(cfg: ModelConfig, batch: int) -> dict:
    if cfg.family == "vlm":
        return {"vision_embeds": jnp.zeros(
            (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "audio":
        return {"frames": jnp.zeros(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)}
    return {}


# jitted step functions are shared per-config (ModelConfig is frozen and
# hashable) so rebuilding an engine — e.g. the wave-vs-continuous benchmark —
# reuses compile caches instead of retracing everything
@functools.lru_cache(maxsize=None)
def _jit_decode(cfg: ModelConfig):
    return jax.jit(lambda p, t, c: model_lib.decode_step(p, cfg, t, c))


@functools.lru_cache(maxsize=None)
def _jit_decode_paged(cfg: ModelConfig):
    return jax.jit(
        lambda p, t, c, a: model_lib.decode_step_paged(p, cfg, t, c, a))


@functools.lru_cache(maxsize=None)
def _jit_prefill_slots(cfg: ModelConfig):
    return jax.jit(lambda p, toks, tls, c, ss: model_lib.prefill_into_slots(
        p, cfg, toks, tls, c, ss, _batch_extras(cfg, toks.shape[0])))


@functools.lru_cache(maxsize=None)
def _jit_prefill(cfg: ModelConfig):
    return jax.jit(lambda p, toks, c, batch: model_lib.prefill(
        p, cfg, toks, c, _batch_extras(cfg, batch)),
        static_argnames=("batch",))


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    straggler_events: int = 0
    wall_decode_s: float = 0.0
    admitted: int = 0
    completed: int = 0
    mode: str = ""
    # per-request latency samples, appended at completion
    admission_wait_s: list = dataclasses.field(default_factory=list)
    ttft_s: list = dataclasses.field(default_factory=list)
    latency_s: list = dataclasses.field(default_factory=list)

    def percentiles(self, series: str = "latency_s",
                    qs: tuple = (50, 90, 99)) -> dict:
        """Per-request latency percentiles, e.g. ``percentiles("ttft_s")``."""
        xs = getattr(self, series)
        return {f"p{q}": float(np.percentile(xs, q)) if xs else 0.0
                for q in qs}

    def summary(self) -> str:
        lat = self.percentiles("latency_s")
        adm = self.percentiles("admission_wait_s")
        return (f"[{self.mode}] requests={self.completed} "
                f"tokens={self.tokens_out} steps={self.decode_steps} "
                f"latency p50/p90/p99="
                f"{lat['p50']:.3f}/{lat['p90']:.3f}/{lat['p99']:.3f}s "
                f"admission p50/p99={adm['p50']:.3f}/{adm['p99']:.3f}s")


class ServingEngine:
    """Single-host engine over the functional model API.

    For the multi-chip case the jitted step functions are the pjit'd ones
    from launch/dryrun.build_step; here the defaults run on local devices.
    """

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 512, eos_id: int = 2,
                 watchdog: Optional[Callable[[int, float], bool]] = None,
                 straggler_timeout_s: float = 5.0, mode: str = "auto",
                 page_size: int = 16):
        if mode == "auto":
            mode = ("continuous" if model_lib.supports_paged(cfg) else "wave")
        if mode == "continuous" and not model_lib.supports_paged(cfg):
            raise ValueError(
                f"continuous mode needs a paged KV cache; family "
                f"{cfg.family!r} has recurrent state tied to the shared "
                f"cursor — use mode='wave'")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.watchdog = watchdog
        self.straggler_timeout_s = straggler_timeout_s
        self.mode = mode
        self.stats = EngineStats(mode=mode)
        self.queue: list[Request] = []
        self.slots: list[Optional[Request]] = [None] * max_batch
        if mode == "continuous":
            self.page_size = page_size
            self.pages_per_slot = pages_needed(max_seq, page_size)
            self.cache = model_lib.init_paged_cache(
                cfg, max_batch, max_seq, page_size=page_size)
            # hot-loop bookkeeping lives host-side in numpy (block table,
            # last tokens, active mask): mutating them costs nothing and they
            # ride into each jitted call as inputs, so the only per-step
            # device work is the decode step itself
            self.block = np.zeros((max_batch, self.pages_per_slot), np.int32)
            del self.cache["block"]
            self.last_np = np.zeros((max_batch,), np.int32)
            self.allocator = PageAllocator(
                max_batch * self.pages_per_slot + 1)
            self.slot_pages: list[list[int]] = [[] for _ in range(max_batch)]
            self.slot_len: list[int] = [0] * max_batch  # host mirror of lens
            self._decode = _jit_decode_paged(cfg)
            self._prefill_slots = _jit_prefill_slots(cfg)
        else:
            self.cache = model_lib.init_cache(cfg, max_batch, max_seq)
            self.last_token = jnp.zeros((max_batch,), jnp.int32)
            self._decode = _jit_decode(cfg)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if self._cache_len0(req) >= self.max_seq:
            raise ValueError(f"prompt ({len(req.prompt)}) does not fit "
                             f"max_seq ({self.max_seq})")
        req.t_submit = time.monotonic()
        self.queue.append(req)

    def _cache_len0(self, req: Request) -> int:
        """Valid cache length right after prefill (vision tokens included)."""
        extra = (self.cfg.n_vision_tokens if self.cfg.family == "vlm" else 0)
        return len(req.prompt) + extra

    # ------------------------------------------------------------------
    # continuous admission: prefill one request into one free slot while
    # the rest of the batch keeps decoding
    # ------------------------------------------------------------------
    def _finish(self, i: int, req: Request) -> None:
        now = time.monotonic()
        req.done = True
        req.t_done = now
        self.stats.completed += 1
        self.stats.admission_wait_s.append(req.admission_wait_s)
        self.stats.ttft_s.append(req.ttft_s)
        self.stats.latency_s.append(req.latency_s)
        self.slots[i] = None
        if self.mode == "continuous":
            self.allocator.free(self.slot_pages[i])
            self.slot_pages[i] = []
            self.slot_len[i] = 0
            self.block[i] = 0
            self.cache["lens"] = self.cache["lens"].at[i].set(0)

    def _admit_continuous(self) -> None:
        """Prefill every queued request a free slot can take, in ONE batched
        prefill-into-cache pass (right-padded, per-row 0-based positions),
        while occupied slots keep their decode state untouched."""
        free = [i for i in range(self.max_batch) if self.slots[i] is None]
        group = []
        now = time.monotonic()
        while free and self.queue:
            i = free.pop(0)
            req = self.queue.pop(0)
            len0 = self._cache_len0(req)
            pids = self.allocator.alloc(pages_needed(len0, self.page_size))
            self.slot_pages[i] = pids
            self.block[i, :len(pids)] = pids
            group.append((i, req, len0))
        if not group:
            return
        # common bucket for the group, capped so bucket + vision tokens still
        # fits a slot's block-table row (tail-pad pages beyond an allocation
        # fall on the null page, but the row itself must not overflow)
        extra = max(len0 - len(req.prompt) for i, req, len0 in group)
        cap = self.pages_per_slot * self.page_size - extra
        bucket = min(max(prefill_bucket(len(req.prompt))
                         for i, req, len0 in group), cap)
        # pad the group to max_batch rows by REPEATING row 0 (its duplicate
        # scatters write identical values, so the result is deterministic):
        # the jitted prefill then only ever sees (max_batch, bucket) shapes,
        # one trace per bucket instead of one per group size
        rows = group + [group[0]] * (self.max_batch - len(group))
        toks = np.asarray(
            [req.prompt + [0] * (bucket - len(req.prompt))
             for i, req, len0 in rows], np.int32)
        slot_ids = np.asarray([i for i, req, len0 in rows], np.int32)
        true_lens = np.asarray([len0 for i, req, len0 in rows], np.int32)
        logits, out_cache = self._prefill_slots(
            self.params, toks, true_lens, {**self.cache, "block": self.block},
            slot_ids)
        out_cache.pop("block")  # authoritative copy stays host-side
        self.cache = out_cache
        self.stats.prefills += 1
        self.stats.admitted += len(group)
        toks_out = np.asarray(sampler.greedy(logits))
        t1 = time.monotonic()
        for (i, req, len0), tok in zip(group, toks_out):
            tok = int(tok)
            req.t_admit = now
            req.t_first_token = t1
            req.out_tokens.append(tok)
            self.last_np[i] = tok
            self.slot_len[i] = len0
            self.slots[i] = req
            if tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens:
                self._finish(i, req)

    def _ensure_pages(self) -> None:
        """Allocate the page each active slot's next write lands in."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            pj = self.slot_len[i] // self.page_size
            if pj >= len(self.slot_pages[i]):
                pid = self.allocator.alloc(1)[0]
                self.slot_pages[i].append(pid)
                self.block[i, pj] = pid

    def _step_continuous(self) -> bool:
        self._admit_continuous()
        if all(s is None for s in self.slots):
            return bool(self.queue)
        self._ensure_pages()
        active = np.asarray([s is not None for s in self.slots])
        pre_cache = {**self.cache, "block": self.block}  # for re-dispatch
        t0 = time.monotonic()
        logits, cache = self._decode(self.params, self.last_np, pre_cache,
                                     active)
        dt = time.monotonic() - t0
        if self.watchdog is not None and self.watchdog(
                self.stats.decode_steps, dt):
            self.stats.straggler_events += 1
            logits, cache = self._decode(self.params, self.last_np,
                                         pre_cache, active)
        cache.pop("block")  # authoritative copy stays host-side
        self.cache = cache
        self.stats.decode_steps += 1
        self.stats.wall_decode_s += dt
        tok_np = np.asarray(sampler.greedy(logits))  # one sync per step
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(tok_np[i])
            self.last_np[i] = t
            req.out_tokens.append(t)
            self.stats.tokens_out += 1
            self.slot_len[i] += 1
            if (t == self.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or self.slot_len[i] >= self.max_seq - 1):
                self._finish(i, req)
        return True

    # ------------------------------------------------------------------
    # legacy wave admission over the shared-cursor cache
    # ------------------------------------------------------------------
    def _admit_wave(self) -> None:
        """The shared length cursor (cache["len"]) forces lockstep decode, so
        new requests only start when the whole batch drains."""
        if any(s is not None for s in self.slots):
            return
        if not self.queue:
            return
        wave = self.queue[:self.max_batch]
        self.queue = self.queue[self.max_batch:]
        now = time.monotonic()
        # right-align prompts to a common prefill length
        plen = max(len(r.prompt) for r in wave)
        toks = jnp.array(
            [([0] * (plen - len(r.prompt)) + r.prompt) for r in wave]
            + [[0] * plen] * (self.max_batch - len(wave)), jnp.int32)
        self.cache = model_lib.init_cache(self.cfg, self.max_batch,
                                          self.max_seq)
        logits, self.cache = _jit_prefill(self.cfg)(
            self.params, toks, self.cache, self.max_batch)
        self.stats.prefills += 1
        self.stats.admitted += len(wave)
        tok = sampler.greedy(logits)
        self.last_token = tok
        t1 = time.monotonic()
        for i, r in enumerate(wave):
            self.slots[i] = r
            r.t_admit = now
            r.t_first_token = t1
            r.out_tokens.append(int(tok[i]))
            if int(tok[i]) == self.eos_id \
                    or len(r.out_tokens) >= r.max_new_tokens:
                self._finish(i, r)

    def _step_wave(self) -> bool:
        self._admit_wave()
        if all(s is None for s in self.slots):
            return bool(self.queue)
        pre_cache = self.cache
        t0 = time.monotonic()
        logits, cache = self._decode(self.params, self.last_token, pre_cache)
        dt = time.monotonic() - t0
        if self.watchdog is not None and self.watchdog(
                self.stats.decode_steps, dt):
            self.stats.straggler_events += 1
            logits, cache = self._decode(self.params, self.last_token,
                                         pre_cache)
        self.cache = cache
        self.stats.decode_steps += 1
        self.stats.wall_decode_s += dt
        tok = sampler.greedy(logits)
        self.last_token = tok
        tok_np = np.asarray(tok)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            t = int(tok_np[i])
            r.out_tokens.append(t)
            self.stats.tokens_out += 1
            if t == self.eos_id or len(r.out_tokens) >= r.max_new_tokens \
                    or int(self.cache["len"]) >= self.max_seq - 1:
                self._finish(i, r)
        return True

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit + one decode step over the active batch; True if any work."""
        if self.mode == "continuous":
            return self._step_continuous()
        return self._step_wave()

    def run(self, max_steps: int = 10_000) -> EngineStats:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return self.stats
