"""Single-replica compatibility shim over :mod:`repro.serving.core`.

The 1k-line serving monolith that used to live here was split into three
layers, each with a public API at the seam (see the design note in
ROADMAP.md):

* :class:`repro.serving.core.EngineCore` — the per-replica synchronous
  loop (slots, paged/tiered KV, chunked prefill, scheduler calls) behind
  the narrow command surface ``add_request / abort_request / step() ->
  list[RequestOutput] / snapshot_slot / inject_slot``.
* :class:`repro.serving.router.Router` — N replicas behind one routing
  policy, merged output streams, cross-replica slot migration.
* :class:`repro.serving.client.ServingClient` — the user-facing handle
  (``submit() -> RequestHandle``, ``handle.tokens()``, ``stream()``,
  ``abort()``); the single place global request ids and sampling seeds
  are allocated.

``ServingEngine`` survives as the one-replica shim: the historical
surface (``submit`` / bool-returning ``step`` / ``run`` / ``stream`` /
``drain_outputs``) over an unmodified ``EngineCore``, so existing tests,
examples, and benchmarks keep their exact semantics — a ``Router`` with
one replica reproduces its outputs token-for-token.  ``Request``,
``RequestOutput``, and ``EngineStats`` are re-exported from the core so
old import paths keep working.
"""

from __future__ import annotations

from repro.serving.core import (EngineCore, EngineStats,  # noqa: F401
                                Request, RequestOutput, SlotSnapshot)

__all__ = ["EngineCore", "EngineStats", "Request", "RequestOutput",
           "ServingEngine", "SlotSnapshot"]


class ServingEngine(EngineCore):
    """One-replica engine with the historical control surface.

    Identical to :class:`EngineCore` except that ``step()`` keeps its
    legacy bool return ("was there work?") instead of the router-facing
    ``list[RequestOutput]``; consume events via ``stream()`` /
    ``drain_outputs()`` exactly as before.
    """

    def step(self) -> bool:
        return self._advance()
