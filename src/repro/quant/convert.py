"""Convert float model params to W8A8 serving form (paper's deployment mode).

Every linear param dict ``{"w": [..., in, out]}`` becomes
``{"w_q": int8 [..., out, in], "scale": f32 [..., out]}`` (bias preserved).
Kept in bf16 (documented): embeddings (row-gather, also the tied LM head),
MoE routed-expert stacks (ragged_dot path), mamba conv/ssm vectors, norms.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_w(w: jax.Array) -> dict:
    wt = jnp.swapaxes(w.astype(jnp.float32), -1, -2)  # [..., out, in]
    absmax = jnp.max(jnp.abs(wt), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    w_q = jnp.clip(jnp.round(wt / scale[..., None]), -127, 127).astype(jnp.int8)
    return {"w_q": w_q, "scale": scale.astype(jnp.float32)}


def quantize_params(params):
    """Recursively rewrite linear dicts into W8A8 form.

    Routers stay full precision (routing decisions are notoriously
    quantization-sensitive; their weights are negligible)."""
    if isinstance(params, dict):
        if "w" in params and isinstance(params["w"], (jax.Array, jax.ShapeDtypeStruct)) \
                and getattr(params["w"], "ndim", 0) >= 2:
            out = _quantize_w(params["w"])
            for k, v in params.items():
                if k != "w":
                    out[k] = v
            return out
        return {k: (v if k == "router" else quantize_params(v))
                for k, v in params.items()}
    if isinstance(params, (list, tuple)):
        return type(params)(quantize_params(v) for v in params)
    return params
