"""Convert float model params to quantized serving form (paper's deployment
mode).

Every linear param dict ``{"w": [..., in, out]}`` becomes, for
``mode="w8a8"``, ``{"w_q": int8 [..., out, in], "scale": f32 [..., out]}``
and, for ``mode="w4a16"``, ``{"w_p4": uint8 [out, in//2], "scale4": f32
[out, ng]}`` (bias preserved in both). Kept in bf16 (documented):
embeddings (row-gather, also the tied LM head), MoE routed-expert stacks
(ragged_dot path), mamba conv/ssm vectors, norms — and routers, exempted by
*path* so a router nested anywhere in the tree (e.g. under a layer list)
stays full precision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_w(w: jax.Array) -> dict:
    wt = jnp.swapaxes(w.astype(jnp.float32), -1, -2)  # [..., out, in]
    absmax = jnp.max(jnp.abs(wt), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    w_q = jnp.clip(jnp.round(wt / scale[..., None]), -127, 127).astype(jnp.int8)
    return {"w_q": w_q, "scale": scale.astype(jnp.float32)}


def _quantize_w4(w: jax.Array) -> dict:
    from repro.quant.int4 import quantize_weight4

    wt = jnp.swapaxes(w.astype(jnp.float32), -1, -2)  # [out, in]
    if wt.ndim != 2 or wt.shape[-1] % 2:
        return _quantize_w(w)  # stacked/odd-width weights fall back to W8A8
    q = quantize_weight4(wt)
    return {"w_p4": q.w_packed, "scale4": q.scale}


def _path_exempt(path: tuple) -> bool:
    """True if any dict key on the path marks a quantization-exempt subtree
    (routing decisions are notoriously quantization-sensitive; their
    weights are negligible)."""
    return any(isinstance(p, str) and p == "router" for p in path)


def quantize_params(params, mode: str = "w8a8", _path: tuple = ()):
    """Recursively rewrite linear dicts into quantized form.

    ``mode`` selects ``"w8a8"`` (int8 weights + dynamic per-token int8
    activations) or ``"w4a16"`` (packed-nibble weights, group-wise scales,
    16-bit activations). Exemption is by path predicate, so routers keep
    full precision no matter how deep in a list/tuple they sit.
    """
    if mode not in ("w8a8", "w4a16"):
        raise ValueError(f"unknown quantization mode: {mode!r}")
    if _path_exempt(_path):
        return params
    if isinstance(params, dict):
        if "w" in params and isinstance(params["w"], (jax.Array, jax.ShapeDtypeStruct)) \
                and getattr(params["w"], "ndim", 0) >= 2:
            qfn = _quantize_w if mode == "w8a8" else _quantize_w4
            out = qfn(params["w"])
            for k, v in params.items():
                if k != "w":
                    out[k] = v
            return out
        return {k: quantize_params(v, mode, _path + (k,))
                for k, v in params.items()}
    if isinstance(params, (list, tuple)):
        return type(params)(quantize_params(v, mode, _path + (i,))
                            for i, v in enumerate(params))
    return params
