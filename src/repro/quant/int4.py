"""W4A16 quantization (paper §VIII-B / Fig. 11): 4-bit packed weights with
group-wise scales, 16-bit activations."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

GROUP = 128


class QuantizedLinear4(NamedTuple):
    w_packed: jax.Array  # uint8 [h, w//2] — two nibbles per byte
    scale: jax.Array     # f32  [h, w//GROUP] group-wise
    h: int
    w: int


def pack_nibbles(w_q: jax.Array) -> jax.Array:
    """int4 values in int8 storage [-8..7] -> packed uint8 pairs."""
    u = (w_q + 8).astype(jnp.uint8)  # [0..15]
    lo = u[:, 0::2]
    hi = u[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jax.Array) -> jax.Array:
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = ((packed >> 4) & 0xF).astype(jnp.int8) - 8
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(packed.shape[0], -1)


def quantize_weight4(w: jax.Array, group: int = GROUP) -> QuantizedLinear4:
    h, width = w.shape
    assert width % 2 == 0
    g = min(group, width)
    ng = -(-width // g)
    pad = ng * g - width
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    wg = wp.reshape(h, ng, g)
    absmax = jnp.max(jnp.abs(wg), axis=2, keepdims=True)
    scale = jnp.maximum(absmax / 7.0, 1e-8)
    w_q = jnp.clip(jnp.round(wg / scale), -8, 7).astype(jnp.int8)
    w_q = w_q.reshape(h, ng * g)[:, :width]
    return QuantizedLinear4(w_packed=pack_nibbles(w_q),
                            scale=scale[:, :, 0].astype(jnp.float32), h=h, w=width)


def dequantize4(q: QuantizedLinear4, group: int = GROUP) -> jax.Array:
    w_q = unpack_nibbles(q.w_packed)[:, :q.w].astype(jnp.float32)
    g = min(group, q.w)
    ng = q.scale.shape[1]
    pad = ng * g - q.w
    w_q = jnp.pad(w_q, ((0, 0), (0, pad))).reshape(q.h, ng, g)
    w = w_q * q.scale[:, :, None]
    return w.reshape(q.h, ng * g)[:, :q.w]


def int4_matvec(q: QuantizedLinear4, x: jax.Array) -> jax.Array:
    """W4A16: dequantize-on-the-fly GeMV with bf16/f32 activations."""
    return dequantize4(q) @ x.astype(jnp.float32)
