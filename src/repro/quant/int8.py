"""W8A8 symmetric quantization (paper default; SmoothQuant-style offline).

Per-output-channel weight scales; per-column (per-token) dynamic activation
scale — a single per-tensor scale would let one outlier token crush the
quantization resolution of every other column in a batched ``x [w, b]``.
All computations accumulate in int32 and dequantize at the end, mirroring the
flash compute core's INT8 MACs (paper §IV-B).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantizedLinear(NamedTuple):
    w_q: jax.Array    # int8 [h, w]
    scale: jax.Array  # f32 [h] per-output-channel


def quantize_weight(w: jax.Array) -> QuantizedLinear:
    """w: [h, w] float -> int8 with per-row symmetric scale."""
    absmax = jnp.max(jnp.abs(w), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    w_q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return QuantizedLinear(w_q=w_q, scale=scale[:, 0].astype(jnp.float32))


def quantize_activation(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: [w] or [w, b] float -> (int8, per-column scale).

    1-D inputs get a scalar scale; batched [w, b] inputs get one scale per
    column b (absmax over the contraction axis 0), so an outlier token only
    costs itself resolution."""
    if x.ndim <= 1:
        absmax = jnp.max(jnp.abs(x))
    else:
        absmax = jnp.max(jnp.abs(x), axis=0)
    # explicit reciprocal multiply: XLA rewrites constant division to it
    # under jit, so spelling it out keeps eager and jitted callers
    # bit-identical (the kernel-vs-ref parity tests compare across both)
    scale = jnp.maximum(absmax * jnp.float32(1.0 / 127.0), 1e-8)
    x_q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return x_q, scale.astype(jnp.float32)


def dequantize(w_q: jax.Array, scale: jax.Array) -> jax.Array:
    return w_q.astype(jnp.float32) * scale[:, None]


def int8_matvec(q: QuantizedLinear, x: jax.Array) -> jax.Array:
    """W8A8 GeMV: int8 x int8 -> int32 accumulate -> f32 dequant."""
    x_q, x_scale = quantize_activation(x)
    acc = jax.lax.dot_general(
        q.w_q.astype(jnp.int32), x_q.astype(jnp.int32),
        (((1,), (0,)), ((), ())))
    if x.ndim <= 1:
        return acc.astype(jnp.float32) * q.scale * x_scale
    return acc.astype(jnp.float32) * q.scale[:, None] * x_scale[None, :]


def quantization_mse(w: jax.Array) -> jax.Array:
    q = quantize_weight(w)
    return jnp.mean((dequantize(q.w_q, q.scale) - w) ** 2)
