"""End-to-end decode performance of Cambricon-LLM (paper Figs 9/11/12/13/14/15).

The decode step is simulated as a whole-channel request stream: read-compute
requests serialize at matrix barriers (activation dependencies); NPU-bound
weight reads are activation-independent and prefetch into channel bubbles
(bounded by the NPU weight buffer).  NPU attention and KV-cache DRAM traffic
appear as channel-idle phases that reads also fill.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core import planner, tiling
from repro.core.hw import DEFAULT_NPU, FlashSpec, NPUSpec
from repro.core.schedule import DEFAULT_SLICE_BYTES, Policy
from repro.sim.engine import NpuPhase, RCBlock, simulate_stream


@dataclasses.dataclass(frozen=True)
class TokenTime:
    total: float
    npu_phase_time: float     # attention + KV/state DRAM traffic
    channel_util: float       # bus-busy fraction over the token
    channel_bytes: float      # bytes that crossed the flash channels (all ch.)
    flash_array_bytes: float  # bytes read out of NAND arrays (energy model)
    stalled_on_reads: float
    kv_tier_bytes: float = 0.0  # KV spill+prefetch bytes this token (all ch.)
    kv_bus_s: float = 0.0       # per-channel bus seconds the KV tier used
    host_gap_s: float = 0.0     # host dispatch gap added on top of compute

    @property
    def tokens_per_s(self) -> float:
        return 1.0 / self.total


def _attn_phase_time(cfg: ModelConfig, seq_len: int, npu: NPUSpec,
                     kv_bytes_per_elem: int, cross: bool = False) -> float:
    """One attention instance on the NPU: QK^T + PV + softmax + KV traffic."""
    n_heads, d_head = cfg.n_heads, cfg.d_head
    kv_heads = cfg.n_kv_heads
    kv_len = cfg.encoder_seq if cross else seq_len
    if cfg.family == "mla_moe" and not cross:
        # absorbed-MLA decode: per-head dot against the compressed cache
        d_head = cfg.kv_lora_rank + cfg.qk_rope_dim
        kv_heads = 1
    macs = 2 * 2 * n_heads * d_head * kv_len
    sfu = n_heads * kv_len
    kv_bytes = 2 * kv_heads * d_head * kv_len * kv_bytes_per_elem
    return macs / npu.ops_per_s + sfu / npu.sfu_ops_per_s + kv_bytes / npu.dram_bw


def _ssm_phase_time(cfg: ModelConfig, npu: NPUSpec, kv_bytes_per_elem: int) -> float:
    """One SSD state update: read+update+write the recurrent state."""
    state_elems = cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state
    conv_elems = cfg.ssm_conv * (cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state)
    macs = 6 * state_elems
    bytes_ = 2 * (state_elems + conv_elems) * kv_bytes_per_elem
    return macs / npu.ops_per_s + bytes_ / npu.dram_bw


def decode_token_time(cfg: ModelConfig, flash: FlashSpec,
                      bytes_per_elem: float = 1.0,
                      policy: Policy = Policy.RC_SLICED,
                      slice_bytes: int = DEFAULT_SLICE_BYTES,
                      seq_len: int = 1024,
                      npu: NPUSpec | None = None,
                      alpha_override: float | None = None,
                      tile_override: tiling.TileShape | None = None,
                      prefetch_bytes: float = 32e6,
                      kv_spill_bytes: float = 0.0,
                      kv_prefetch_bytes: float = 0.0,
                      host_dispatch_s: float = 0.0,
                      n_dispatches: int = 2,
                      overlap_dispatch: bool = False) -> TokenTime:
    """Simulate one decode token; ``kv_spill_bytes``/``kv_prefetch_bytes``
    are the token's tiered-KV page traffic (total across channels, e.g. from
    ``EngineStats.kv_spill_bytes / tokens_out``), accounted as sliced plain
    write/read requests riding the Slice Control bubbles.

    ``host_dispatch_s`` prices the serving loop's host-side overhead per
    jitted dispatch (default 0 = ideal host).  A synchronous loop pays
    ``n_dispatches`` gaps per token serially (decode + sample = 2); the
    overlapped loop (``overlap_dispatch=True``, one fused dispatch enqueued
    while the previous step still computes) hides the gap behind compute —
    only ``max(0, gap - compute)`` of it can ever surface as latency."""
    npu = npu or DEFAULT_NPU  # reprolint: ok boolean-select-trap — npu is an NPUSpec or None, never numeric
    act_bytes = 1.0 if bytes_per_elem >= 1.0 else 2.0  # W4A16 -> 16-bit acts
    kv_b = int(act_bytes)

    plan_cache: dict[tuple[int, int], tiling.MatrixPlan] = {}

    def get_plan(h: int, w: int) -> tiling.MatrixPlan:
        key = (h, w)
        if key not in plan_cache:
            plan_cache[key] = tiling.plan_matrix(
                h, w, flash, bytes_per_elem,
                alpha_override=alpha_override, tile_override=tile_override)
        return plan_cache[key]

    items = []
    npu_phase_time = 0.0
    channel_bytes = 0.0
    array_bytes = 0.0
    stream = planner.decode_execution_stream(cfg)
    n_attn_seen = 0
    for it in stream:
        if it[0] == "gemv":
            _, h, w = it
            plan = get_plan(h, w)
            reads_per_ch = plan.npu_bytes / flash.channels
            rc_in = (plan.tile.w / flash.channels * act_bytes
                     + flash.t_cmd * flash.bw_channel)  # command overhead
            rc_out = plan.tile.h * act_bytes
            items.append(RCBlock(
                n_tiles=plan.n_tiles, rc_input_bytes=rc_in,
                rc_result_bytes=rc_out, read_bytes=reads_per_ch,
                t_r=flash.t_r, bw=flash.bw_channel,
                page_bytes=flash.page_bytes))
            channel_bytes += (plan.n_tiles * (rc_in + rc_out) * flash.channels
                              + plan.npu_bytes)
            array_bytes += h * w * bytes_per_elem
        elif it[0] == "attn":
            cross = cfg.family == "audio" and n_attn_seen % 2 == 1
            dur = _attn_phase_time(cfg, seq_len, npu, kv_b, cross)
            n_attn_seen += 1
            npu_phase_time += dur
            items.append(NpuPhase(dur))
        elif it[0] == "ssm":
            dur = _ssm_phase_time(cfg, npu, kv_b)
            npu_phase_time += dur
            items.append(NpuPhase(dur))
    res = simulate_stream(items, policy, slice_bytes, prefetch_bytes,
                          kv_write_bytes=kv_spill_bytes / flash.channels,
                          kv_read_bytes=kv_prefetch_bytes / flash.channels,
                          kv_bw=flash.bw_channel,
                          kv_page_bytes=flash.page_bytes)
    gap = n_dispatches * host_dispatch_s
    if overlap_dispatch:
        gap = max(0.0, gap - res.time)
    return TokenTime(
        total=res.time + gap,
        npu_phase_time=npu_phase_time,
        channel_util=res.util,
        channel_bytes=channel_bytes,
        flash_array_bytes=array_bytes,
        stalled_on_reads=res.stalled_on_reads,
        kv_tier_bytes=kv_spill_bytes + kv_prefetch_bytes,
        kv_bus_s=res.kv_bus_s,
        host_gap_s=gap,
    )


def kv_swap_overhead_s(cfg: ModelConfig, flash: FlashSpec,
                       kv_spill_bytes: float, kv_prefetch_bytes: float,
                       **kw) -> float:
    """Token-latency cost of riding the given per-token KV tier traffic
    through the channel bubbles: decode time with the traffic minus the
    all-resident baseline.  Near zero while the bubbles absorb it (the
    paper's Slice Control headroom), rising once the bus saturates."""
    base = decode_token_time(cfg, flash, **kw)
    kv = decode_token_time(cfg, flash, kv_spill_bytes=kv_spill_bytes,
                           kv_prefetch_bytes=kv_prefetch_bytes, **kw)
    return kv.total - base.total


def prefill_ttft_s(cfg: ModelConfig, flash: FlashSpec,
                   prompt_len: int, cached_tokens: int = 0,
                   **kw) -> float:
    """Time-to-first-token of a prefill with ``cached_tokens`` of the prompt
    already served by the KV prefix cache.

    The weight stream is token-parallel (one pass over the layers covers
    every new position's GEMVs — the whole suffix batches into it), while
    the per-position NPU attention/SSM phases serialize; cached positions
    participate only as attention context, which the ``seq_len``-sized
    phases already price.  So a prefix hit removes ``cached_tokens`` of the
    serialized NPU phases — TTFT decreases monotonically in the cached
    length and collapses to a single decode-step time on a full hit, which
    is exactly what the serving engine's zero-dispatch resume admission
    does.  ``**kw`` forwards to :func:`decode_token_time`."""
    if prompt_len < 1:
        raise ValueError(f"prompt_len must be >= 1, got {prompt_len}")
    cached = max(0, min(int(cached_tokens), prompt_len - 1))
    n_new = prompt_len - cached
    t = decode_token_time(cfg, flash, seq_len=prompt_len, **kw)
    return t.total + (n_new - 1) * t.npu_phase_time


def family_kv_page_bytes(cfg: ModelConfig, page_size: int,
                         bytes_per_elem: float = 2.0,
                         kv_dtype: str = "bf16") -> float:
    """Bytes one evicted KV page moves, per family — the MLA family spills
    compressed [page, d_ckv + d_krope] rows and the hybrid family only its
    shared-attention groups, so their tier traffic is a fraction of a
    same-sized dense model's.  Derives from the same element count the
    engine's ``kv_page_bytes`` uses (``serving.kv_cache.kv_page_elems``),
    keeping the sim pricing honest with the live byte counters.

    ``kv_dtype="int8"`` prices the quantized pools: one byte per element
    plus the f32 per-row scale payloads (``kv_page_scale_elems``) that
    spill alongside them — a ~2·Dh/(Dh+4) traffic reduction vs bf16."""
    from repro.serving.kv_cache import kv_page_elems, kv_page_scale_elems
    if kv_dtype == "int8":
        return (kv_page_elems(cfg, page_size)
                + 4.0 * kv_page_scale_elems(cfg, page_size))
    return kv_page_elems(cfg, page_size) * bytes_per_elem


def kv_page_cost_s(cfg: ModelConfig, flash: FlashSpec,
                   kv_page_bytes: float | None = None,
                   page_size: int = 16, **kw) -> float:
    """Token-latency cost of ONE evicted KV page (spilled now, prefetched
    back later) — what the serving engine charges an eviction decision.
    ``kv_page_bytes`` defaults to the family-accurate page size
    (``family_kv_page_bytes``), so MLA's compressed pages price cheaper
    than a dense model's full-K/V pages."""
    if kv_page_bytes is None:
        kv_page_bytes = family_kv_page_bytes(cfg, page_size)
    return kv_swap_overhead_s(cfg, flash, kv_page_bytes, kv_page_bytes, **kw)


def flash_only_token_time(cfg: ModelConfig, flash: FlashSpec,
                          bytes_per_elem: float = 1.0,
                          seq_len: int = 1024,
                          npu: NPUSpec | None = None) -> TokenTime:
    """Fig-14 ablation: no hardware-aware tiling, everything on flash (α=1)."""
    return decode_token_time(cfg, flash, bytes_per_elem, Policy.RC_ONLY,
                             seq_len=seq_len, npu=npu, alpha_override=1.0)
