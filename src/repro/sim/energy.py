"""Data-movement energy model (paper Fig. 16).

Constants are pJ/bit, from the in-storage-computing literature the paper
cites ([21] Gonugondla ISCAS'18, [51] Pandiyan IISWC'14) and public interface
specs.  NAND array sensing dominates both architectures (every weight bit is
sensed from the array exactly once per token either way); Cambricon-LLM's win
comes from eliminating the SSD->DRAM->accelerator double hop and shipping
~10x fewer bytes across external interfaces.

Calibration note (documented, honest): with these constants the model lands
at Cambricon-LLM-S ≈ 0.6-0.7x Flexgen-SSD energy and 9-12x less transferred
data, matching the paper's "67% of the energy" and "9.7-11.6x less data".
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core import planner
from repro.core.hw import FlashSpec

PJ_PER_BIT = {
    "nand_array": 30.0,   # NAND sensing + on-die movement
    "flash_channel": 1.5,  # ONFI-class channel bus
    "d2d": 0.5,           # chiplet die-to-die link (UCIe-class)
    "lpddr": 4.0,         # LPDDR5X access
    "pcie": 5.0,          # PCIe 4.0 SerDes
    "ddr": 5.0,           # server DDR4/5
    "nvme_internal": 1.5,  # SSD-internal channel to controller
}


@dataclasses.dataclass(frozen=True)
class TransferEnergy:
    transferred_bytes: float   # bytes crossing external interfaces
    energy_j: float

    @property
    def energy_mj(self) -> float:
        return self.energy_j * 1e3


def cambricon_per_token(cfg: ModelConfig, flash: FlashSpec,
                        channel_bytes: float, array_bytes: float,
                        kv_bytes: float) -> TransferEnergy:
    """Energy per decoded token for Cambricon-LLM.

    array_bytes: NAND array reads (all active weights, sensed once);
    channel_bytes: flash-channel traffic (rc inputs/results + NPU reads);
    every channel byte also crosses the D2D link to the NPU; KV cache moves
    through LPDDR once per token.
    """
    bits = 8.0
    e = (array_bytes * PJ_PER_BIT["nand_array"]
         + channel_bytes * PJ_PER_BIT["flash_channel"]
         + channel_bytes * PJ_PER_BIT["d2d"]
         + kv_bytes * PJ_PER_BIT["lpddr"]) * bits * 1e-12
    return TransferEnergy(transferred_bytes=channel_bytes + kv_bytes, energy_j=e)


def flexgen_ssd_per_token(cfg: ModelConfig, kv_bytes: float,
                          bytes_per_elem: float = 1.0) -> TransferEnergy:
    """Flexgen-SSD: weights sensed in the SSD's NAND, moved SSD->DRAM over
    PCIe, then DRAM->GPU over PCIe (the paper: conventional architectures
    "increase the total data transfer by over 3x")."""
    w = sum(m.active_params for m in planner.model_matrices(cfg)) * bytes_per_elem
    bits = 8.0
    transferred = 3.0 * w + kv_bytes
    e = (w * PJ_PER_BIT["nand_array"]          # sensed once in the SSD
         + w * PJ_PER_BIT["nvme_internal"]
         + w * PJ_PER_BIT["pcie"]              # SSD -> host DRAM
         + w * PJ_PER_BIT["ddr"]               # write+read host DRAM
         + w * PJ_PER_BIT["ddr"]
         + w * PJ_PER_BIT["pcie"]              # host DRAM -> GPU
         + kv_bytes * PJ_PER_BIT["ddr"]) * bits * 1e-12
    return TransferEnergy(transferred_bytes=transferred, energy_j=e)
