"""Event-driven flash-channel simulator (SSDsim-style, per paper §VII-A).

Channels are symmetric: we simulate one channel's bus + die pool exactly and
read the matrix completion time off it.  The model captures the paper's
pipeline (Fig. 6): read-compute input transfers, ~tR in-die windows, result
uploads, and plain reads/writes either whole-page (blocking) or sliced into
the bubbles.

Resources on a channel:
  * the bus — serializes every transfer (rc inputs, rc results, read/write
    slices);
  * the die pool — a tile's array-read+compute occupies all dies for tR
    (all compute cores cooperate on one tile; the two-plane data/cache
    register pipeline lets the next tile's array read overlap the bus phase,
    which is captured by allowing the next tile's input transfer during the
    current tile's tR window);
  * NPU-bound reads use any idle plane, so they do not contend for dies in
    this model (the idle plane serves them, per §IV-C "the idle plane serves
    normal read requests"), only for the bus.  Plain WRITES (KV pages
    spilled by the tiered cache) are the symmetric case — the page programs
    an idle plane after its bus transfer, so they too contend only for the
    bus.
"""

from __future__ import annotations

import dataclasses

from repro.core.schedule import (DEFAULT_SLICE_BYTES, ChannelWorkload, Policy)


@dataclasses.dataclass
class BusSegment:
    start: float
    end: float
    kind: str  # "rc_in" | "rc_out" | "read" | "write"


@dataclasses.dataclass
class SimResult:
    time: float                  # matrix completion time (rc + reads + writes)
    rc_done: float               # last read-compute completion
    reads_done: float            # last NPU-bound byte delivered
    bus_busy: float              # total bus-occupied seconds
    util: float                  # bus_busy / time
    segments: list[BusSegment]   # trace (for Fig-6 style plots)
    writes_done: float = 0.0     # last flash-bound (KV spill) byte delivered


def simulate_channel(w: ChannelWorkload, policy: Policy = Policy.RC_SLICED,
                     slice_bytes: int = DEFAULT_SLICE_BYTES,
                     keep_trace: bool = False) -> SimResult:
    """Simulate one channel processing ``w``; returns completion stats.

    Event structure per read-compute request i:
      input transfer  [s_i, s_i + t_in]   (bus)
      die window      [s_i + t_in, s_i + t_in + tR]   (dies, all of them)
      result transfer [die_end, die_end + t_out]      (bus, priority)
    Plain traffic (NPU-bound reads, then flash-bound KV writes) fills bus
    gaps: whole pages (RC_UNSLICED) or slices (RC_SLICED).  Read data is
    produced by idle planes and writes program idle planes, so we assume a
    page is ready whenever the bus can take it (array reads/programs overlap
    earlier traffic), which matches the paper's steady-state pipeline.
    """
    t_in = w.rc_input_bytes / w.bw
    t_out = w.rc_result_bytes / w.bw
    t_slice = slice_bytes / w.bw
    t_page = w.page_bytes / w.bw

    segments: list[BusSegment] = []
    bus_busy = 0.0

    def occupy(start: float, dur: float, kind: str) -> float:
        nonlocal bus_busy
        bus_busy += dur
        if keep_trace:
            segments.append(BusSegment(start, start + dur, kind))
        return start + dur

    # Pending plain-traffic bytes: reads drain before writes.
    if policy != Policy.RC_ONLY:
        plain = {"read": float(w.n_reads * w.page_bytes),
                 "write": float(w.n_writes * w.page_bytes)}
    else:
        plain = {"read": 0.0, "write": 0.0}
    done_at = {"read": 0.0, "write": 0.0}

    bus_free = 0.0      # earliest time the bus is available
    dies_free = 0.0     # earliest time the die pool can start a new tile
    rc_done = 0.0

    def plain_pending() -> bool:
        return plain["read"] > 0 or plain["write"] > 0

    def next_kind() -> str:
        return "read" if plain["read"] > 0 else "write"

    def fill_bubble(limit: float) -> None:
        """Fill the bus gap [bus_free, limit] with plain-traffic slices."""
        nonlocal bus_free
        while plain_pending():
            kind = next_kind()
            n_fit = int((limit - bus_free) / t_slice)
            n_have = int(-(-plain[kind] // slice_bytes))
            n = min(n_fit, n_have)
            if n <= 0:
                return
            t = bus_free
            for _s in range(n):
                t = occupy(t, t_slice, kind)
            plain[kind] = max(0.0, plain[kind] - n * slice_bytes)
            done_at[kind] = t
            bus_free = t

    for _ in range(w.n_tiles):
        # Input transfer: needs the bus; the die pool must be free by the time
        # the transfer completes (two-plane pipelining lets transfer overlap
        # the previous tile's die window).
        start_in = max(bus_free, dies_free - t_in)
        # RC_UNSLICED: a whole-page read/write may be occupying the bus
        # (head-of-line blocking).  Interleave: before each rc input, if
        # plain traffic remains, one full page transfer goes out first
        # (paper Fig. 6b's interleaving).
        if policy == Policy.RC_UNSLICED and plain_pending():
            kind = next_kind()
            bus_free = occupy(bus_free, t_page, kind)
            plain[kind] = max(0.0, plain[kind] - w.page_bytes)
            done_at[kind] = bus_free
            start_in = max(bus_free, dies_free - t_in)
        if policy == Policy.RC_SLICED and plain_pending():
            fill_bubble(start_in)
            start_in = max(bus_free, dies_free - t_in)
        end_in = occupy(start_in, t_in, "rc_in")
        bus_free = end_in
        die_start = max(end_in, dies_free)
        die_end = die_start + w.t_r
        dies_free = die_end
        # Result upload has priority at die_end, but slices may use the bubble
        # [end_in, die_end] first.
        if policy == Policy.RC_SLICED and plain_pending():
            fill_bubble(die_end)
        start_out = max(bus_free, die_end)
        bus_free = occupy(start_out, t_out, "rc_out")
        rc_done = bus_free

    # Drain remaining plain traffic after the last rc request.
    while plain_pending():
        kind = next_kind()
        step = min(slice_bytes if policy == Policy.RC_SLICED else w.page_bytes,
                   plain[kind])
        bus_free = occupy(bus_free, step / w.bw, kind)
        plain[kind] -= step
        done_at[kind] = bus_free

    total = max(rc_done, done_at["read"], done_at["write"])
    if total <= 0.0:
        total = 0.0
        util = 0.0
    else:
        util = bus_busy / total
    return SimResult(time=total, rc_done=rc_done, reads_done=done_at["read"],
                     bus_busy=bus_busy, util=util, segments=segments,
                     writes_done=done_at["write"])


# ---------------------------------------------------------------------------
# Whole-model stream simulation
# ---------------------------------------------------------------------------
#
# A decode step is a *sequence* of GeMV matrices (layer order) interleaved
# with NPU-only phases (attention + KV-cache traffic).  Read-compute requests
# are activation-dependent (matrix k+1's input is matrix k's output) and so
# serialize at matrix barriers; plain weight READS are activation-independent
# and may prefetch ahead into any channel bubble, bounded by the NPU's weight
# buffer (``prefetch_bytes``).  This is the paper's Slice Control applied to
# the full request stream.
#
# Tiered-KV traffic (``kv_write_bytes`` spilled pages NPU->flash,
# ``kv_read_bytes`` prefetched pages flash->NPU) is a third request class:
# activation-independent like weight reads, but lowest priority — it rides
# whatever bubble space weight reads leave behind, and only gates the token's
# completion (the spill must land before the hot page is reused next token),
# never a matrix barrier.


@dataclasses.dataclass(frozen=True)
class RCBlock:
    """One matrix's per-channel workload inside the stream."""

    n_tiles: int
    rc_input_bytes: float
    rc_result_bytes: float
    read_bytes: float  # NPU-bound weight bytes on this channel, this matrix
    t_r: float
    bw: float
    page_bytes: float = 16384.0


@dataclasses.dataclass(frozen=True)
class NpuPhase:
    """Channel-idle phase (attention / KV traffic); reads may still flow."""

    duration: float


@dataclasses.dataclass
class StreamResult:
    time: float
    bus_busy: float
    util: float
    stalled_on_reads: float  # time the barrier waited on undelivered reads
    kv_done: float = 0.0     # when the last KV-tier byte crossed the bus
    kv_bus_s: float = 0.0    # bus seconds spent on KV spill/prefetch traffic


def simulate_stream(items: list, policy: Policy = Policy.RC_SLICED,
                    slice_bytes: int = DEFAULT_SLICE_BYTES,
                    prefetch_bytes: float = 32e6,
                    kv_write_bytes: float = 0.0,
                    kv_read_bytes: float = 0.0,
                    kv_bw: float = 1.0e9,
                    kv_page_bytes: float = 16384.0) -> StreamResult:
    """Simulate one channel executing the full decode stream.

    Matrix barriers: RCBlock ``i+1`` cannot start until block ``i``'s rc tiles
    are done AND its NPU-bound read bytes are delivered.  Reads are delivered
    FIFO; reads belonging to blocks at-or-before the executing block are
    always allowed, reads of future blocks prefetch into bubbles while the
    NPU-side weight buffer (``prefetch_bytes``) has room.

    KV-tier traffic (``kv_write_bytes`` + ``kv_read_bytes``, this channel's
    share of the token's spill/prefetch bytes) fills bubbles AFTER weight
    reads each time the bus idles, and drains at the end of the stream if
    bubbles didn't absorb it — the token is only complete once the tier
    traffic has crossed the bus.  Like plain reads it follows the policy:
    RC_ONLY drops it, RC_UNSLICED moves whole ``kv_page_bytes`` pages,
    RC_SLICED moves ``slice_bytes`` slices.
    """
    n = len(items)
    reads = [it.read_bytes if isinstance(it, RCBlock) else 0.0 for it in items]
    left = list(reads)
    finish = [0.0] * n  # when item i's reads were fully delivered

    bus_free = 0.0
    dies_free = 0.0
    bus_busy = 0.0
    stalled = 0.0
    q_head = 0
    while q_head < n and left[q_head] <= 0:
        q_head += 1
    delivered_total = 0.0
    consumed_total = 0.0  # reads of all blocks at-or-before the current barrier
    current = 0
    kv_left = (0.0 if policy == Policy.RC_ONLY
               else float(kv_write_bytes) + float(kv_read_bytes))
    kv_step = slice_bytes if policy == Policy.RC_SLICED else kv_page_bytes
    kv_unit = kv_step / kv_bw
    kv_done_at = 0.0
    kv_bus = 0.0

    def fill_reads(until: float) -> None:
        """Deliver read data into the bus gap [bus_free, until]."""
        nonlocal bus_free, bus_busy, q_head, delivered_total
        if policy == Policy.RC_ONLY:
            return
        while q_head < n:
            it = items[q_head]
            if policy == Policy.RC_UNSLICED and q_head > current:
                return  # unsliced reads can't opportunistically prefetch
            step = slice_bytes if policy == Policy.RC_SLICED else it.page_bytes
            t_unit = step / it.bw
            gap = min(until, 1e30) - bus_free
            if gap < t_unit - 1e-15:
                return
            # prefetch cap for future blocks' reads
            if q_head > current:
                room = prefetch_bytes - (delivered_total - consumed_total)
                if room < step:
                    return
                budget_units = int(room / step)
            else:
                budget_units = 1 << 60
            units_left = int(-(-left[q_head] // step))
            k = min(int(gap / t_unit), units_left, budget_units)
            if k <= 0:
                return
            amt = min(k * step, left[q_head])
            bus_free += k * t_unit
            bus_busy += k * t_unit
            delivered_total += amt
            left[q_head] -= amt
            if left[q_head] <= 1e-9:
                finish[q_head] = bus_free
                q_head += 1
                while q_head < n and left[q_head] <= 0:
                    q_head += 1

    def fill_kv(until: float) -> None:
        """Lowest priority: KV tier slices ride leftover bubble space."""
        nonlocal bus_free, bus_busy, kv_left, kv_done_at, kv_bus
        if kv_left <= 0:
            return
        gap = min(until, 1e30) - bus_free
        k = min(int(gap / kv_unit), int(-(-kv_left // kv_step)))
        if k <= 0:
            return
        dur = k * kv_unit
        bus_free += dur
        bus_busy += dur
        kv_bus += dur
        kv_left = max(0.0, kv_left - k * kv_step)
        kv_done_at = bus_free

    barrier = 0.0
    for i, it in enumerate(items):
        current = i
        if isinstance(it, NpuPhase):
            end = barrier + it.duration
            fill_reads(end)
            fill_kv(end)
            barrier = end
            consumed_total += 0.0
            continue
        t_in = it.rc_input_bytes / it.bw
        t_out = it.rc_result_bytes / it.bw
        rc_done = barrier
        for _t in range(it.n_tiles):
            earliest = max(barrier, dies_free - t_in)
            # RC_UNSLICED head-of-line blocking: a pending whole-page read for
            # the current (or earlier) block transmits before the rc input.
            if (policy == Policy.RC_UNSLICED and q_head <= i and q_head < n
                    and left[q_head] > 0):
                fill_reads(max(bus_free, earliest) + it.page_bytes / it.bw)
            else:
                fill_reads(max(bus_free, earliest))
            fill_kv(max(bus_free, earliest))
            start_in = max(bus_free, earliest)
            end_in = start_in + t_in
            bus_busy += t_in
            bus_free = end_in
            die_end = max(end_in, dies_free) + it.t_r
            dies_free = die_end
            fill_reads(die_end)
            fill_kv(die_end)
            start_out = max(bus_free, die_end)
            bus_free = start_out + t_out
            bus_busy += t_out
            rc_done = bus_free
        # Drain this block's own remaining reads (they gate the barrier).
        if q_head <= i and q_head < n and left[i] > 0:
            t0 = max(bus_free, rc_done)
            fill_reads(float("inf"))
            stalled += max(0.0, bus_free - t0)
        my_reads = finish[i] if reads[i] > 0 else 0.0
        barrier = max(rc_done, my_reads)
        consumed_total += reads[i]

    # Tail-drain the KV tier traffic the bubbles didn't absorb.
    if kv_left > 0:
        fill_kv(float("inf"))
    total = max(barrier, kv_done_at)
    util = bus_busy / total if total > 0 else 0.0
    return StreamResult(time=total, bus_busy=bus_busy, util=util,
                        stalled_on_reads=stalled, kv_done=kv_done_at,
                        kv_bus_s=kv_bus)
