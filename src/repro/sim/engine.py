"""Event-driven flash-channel simulator (SSDsim-style, per paper §VII-A).

Channels are symmetric: we simulate one channel's bus + die pool exactly and
read the matrix completion time off it.  The model captures the paper's
pipeline (Fig. 6): read-compute input transfers, ~tR in-die windows, result
uploads, and plain reads either whole-page (blocking) or sliced into the
bubbles.

Resources on a channel:
  * the bus — serializes every transfer (rc inputs, rc results, read slices);
  * the die pool — a tile's array-read+compute occupies all dies for tR
    (all compute cores cooperate on one tile; the two-plane data/cache
    register pipeline lets the next tile's array read overlap the bus phase,
    which is captured by allowing the next tile's input transfer during the
    current tile's tR window);
  * NPU-bound reads use any idle plane, so they do not contend for dies in
    this model (the idle plane serves them, per §IV-C "the idle plane serves
    normal read requests"), only for the bus.
"""

from __future__ import annotations

import dataclasses

from repro.core.schedule import (DEFAULT_SLICE_BYTES, ChannelWorkload, Policy)


@dataclasses.dataclass
class BusSegment:
    start: float
    end: float
    kind: str  # "rc_in" | "rc_out" | "read"


@dataclasses.dataclass
class SimResult:
    time: float                  # matrix completion time (all rc + all reads)
    rc_done: float               # last read-compute completion
    reads_done: float            # last NPU-bound byte delivered
    bus_busy: float              # total bus-occupied seconds
    util: float                  # bus_busy / time
    segments: list[BusSegment]   # trace (for Fig-6 style plots)


def simulate_channel(w: ChannelWorkload, policy: Policy = Policy.RC_SLICED,
                     slice_bytes: int = DEFAULT_SLICE_BYTES,
                     keep_trace: bool = False) -> SimResult:
    """Simulate one channel processing ``w``; returns completion stats.

    Event structure per read-compute request i:
      input transfer  [s_i, s_i + t_in]   (bus)
      die window      [s_i + t_in, s_i + t_in + tR]   (dies, all of them)
      result transfer [die_end, die_end + t_out]      (bus, priority)
    Reads fill bus gaps: whole pages (RC_UNSLICED) or slices (RC_SLICED).
    Read data is produced by idle planes; we assume a page is ready whenever
    the bus can take it (array reads overlap earlier traffic), which matches
    the paper's steady-state pipeline.
    """
    t_in = w.rc_input_bytes / w.bw
    t_out = w.rc_result_bytes / w.bw
    t_slice = slice_bytes / w.bw
    t_page = w.page_bytes / w.bw

    segments: list[BusSegment] = []
    bus_busy = 0.0

    def occupy(start: float, dur: float, kind: str) -> float:
        nonlocal bus_busy
        bus_busy += dur
        if keep_trace:
            segments.append(BusSegment(start, start + dur, kind))
        return start + dur

    # Pending read bytes for the NPU.
    read_bytes_left = w.n_reads * w.page_bytes if policy != Policy.RC_ONLY else 0.0
    reads_done_at = 0.0

    bus_free = 0.0      # earliest time the bus is available
    dies_free = 0.0     # earliest time the die pool can start a new tile
    rc_done = 0.0

    for _ in range(w.n_tiles):
        # Input transfer: needs the bus; the die pool must be free by the time
        # the transfer completes (two-plane pipelining lets transfer overlap
        # the previous tile's die window).
        start_in = max(bus_free, dies_free - t_in)
        # RC_UNSLICED: a whole-page read may be occupying the bus (head-of-line
        # blocking). Interleave: before each rc input, if reads remain, one
        # full page transfer goes out first (paper Fig. 6b's interleaving).
        if policy == Policy.RC_UNSLICED and read_bytes_left > 0:
            bus_free = occupy(bus_free, t_page, "read")
            read_bytes_left -= w.page_bytes
            reads_done_at = bus_free
            start_in = max(bus_free, dies_free - t_in)
        if policy == Policy.RC_SLICED and read_bytes_left > 0:
            # Fill the gap [bus_free, start_in] with read slices.
            gap = start_in - bus_free
            n_fit = int(gap / t_slice)
            n_have = int(-(-read_bytes_left // slice_bytes))
            n = min(n_fit, n_have)
            if n > 0:
                t = bus_free
                for _s in range(n):
                    t = occupy(t, t_slice, "read")
                read_bytes_left -= n * slice_bytes
                reads_done_at = t
                bus_free = t
                start_in = max(bus_free, dies_free - t_in)
        end_in = occupy(start_in, t_in, "rc_in")
        bus_free = end_in
        die_start = max(end_in, dies_free)
        die_end = die_start + w.t_r
        dies_free = die_end
        # Result upload has priority at die_end, but slices may use the bubble
        # [end_in, die_end] first.
        if policy == Policy.RC_SLICED and read_bytes_left > 0:
            gap = die_end - bus_free
            n_fit = int(gap / t_slice)
            n_have = int(-(-read_bytes_left // slice_bytes))
            n = min(n_fit, n_have)
            if n > 0:
                t = bus_free
                for _s in range(n):
                    t = occupy(t, t_slice, "read")
                read_bytes_left -= n * slice_bytes
                reads_done_at = t
                bus_free = t
        start_out = max(bus_free, die_end)
        bus_free = occupy(start_out, t_out, "rc_out")
        rc_done = bus_free

    # Drain remaining reads after the last rc request.
    while read_bytes_left > 0:
        step = min(slice_bytes if policy == Policy.RC_SLICED else w.page_bytes,
                   read_bytes_left)
        bus_free = occupy(bus_free, step / w.bw, "read")
        read_bytes_left -= step
        reads_done_at = bus_free

    total = max(rc_done, reads_done_at)
    if total <= 0.0:
        total = 0.0
        util = 0.0
    else:
        util = bus_busy / total
    return SimResult(time=total, rc_done=rc_done, reads_done=reads_done_at,
                     bus_busy=bus_busy, util=util, segments=segments)


# ---------------------------------------------------------------------------
# Whole-model stream simulation
# ---------------------------------------------------------------------------
#
# A decode step is a *sequence* of GeMV matrices (layer order) interleaved
# with NPU-only phases (attention + KV-cache traffic).  Read-compute requests
# are activation-dependent (matrix k+1's input is matrix k's output) and so
# serialize at matrix barriers; plain weight READS are activation-independent
# and may prefetch ahead into any channel bubble, bounded by the NPU's weight
# buffer (``prefetch_bytes``).  This is the paper's Slice Control applied to
# the full request stream.


@dataclasses.dataclass(frozen=True)
class RCBlock:
    """One matrix's per-channel workload inside the stream."""

    n_tiles: int
    rc_input_bytes: float
    rc_result_bytes: float
    read_bytes: float  # NPU-bound weight bytes on this channel, this matrix
    t_r: float
    bw: float
    page_bytes: float = 16384.0


@dataclasses.dataclass(frozen=True)
class NpuPhase:
    """Channel-idle phase (attention / KV traffic); reads may still flow."""

    duration: float


@dataclasses.dataclass
class StreamResult:
    time: float
    bus_busy: float
    util: float
    stalled_on_reads: float  # time the barrier waited on undelivered reads


def simulate_stream(items: list, policy: Policy = Policy.RC_SLICED,
                    slice_bytes: int = DEFAULT_SLICE_BYTES,
                    prefetch_bytes: float = 32e6) -> StreamResult:
    """Simulate one channel executing the full decode stream.

    Matrix barriers: RCBlock ``i+1`` cannot start until block ``i``'s rc tiles
    are done AND its NPU-bound read bytes are delivered.  Reads are delivered
    FIFO; reads belonging to blocks at-or-before the executing block are
    always allowed, reads of future blocks prefetch into bubbles while the
    NPU-side weight buffer (``prefetch_bytes``) has room.
    """
    n = len(items)
    reads = [it.read_bytes if isinstance(it, RCBlock) else 0.0 for it in items]
    left = list(reads)
    finish = [0.0] * n  # when item i's reads were fully delivered

    bus_free = 0.0
    dies_free = 0.0
    bus_busy = 0.0
    stalled = 0.0
    q_head = 0
    while q_head < n and left[q_head] <= 0:
        q_head += 1
    delivered_total = 0.0
    consumed_total = 0.0  # reads of all blocks at-or-before the current barrier
    current = 0

    def fill_reads(until: float) -> None:
        """Deliver read data into the bus gap [bus_free, until]."""
        nonlocal bus_free, bus_busy, q_head, delivered_total
        if policy == Policy.RC_ONLY:
            return
        while q_head < n:
            it = items[q_head]
            if policy == Policy.RC_UNSLICED and q_head > current:
                return  # unsliced reads can't opportunistically prefetch
            step = slice_bytes if policy == Policy.RC_SLICED else it.page_bytes
            t_unit = step / it.bw
            gap = min(until, 1e30) - bus_free
            if gap < t_unit - 1e-15:
                return
            # prefetch cap for future blocks' reads
            if q_head > current:
                room = prefetch_bytes - (delivered_total - consumed_total)
                if room < step:
                    return
                budget_units = int(room / step)
            else:
                budget_units = 1 << 60
            units_left = int(-(-left[q_head] // step))
            k = min(int(gap / t_unit), units_left, budget_units)
            if k <= 0:
                return
            amt = min(k * step, left[q_head])
            bus_free += k * t_unit
            bus_busy += k * t_unit
            delivered_total += amt
            left[q_head] -= amt
            if left[q_head] <= 1e-9:
                finish[q_head] = bus_free
                q_head += 1
                while q_head < n and left[q_head] <= 0:
                    q_head += 1

    barrier = 0.0
    for i, it in enumerate(items):
        current = i
        if isinstance(it, NpuPhase):
            end = barrier + it.duration
            fill_reads(end)
            barrier = end
            consumed_total += 0.0
            continue
        t_in = it.rc_input_bytes / it.bw
        t_out = it.rc_result_bytes / it.bw
        rc_done = barrier
        for _t in range(it.n_tiles):
            earliest = max(barrier, dies_free - t_in)
            # RC_UNSLICED head-of-line blocking: a pending whole-page read for
            # the current (or earlier) block transmits before the rc input.
            if (policy == Policy.RC_UNSLICED and q_head <= i and q_head < n
                    and left[q_head] > 0):
                fill_reads(max(bus_free, earliest) + it.page_bytes / it.bw)
            else:
                fill_reads(max(bus_free, earliest))
            start_in = max(bus_free, earliest)
            end_in = start_in + t_in
            bus_busy += t_in
            bus_free = end_in
            die_end = max(end_in, dies_free) + it.t_r
            dies_free = die_end
            fill_reads(die_end)
            start_out = max(bus_free, die_end)
            bus_free = start_out + t_out
            bus_busy += t_out
            rc_done = bus_free
        # Drain this block's own remaining reads (they gate the barrier).
        if q_head <= i and q_head < n and left[i] > 0:
            t0 = max(bus_free, rc_done)
            fill_reads(float("inf"))
            stalled += max(0.0, bus_free - t0)
        my_reads = finish[i] if reads[i] > 0 else 0.0
        barrier = max(rc_done, my_reads)
        consumed_total += reads[i]

    util = bus_busy / barrier if barrier > 0 else 0.0
    return StreamResult(time=barrier, bus_busy=bus_busy, util=util,
                        stalled_on_reads=stalled)
