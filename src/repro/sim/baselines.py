"""Analytic baseline models (paper Table III): Flexgen-SSD / Flexgen-DRAM /
MLC-LLM.  Single-batch decode is bandwidth-bound end to end, so each baseline
is modelled as weights-over-the-bottleneck-link plus framework efficiency.

Constants (documented calibration, public specs):
  * Flexgen-SSD : Intel PCIe-4 NVMe sequential read ~7 GB/s, efficiency 0.8
  * Flexgen-DRAM: PCIe 4.0 x16 host->GPU ~25 GB/s, efficiency 0.9
  * MLC-LLM     : Snapdragon 8 Gen 2 LPDDR5X ~50 GB/s effective, eff. 0.55,
                  4-bit weights (the paper's Table III: MLC-LLM runs W4)
Validation vs paper: OPT-6.7B Flexgen-SSD 0.81 tok/s (model: 0.84),
Flexgen-DRAM 3.52 tok/s (model: 3.47); Llama2-7B MLC-LLM 7.58 (model: 7.7).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.core import planner

NVME_BW = 7.0e9
NVME_EFF = 0.8
PCIE_BW = 25.0e9
PCIE_EFF = 0.9
PHONE_DRAM_BW = 50.0e9
PHONE_EFF = 0.55


def _weight_bytes(cfg: ModelConfig, bytes_per_elem: float) -> float:
    return sum(m.active_params for m in planner.model_matrices(cfg)) * bytes_per_elem


def flexgen_ssd_tokens_per_s(cfg: ModelConfig, bytes_per_elem: float = 1.0) -> float:
    return NVME_BW * NVME_EFF / _weight_bytes(cfg, bytes_per_elem)


def flexgen_dram_tokens_per_s(cfg: ModelConfig, bytes_per_elem: float = 1.0) -> float:
    return PCIE_BW * PCIE_EFF / _weight_bytes(cfg, bytes_per_elem)


def mlc_llm_tokens_per_s(cfg: ModelConfig, bytes_per_elem: float = 0.5) -> float:
    """4-bit round-to-nearest quantization on a Snapdragon 8 Gen 2."""
    return PHONE_DRAM_BW * PHONE_EFF / _weight_bytes(cfg, bytes_per_elem)


def mlc_llm_fits_dram(cfg: ModelConfig, dram_bytes: float = 12e9,
                      bytes_per_elem: float = 0.5) -> bool:
    """MLC-LLM OOMs beyond ~7B on a 12-16GB phone (paper: 13B/70B OOM)."""
    return _weight_bytes(cfg, bytes_per_elem) + 2e9 < dram_bytes
