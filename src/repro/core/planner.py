"""Model → GeMV workload → per-matrix Cambricon-LLM plans.

``model_matrices`` enumerates every weight matrix a model streams during
decode (the paper's unit of work: >95% of single-batch decode is GeMV).
``plan_model`` applies the §V tiling/α-split to each matrix and aggregates the
analytic per-token time; ``sim/llm_perf.py`` runs the same plans through the
event-driven channel simulator for the faithful numbers.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.hw import FlashSpec, NPUSpec
from repro.core import tiling


@dataclasses.dataclass(frozen=True)
class GemvMatrix:
    """One distinct weight matrix shape in the model.

    ``count``        — stored instances (contributes to capacity/params).
    ``active_count`` — instances streamed per decoded token (MoE: top-k routed
                       + shared; zamba2 shared block: one stored copy streamed
                       at every invocation).
    """

    name: str
    h: int  # output dim (GeMV result length)
    w: int  # input dim
    count: int
    active_count: int = -1  # -1 -> == count
    is_expert: bool = False

    def __post_init__(self):
        if self.active_count < 0:
            object.__setattr__(self, "active_count", self.count)

    @property
    def params(self) -> int:
        return self.h * self.w * self.count

    @property
    def active_params(self) -> int:
        return self.h * self.w * self.active_count


def _attn_matrices(cfg: ModelConfig, n_layers: int, prefix: str = "",
                   active_mult: int = 1, stored: int | None = None) -> list[GemvMatrix]:
    stored = n_layers if stored is None else stored
    active = n_layers * active_mult
    qkv_out = cfg.n_heads * cfg.d_head
    kv_out = cfg.n_kv_heads * cfg.d_head
    return [
        GemvMatrix(prefix + "attn.q", qkv_out, cfg.d_model, stored, active),
        GemvMatrix(prefix + "attn.k", kv_out, cfg.d_model, stored, active),
        GemvMatrix(prefix + "attn.v", kv_out, cfg.d_model, stored, active),
        GemvMatrix(prefix + "attn.o", cfg.d_model, qkv_out, stored, active),
    ]


def _ffn_matrices(cfg: ModelConfig, d_ff: int, n_layers: int, prefix: str = "",
                  active_mult: int = 1, stored: int | None = None) -> list[GemvMatrix]:
    stored = n_layers if stored is None else stored
    active = n_layers * active_mult
    mats = []
    if cfg.gated_ffn:
        mats.append(GemvMatrix(prefix + "ffn.gate", d_ff, cfg.d_model, stored, active))
    mats.append(GemvMatrix(prefix + "ffn.up", d_ff, cfg.d_model, stored, active))
    mats.append(GemvMatrix(prefix + "ffn.down", cfg.d_model, d_ff, stored, active))
    return mats


def _moe_matrices(cfg: ModelConfig, n_moe_layers: int) -> list[GemvMatrix]:
    mats = [GemvMatrix("moe.router", cfg.n_experts, cfg.d_model, n_moe_layers)]
    gate_mats = 2 if cfg.gated_ffn else 1
    # routed experts: stored n_experts per layer, active top_k per layer
    for nm, h, w in [("gate", cfg.moe_d_ff, cfg.d_model),
                     ("up", cfg.moe_d_ff, cfg.d_model),
                     ("down", cfg.d_model, cfg.moe_d_ff)][2 - gate_mats:]:
        mats.append(GemvMatrix(
            f"moe.expert.{nm}", h, w,
            count=n_moe_layers * cfg.n_experts,
            active_count=n_moe_layers * cfg.top_k, is_expert=True))
        if cfg.n_shared_experts:
            mats.append(GemvMatrix(
                f"moe.shared.{nm}", h, w,
                count=n_moe_layers * cfg.n_shared_experts))
    return mats


def _mla_matrices(cfg: ModelConfig, n_layers: int) -> list[GemvMatrix]:
    qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
    return [
        GemvMatrix("mla.q", cfg.n_heads * qk_head, cfg.d_model, n_layers),
        GemvMatrix("mla.kv_a", cfg.kv_lora_rank + cfg.qk_rope_dim, cfg.d_model, n_layers),
        GemvMatrix("mla.kv_b", cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim),
                   cfg.kv_lora_rank, n_layers),
        GemvMatrix("mla.o", cfg.d_model, cfg.n_heads * cfg.v_head_dim, n_layers),
    ]


def _ssm_matrices(cfg: ModelConfig, n_layers: int) -> list[GemvMatrix]:
    d_in = cfg.d_inner
    proj_out = 2 * d_in + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_nheads
    return [
        GemvMatrix("ssm.in_proj", proj_out, cfg.d_model, n_layers),
        GemvMatrix("ssm.out_proj", cfg.d_model, d_in, n_layers),
    ]


def model_matrices(cfg: ModelConfig) -> list[GemvMatrix]:
    mats: list[GemvMatrix] = []
    f = cfg.family
    if f in ("dense", "vlm"):
        mats += _attn_matrices(cfg, cfg.n_layers)
        mats += _ffn_matrices(cfg, cfg.d_ff, cfg.n_layers)
    elif f == "moe":
        mats += _attn_matrices(cfg, cfg.n_layers)
        mats += _moe_matrices(cfg, cfg.n_layers)
    elif f == "mla_moe":
        mats += _mla_matrices(cfg, cfg.n_layers)
        n_moe = cfg.n_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            mats += _ffn_matrices(cfg, cfg.dense_d_ff, cfg.first_k_dense, "dense.")
        mats += _moe_matrices(cfg, n_moe)
    elif f == "audio":
        # encoder weights: stored, but not streamed per decoded token
        mats += _attn_matrices(cfg, cfg.n_encoder_layers, "enc.", active_mult=0)
        mats += _ffn_matrices(cfg, cfg.d_ff, cfg.n_encoder_layers, "enc.", active_mult=0)
        mats += _attn_matrices(cfg, cfg.n_layers, "dec.")
        # cross attention: k/v applied to encoder states at prefill only
        qkv_out = cfg.n_heads * cfg.d_head
        mats += [
            GemvMatrix("dec.xattn.q", qkv_out, cfg.d_model, cfg.n_layers),
            GemvMatrix("dec.xattn.k", qkv_out, cfg.d_model, cfg.n_layers, 0),
            GemvMatrix("dec.xattn.v", qkv_out, cfg.d_model, cfg.n_layers, 0),
            GemvMatrix("dec.xattn.o", cfg.d_model, qkv_out, cfg.n_layers),
        ]
        mats += _ffn_matrices(cfg, cfg.d_ff, cfg.n_layers, "dec.")
    elif f == "hybrid":
        mats += _ssm_matrices(cfg, cfg.n_layers)
        n_invocations = cfg.n_layers // cfg.shared_attn_every
        mats += _attn_matrices(cfg, 1, "shared.", active_mult=n_invocations)
        mats += _ffn_matrices(cfg, cfg.d_ff, 1, "shared.", active_mult=n_invocations)
    elif f == "ssm":
        mats += _ssm_matrices(cfg, cfg.n_layers)
    else:
        raise ValueError(f"unknown family {f!r}")
    # LM head: one GeMV per token (tied or not, it is streamed).
    mats.append(GemvMatrix("lm_head", cfg.vocab_size, cfg.d_model, 1))
    if not cfg.tie_embeddings:
        # embedding table: stored; lookup is a row-gather, not a streamed GeMV
        mats.append(GemvMatrix("embed", cfg.vocab_size, cfg.d_model, 1, 0))
    return mats


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    cfg: ModelConfig
    flash: FlashSpec
    bytes_per_elem: float
    plans: tuple[tuple[GemvMatrix, tiling.MatrixPlan], ...]

    @property
    def stored_bytes(self) -> float:
        return sum(m.params for m, _ in self.plans) * self.bytes_per_elem

    @property
    def streamed_bytes_per_token(self) -> float:
        return sum(m.active_params for m, _ in self.plans) * self.bytes_per_elem

    def analytic_token_time(self, npu: NPUSpec | None = None,
                            seq_len: int = 1024) -> float:
        """Sum of per-matrix GeMV times + NPU-side attention/KV-cache time."""
        npu = npu or NPUSpec()
        t = 0.0
        for mat, plan in self.plans:
            t += mat.active_count * tiling.matrix_time_analytic(plan, self.flash, npu)
        t += kv_cache_time(self.cfg, seq_len, npu)
        return t


def kv_cache_time(cfg: ModelConfig, seq_len: int, npu: NPUSpec) -> float:
    """DRAM-side time: stream the KV cache (or SSM state) once per token."""
    kv_bytes = kv_cache_bytes(cfg, seq_len, batch=1)
    return kv_bytes / npu.dram_bw


def kv_cache_bytes(cfg: ModelConfig, seq_len: int, batch: int,
                   bytes_per_elem: int = 2) -> int:
    """KV cache (or SSM state) footprint for ``batch`` sequences."""
    f = cfg.family
    if f == "ssm":
        # state: (nheads, headdim, state) + rolling conv window, per layer
        per_layer = (cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state
                     + cfg.ssm_conv * (cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state))
        return batch * cfg.n_layers * per_layer * bytes_per_elem
    if f == "hybrid":
        ssm_state = (cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state
                     + cfg.ssm_conv * (cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state))
        n_inv = cfg.n_layers // cfg.shared_attn_every
        attn_kv = 2 * n_inv * cfg.n_kv_heads * cfg.d_head * seq_len
        return batch * (cfg.n_layers * ssm_state + attn_kv) * bytes_per_elem
    if f == "mla_moe":
        per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim  # compressed MLA cache
        return batch * cfg.n_layers * per_tok * seq_len * bytes_per_elem
    if f == "audio":
        self_kv = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.d_head * seq_len
        cross_kv = 2 * cfg.n_layers * cfg.n_heads * cfg.d_head * cfg.encoder_seq
        return batch * (self_kv + cross_kv) * bytes_per_elem
    return batch * 2 * cfg.n_layers * cfg.n_kv_heads * cfg.d_head * seq_len * bytes_per_elem


def plan_model(cfg: ModelConfig, flash: FlashSpec,
               bytes_per_elem: float = 1.0,
               alpha_override: float | None = None,
               tile_override: tiling.TileShape | None = None) -> ModelPlan:
    plans = []
    for mat in model_matrices(cfg):
        plans.append((mat, tiling.plan_matrix(
            mat.h, mat.w, flash, bytes_per_elem,
            alpha_override=alpha_override, tile_override=tile_override)))
    return ModelPlan(cfg=cfg, flash=flash, bytes_per_elem=bytes_per_elem,
                     plans=tuple(plans))


# ---------------------------------------------------------------------------
# Ordered per-token execution stream (for the whole-model channel simulation)
# ---------------------------------------------------------------------------


def decode_execution_stream(cfg: ModelConfig) -> list[tuple]:
    """The decode step as an ordered list of execution items.

    Items: ``("gemv", h, w)`` — one weight-matrix GeMV;
           ``("attn",)``      — NPU attention + KV-cache phase (one layer);
           ``("ssm",)``       — NPU SSD state update phase (one layer).
    """
    items: list[tuple] = []
    qkv = cfg.n_heads * cfg.d_head
    kvo = cfg.n_kv_heads * cfg.d_head

    def attn_block():
        items.append(("gemv", qkv, cfg.d_model))
        items.append(("gemv", kvo, cfg.d_model))
        items.append(("gemv", kvo, cfg.d_model))
        items.append(("attn",))
        items.append(("gemv", cfg.d_model, qkv))

    def mla_block():
        qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
        items.append(("gemv", cfg.n_heads * qk_head, cfg.d_model))
        items.append(("gemv", cfg.kv_lora_rank + cfg.qk_rope_dim, cfg.d_model))
        items.append(("gemv", cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim),
                      cfg.kv_lora_rank))
        items.append(("attn",))
        items.append(("gemv", cfg.d_model, cfg.n_heads * cfg.v_head_dim))

    def ffn_block(d_ff: int):
        if cfg.gated_ffn:
            items.append(("gemv", d_ff, cfg.d_model))
        items.append(("gemv", d_ff, cfg.d_model))
        items.append(("gemv", cfg.d_model, d_ff))

    def moe_block():
        items.append(("gemv", cfg.n_experts, cfg.d_model))  # router
        for _ in range(cfg.top_k + cfg.n_shared_experts):
            ffn_block(cfg.moe_d_ff)

    def ssm_block():
        d_in = cfg.d_inner
        proj = 2 * d_in + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_nheads
        items.append(("gemv", proj, cfg.d_model))
        items.append(("ssm",))
        items.append(("gemv", cfg.d_model, d_in))

    f = cfg.family
    if f in ("dense", "vlm"):
        for _ in range(cfg.n_layers):
            attn_block()
            ffn_block(cfg.d_ff)
    elif f == "moe":
        for _ in range(cfg.n_layers):
            attn_block()
            moe_block()
    elif f == "mla_moe":
        for i in range(cfg.n_layers):
            mla_block()
            if i < cfg.first_k_dense:
                ffn_block(cfg.dense_d_ff)
            else:
                moe_block()
    elif f == "audio":
        for _ in range(cfg.n_layers):  # decoder-only weights stream per token
            attn_block()  # self attention
            items.append(("gemv", qkv, cfg.d_model))  # cross-attn q
            items.append(("attn",))                   # cross attention
            items.append(("gemv", cfg.d_model, qkv))  # cross-attn o
            ffn_block(cfg.d_ff)
    elif f == "hybrid":
        for i in range(cfg.n_layers):
            ssm_block()
            if cfg.shared_attn_every and (i + 1) % cfg.shared_attn_every == 0:
                attn_block()
                ffn_block(cfg.d_ff)
    elif f == "ssm":
        for _ in range(cfg.n_layers):
            ssm_block()
    else:
        raise ValueError(f)
    items.append(("gemv", cfg.vocab_size, cfg.d_model))  # lm head
    return items
