"""Outlier-oriented on-die error correction (paper §VI), bit-exact in JAX.

Per 16KB page of INT8 weights (16384 elements):

* top-1% |magnitude| values (163 entries) are "outliers";
* the ECC sidecar stores, per page:
    - the protection threshold (smallest |outlier|), replicated 9×,
    - per outlier: 14-bit address + 5-bit Hamming parity + N=2 value copies;
  total 8*9 + (14+5+16)*163 = 5777 bits ≈ 722 B < 1664 B page spare area;
* decode: per-bit majority vote of {in-page value, copy0, copy1} for protected
  addresses (protected flip rate ≈ 3x² for raw BER x); any unprotected value
  whose magnitude exceeds the threshold is a fake outlier minted by a bit flip
  and is clamped to zero.

All functions are jit/vmap friendly; pages batch along a leading axis.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

PAGE_ELEMS = 16384
OUTLIER_FRACTION = 0.01
THRESHOLD_COPIES = 9
VALUE_COPIES = 2  # N in the paper (even)
ADDR_BITS = 14
HAMMING_PARITY_BITS = 5


def n_outliers(page_elems: int = PAGE_ELEMS) -> int:
    return int(page_elems * OUTLIER_FRACTION)


def ecc_size_bits(page_elems: int = PAGE_ELEMS) -> int:
    """Paper: 8*9 + (14 + 5 + 8*N) * n_outliers bits (722 B for a 16KB page)."""
    per_entry = ADDR_BITS + HAMMING_PARITY_BITS + 8 * VALUE_COPIES
    return 8 * THRESHOLD_COPIES + per_entry * n_outliers(page_elems)


class PageECC(NamedTuple):
    """ECC sidecar for a batch of pages. Leading dims are batch dims."""

    threshold: jax.Array  # (..., 9)  uint8 magnitude copies
    addr: jax.Array       # (..., K)  uint16, 14-bit addresses
    addr_parity: jax.Array  # (..., K) uint8, 5-bit Hamming parity
    copies: jax.Array     # (..., K, N) uint8 bit patterns of the outlier values


# --------------------------------------------------------------------------
# Hamming(19,14) single-error-correcting code over the 14-bit address.
# Parity bit p_i (i=0..4) covers data bits whose (position+1) has bit i set in
# the classic Hamming layout.  We precompute masks over data-bit indices.
# --------------------------------------------------------------------------


@functools.lru_cache(None)
def _hamming_layout():
    """Return (data_positions, parity_positions) in the 19-bit codeword.

    Codeword positions are 1-based 1..19; positions that are powers of two
    (1,2,4,8,16) hold parity, the rest hold the 14 data bits in order.
    """
    parity_pos = [1, 2, 4, 8, 16]
    data_pos = [p for p in range(1, 20) if p not in parity_pos]
    return tuple(data_pos), tuple(parity_pos)


def hamming_encode(addr: jax.Array) -> jax.Array:
    """addr: uint16 with 14 significant bits -> 5-bit parity, uint8."""
    data_pos, parity_pos = _hamming_layout()
    addr = addr.astype(jnp.uint32)
    parity = jnp.zeros_like(addr)
    for i, pp in enumerate(parity_pos):
        acc = jnp.zeros_like(addr)
        for k, dp in enumerate(data_pos):
            if dp & pp:
                acc = acc ^ ((addr >> k) & 1)
        parity = parity | (acc << i)
    return parity.astype(jnp.uint8)


def hamming_correct(addr: jax.Array, parity: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Correct single-bit errors in (addr, parity); return (addr, valid).

    ``valid`` is False when the syndrome points outside the codeword (a
    detectable multi-bit error) — the paper discards such entries.
    Double-bit errors may alias to a miscorrection (inherent to SEC codes).
    """
    data_pos, parity_pos = _hamming_layout()
    addr = addr.astype(jnp.uint32)
    parity = parity.astype(jnp.uint32)
    syndrome = jnp.zeros_like(addr)
    for i, pp in enumerate(parity_pos):
        acc = (parity >> i) & 1
        for k, dp in enumerate(data_pos):
            if dp & pp:
                acc = acc ^ ((addr >> k) & 1)
        syndrome = syndrome | (acc << i)
    # syndrome == 0 -> clean. syndrome == codeword position -> flip that bit.
    corrected = addr
    for k, dp in enumerate(data_pos):
        corrected = jnp.where(syndrome == dp, corrected ^ (1 << k), corrected)
    # Parity-position syndromes (1,2,4,8,16) mean the parity bit itself
    # flipped; the address is fine.
    valid = syndrome <= 19
    return corrected.astype(jnp.uint16), valid


# --------------------------------------------------------------------------
# Bit-level helpers
# --------------------------------------------------------------------------


def _majority3_u8(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    return ((a & b) | (a & c) | (b & c)).astype(jnp.uint8)


def _majority_bits(copies: jax.Array, axis: int) -> jax.Array:
    """Per-bit majority over an odd number of uint8 copies along ``axis``."""
    axis = axis % copies.ndim  # normalize; the bit axis is appended last
    n = copies.shape[axis]
    bits = jnp.stack([(copies >> k) & 1 for k in range(8)], axis=-1)  # (..., n, 8)
    counts = bits.astype(jnp.int32).sum(axis=axis)
    maj = (counts > n // 2).astype(jnp.uint8)
    out = jnp.zeros(maj.shape[:-1], jnp.uint8)
    for k in range(8):
        out = out | (maj[..., k] << k)
    return out


def _abs_i8(v_u8: jax.Array) -> jax.Array:
    """|value| of an int8 bit pattern, computed in int32 (|-128| = 128)."""
    return jnp.abs(v_u8.astype(jnp.int8).astype(jnp.int32))


# --------------------------------------------------------------------------
# Encode / decode
# --------------------------------------------------------------------------


def encode_page(page_u8: jax.Array) -> PageECC:
    """Build the ECC sidecar for one page of int8 bit patterns (uint8[P])."""
    p = page_u8.shape[-1]
    k = n_outliers(p)
    mags = _abs_i8(page_u8)
    top_mags, top_idx = jax.lax.top_k(mags, k)
    threshold_mag = top_mags[-1]  # smallest protected magnitude
    threshold = jnp.broadcast_to(
        jnp.minimum(threshold_mag, 255).astype(jnp.uint8), (THRESHOLD_COPIES,))
    addr = top_idx.astype(jnp.uint16)
    parity = hamming_encode(addr)
    vals = page_u8[top_idx]
    copies = jnp.broadcast_to(vals[:, None], (k, VALUE_COPIES)).astype(jnp.uint8)
    return PageECC(threshold=threshold, addr=addr, addr_parity=parity, copies=copies)


def decode_page(page_u8: jax.Array, ecc: PageECC) -> jax.Array:
    """Correct one (possibly corrupted) page given its (possibly corrupted) ECC."""
    threshold = _majority_bits(ecc.threshold, axis=-1).astype(jnp.int32)
    addr, valid = hamming_correct(ecc.addr, ecc.addr_parity)
    addr = jnp.minimum(addr.astype(jnp.int32), page_u8.shape[-1] - 1)

    # Fake-outlier suppression: unprotected values above threshold -> 0.
    mags = _abs_i8(page_u8)
    protected_mask = jnp.zeros(page_u8.shape[-1], bool).at[addr].set(valid, mode="drop")
    out = jnp.where((mags > threshold) & ~protected_mask, jnp.uint8(0), page_u8)

    # Outlier restoration: per-bit majority of {in-page value, copy0, copy1}.
    in_page = page_u8[addr]
    voted = _majority3_u8(in_page, ecc.copies[:, 0], ecc.copies[:, 1])
    restored = jnp.where(valid, voted, out[addr])
    return out.at[addr].set(restored, mode="drop")


def encode_pages(pages_u8: jax.Array) -> PageECC:
    """vmap of encode_page over a leading batch of pages (B, P)."""
    return jax.vmap(encode_page)(pages_u8)


def decode_pages(pages_u8: jax.Array, ecc: PageECC) -> jax.Array:
    return jax.vmap(decode_page)(pages_u8, ecc)


# --------------------------------------------------------------------------
# Error injection (the paper's "flash error models of varying intensities")
# --------------------------------------------------------------------------


def inject_bitflips(arr_u8: jax.Array, ber: float, key: jax.Array) -> jax.Array:
    """Flip each bit of ``arr_u8`` independently with probability ``ber``."""
    flips = jax.random.bernoulli(key, ber, arr_u8.shape + (8,))
    weights = (1 << jnp.arange(8, dtype=jnp.uint32))
    mask = (flips.astype(jnp.uint32) * weights).sum(-1).astype(jnp.uint8)
    return arr_u8 ^ mask


def inject_ecc_bitflips(ecc: PageECC, ber: float, key: jax.Array) -> PageECC:
    """Corrupt the ECC sidecar itself (it lives in the same flash)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    thr = inject_bitflips(ecc.threshold, ber, k1)
    copies = inject_bitflips(ecc.copies, ber, k2)
    parity = inject_bitflips(ecc.addr_parity, ber, k3) & 0x1F
    addr16 = ecc.addr
    flips = jax.random.bernoulli(k4, ber, addr16.shape + (ADDR_BITS,))
    weights = (1 << jnp.arange(ADDR_BITS, dtype=jnp.uint32))
    mask = (flips.astype(jnp.uint32) * weights).sum(-1).astype(jnp.uint16)
    return PageECC(threshold=thr, addr=addr16 ^ mask, addr_parity=parity, copies=copies)


def protected_flip_rate(ber: float, n_copies: int = VALUE_COPIES) -> float:
    """Closed form f_prot ≈ C(N+1, N/2+1) x^{N/2+1} (paper §VI). N=2 -> 3x²."""
    import math

    n = n_copies
    total = 0.0
    for i in range(n // 2 + 1, n + 2):
        total += math.comb(n + 1, i) * ber**i * (1 - ber) ** (n + 1 - i)
    return total
