"""TPU-native realization of the paper's α-split (DESIGN.md §2).

On a TPU mesh the "flash tier" is the ``data`` axis holding a 1/N shard of
every weight matrix (ZeRO-3 layout).  For each matrix and each step kind the
planner chooses between — or mixes — two collective schedules:

  SHIP-ACTIVATIONS ("read-compute request"):
      keep weights sharded; every chip computes a partial GeMV on its shard
      and the small outputs are reduce-scattered / all-reduced.
      per-step ICI bytes  ≈ c_act = 2 * out_dim * tokens * act_bytes
      per-step HBM bytes  ≈ weight_shard = h*w*bpe / N      (every chip)

  SHIP-WEIGHTS ("read request"):
      all-gather the weight shard ring-wise, compute locally.
      per-step ICI bytes  ≈ c_w = h*w*bpe * (N-1)/N
      per-step HBM bytes  ≈ h*w*bpe  (the gathered copy is streamed once)

Decode (tokens≈1) makes ship-activations strictly cheaper (the paper's
arithmetic-intensity-2 regime); large-token training flips the balance
exactly like the paper's α balances t_r vs t_rc.  ``alpha_tpu`` returns the
fraction of rows to run ship-activations so both links/paths finish together
(compute overlap assumed, as the paper overlaps flash and channel paths).
"""

from __future__ import annotations

import dataclasses

from repro.core.hw import TPUSpec, TPU_V5E


@dataclasses.dataclass(frozen=True)
class TpuMatrixPlan:
    h: int
    w: int
    tokens: int
    n_shards: int
    alpha: float            # fraction of rows via ship-activations
    t_ship_act: float       # time if fully ship-activations
    t_ship_weights: float   # time if fully ship-weights
    t_hybrid: float

    @property
    def schedule(self) -> str:
        if self.alpha >= 0.99:
            return "ship_activations"
        if self.alpha <= 0.01:
            return "ship_weights"
        return "hybrid"


def _t_act(h: int, w: int, tokens: int, n: int, bpe_w: float, bpe_a: float,
           tpu: TPUSpec) -> float:
    """Ship-activations time: local shard GeMM + output all-reduce."""
    hbm = h * w * bpe_w / n / tpu.hbm_bw
    flops = 2 * h * w * tokens / n / tpu.peak_flops_bf16
    ici = 2 * h * tokens * bpe_a * (n - 1) / n / tpu.ici_bw_per_link
    return max(hbm, flops) + ici


def _t_w(h: int, w: int, tokens: int, n: int, bpe_w: float,
         tpu: TPUSpec) -> float:
    """Ship-weights time: ring all-gather overlapped with local GeMM."""
    ici = h * w * bpe_w * (n - 1) / n / tpu.ici_bw_per_link
    hbm = h * w * bpe_w / tpu.hbm_bw
    flops = 2 * h * w * tokens / tpu.peak_flops_bf16
    return max(ici, hbm, flops)


def alpha_tpu(h: int, w: int, tokens: int, n_shards: int,
              bpe_w: float = 1.0, bpe_a: float = 2.0,
              tpu: TPUSpec = TPU_V5E) -> TpuMatrixPlan:
    """Balance the two schedules over row-subsets of one matrix.

    Rows split α:(1-α); the two paths run concurrently on disjoint link
    budgets is *not* true on TPU (same ICI), so the hybrid runs them back to
    back: t(α) = t_act(αh) + t_w((1-α)h).  t is piecewise-linear in α, so the
    optimum is at an endpoint unless the paths bottleneck differently —
    we evaluate the three candidates and keep the best (the paper's AM-GM
    reasoning collapses to this on a shared link).
    """
    t_a = _t_act(h, w, tokens, n_shards, bpe_w, bpe_a, tpu)
    t_s = _t_w(h, w, tokens, n_shards, bpe_w, tpu)
    # interior candidate: overlap HBM of the act path with ICI of the weight
    # path (different resources!) — stream (1-α) of rows while computing α.
    best_alpha, best_t = (1.0, t_a) if t_a <= t_s else (0.0, t_s)
    for k in range(1, 8):
        a = k / 8.0
        t_mix = max(_t_act(int(a * h), w, tokens, n_shards, bpe_w, bpe_a, tpu),
                    _t_w(h - int(a * h), w, tokens, n_shards, bpe_w, tpu))
        if t_mix < best_t:
            best_alpha, best_t = a, t_mix
    return TpuMatrixPlan(h=h, w=w, tokens=tokens, n_shards=n_shards,
                         alpha=best_alpha, t_ship_act=t_a, t_ship_weights=t_s,
                         t_hybrid=best_t)
