"""Hardware-aware tiling (paper §V).

Pure closed-form math, no jax required.  Everything here is unit-tested with
hypothesis against brute-force enumeration (AM-GM optimality, α balance).

Definitions (paper notation):

* A weight matrix ``(H_weight, W_weight)`` is cut into tiles ``(H_req, W_req)``.
  One tile = one read-compute request, computed cooperatively by every compute
  core in the flash; each core owns an *atomic tile* of exactly one page.
* Channel traffic per tile with input broadcast on a channel (scheme (b)):
      Trans = W_req + channel_num * H_req
  subject to   H_req * W_req = channel_num * ccore_num * pagesize_elems
  AM-GM minimum at
      H_req* = sqrt(ccore_num * pagesize_elems)
      W_req* = channel_num * sqrt(ccore_num * pagesize_elems)
* Workload split α (fraction of the matrix processed in-flash) balances the
  time of read-compute requests against plain (sliced) read requests that feed
  the NPU through leftover channel bandwidth.

The same API also serves the TPU adaptation: ``pagesize_elems`` becomes the
per-core VMEM tile element count and channels/ccores become mesh-axis sizes —
see core/partition_plan.py.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.hw import FlashSpec, NPUSpec


@dataclasses.dataclass(frozen=True)
class TileShape:
    h: int  # H_req: rows of the weight tile (output elements of the GeMV)
    w: int  # W_req: cols of the weight tile (input elements of the GeMV)

    @property
    def elems(self) -> int:
        return self.h * self.w


def channel_traffic_broadcast(h_req: int, w_req: int, channel_num: int) -> int:
    """Trans for splitting scheme (b): input vectors broadcast per channel.

    Each channel ships the full ``w_req`` input once (broadcast to its ccores)
    and returns its ``h_req``-long partial result slice per channel.
    """
    return w_req + channel_num * h_req


def channel_traffic_no_reuse(h_req: int, w_req: int, channel_num: int, ccore_num: int) -> int:
    """Trans for the inferior scheme (c): no input reuse across a channel."""
    return ccore_num * w_req + channel_num * h_req


def optimal_tile(flash: FlashSpec, bytes_per_elem: float = 1.0) -> TileShape:
    """Paper §V-A closed form, rounded to integers that preserve the invariant.

    ``pagesize`` in the paper is in weight *elements* (INT8 → bytes == elems).
    For W4A16 mode ``bytes_per_elem=0.5`` doubles the elements per page.
    """
    pagesize_elems = int(flash.page_bytes / bytes_per_elem)
    ccore = flash.ccores_per_channel
    root = math.isqrt(ccore * pagesize_elems)
    # Snap H to a power of two so the page invariant holds exactly (all flash
    # geometry params are powers of two) and tiles stay MXU/128-aligned in the
    # TPU adaptation.  For exact squares (e.g. -S: ccore=4, page=16K -> 256)
    # this is the paper's closed form verbatim; otherwise pick the power-of-2
    # neighbour minimizing Trans (ties -> smaller H: smaller result vectors).
    lo = 1 << (root.bit_length() - 1)
    hi = lo * 2
    total = flash.channels * ccore * pagesize_elems

    def trans(h: int) -> int:
        return total // (flash.channels * h) * flash.channels + flash.channels * h

    h = lo if trans(lo) <= trans(hi) else hi
    w = total // (flash.channels * h) * flash.channels  # divisible by channels
    return TileShape(h=h, w=w)


def min_channel_traffic(flash: FlashSpec, bytes_per_elem: float = 1.0) -> float:
    """min Trans = 2 * channel_num * sqrt(ccore_num * pagesize_elems)."""
    pagesize_elems = flash.page_bytes / bytes_per_elem
    return 2.0 * flash.channels * math.sqrt(flash.ccores_per_channel * pagesize_elems)


def read_compute_time(flash: FlashSpec, tile: TileShape, bytes_per_elem: float = 1.0) -> float:
    """t_rc = tR + W_req / (channel_num * bw_channel)   (paper §V-B).

    Input vector elements are activations; the paper's formulation counts the
    INT8 input stream, we scale by activation byte width (INT8=1, bf16=2 for
    W4A16 mode's 16-bit activations).
    """
    act_bytes = 1.0 if bytes_per_elem >= 1.0 else 2.0
    return (flash.t_r + flash.t_cmd
            + (tile.w * act_bytes) / (flash.channels * flash.bw_channel))


def rc_channel_utilization(flash: FlashSpec, tile: TileShape, bytes_per_elem: float = 1.0) -> float:
    """rate_rc = (H_req + W_req/channel_num) / (tR * bw_channel)."""
    act_bytes = 1.0 if bytes_per_elem >= 1.0 else 2.0
    per_channel_bytes = tile.h * act_bytes + (tile.w * act_bytes) / flash.channels
    return per_channel_bytes / (flash.t_r * flash.bw_channel)


def read_time(flash: FlashSpec, tile: TileShape, bytes_per_elem: float = 1.0) -> float:
    """t_r = pagesize / ((1 - rate_rc) * bw_channel): a plain page read through
    the bandwidth left over by read-compute traffic."""
    rate = min(rc_channel_utilization(flash, tile, bytes_per_elem), 0.999)
    return flash.page_bytes / ((1.0 - rate) * flash.bw_channel)


def alpha_requests(flash: FlashSpec, tile: TileShape | None = None,
                   bytes_per_elem: float = 1.0) -> float:
    """The paper's literal §V-B expression  α = t_r / (t_r + t_rc).

    This is the balanced fraction of *requests* that are read-compute requests
    (one read-compute request per whole tile vs one read request per page).
    It is NOT the byte fraction — see :func:`alpha_split` for the byte-level
    split the planner actually uses (derived from the same balance condition).
    """
    if tile is None:
        tile = optimal_tile(flash, bytes_per_elem)
    t_rc = read_compute_time(flash, tile, bytes_per_elem)
    t_r = read_time(flash, tile, bytes_per_elem)
    return t_r / (t_r + t_rc)


def alpha_split(flash: FlashSpec, tile: TileShape | None = None,
                bytes_per_elem: float = 1.0) -> float:
    """Byte fraction of the weight matrix processed in-flash.

    Derived from the paper's balance condition ("execution times for read and
    read-compute requests are equal"):  the flash serializes tiles at ``t_rc``
    each (every tile occupies all compute cores; ``ccore_num`` pages per
    channel per tile), while each channel independently delivers NPU-bound
    pages at ``t_r`` each through leftover bandwidth.  Equal-time balance with
    ``N_r = channels * N_rc * t_rc / t_r`` reads gives byte fraction

        α_bytes = ccore_num * t_r / (ccore_num * t_r + t_rc).

    Sanity: for Cambricon-LLM-S this is ≈0.69, which reproduces the paper's
    Fig. 14 ablation (hybrid tiling 1.3–1.4× faster than flash-only); the
    literal request-ratio 0.35 would make the hybrid *slower* than flash-only.
    """
    if tile is None:
        tile = optimal_tile(flash, bytes_per_elem)
    t_rc = read_compute_time(flash, tile, bytes_per_elem)
    t_r = read_time(flash, tile, bytes_per_elem)
    cc = flash.ccores_per_channel
    return (cc * t_r) / (cc * t_r + t_rc)


@dataclasses.dataclass(frozen=True)
class MatrixPlan:
    """Execution plan for one weight matrix's GeMV (paper Fig. 7a).

    ``flash_rows`` rows are handled by read-compute requests in ``n_tiles``
    tiles of ``tile``; the remaining ``npu_rows`` stream to the NPU as sliced
    read requests.
    """

    h_weight: int
    w_weight: int
    tile: TileShape
    alpha: float
    flash_rows: int
    npu_rows: int
    n_tiles: int
    n_read_pages: int
    bytes_per_elem: float = 1.0

    @property
    def flash_bytes(self) -> float:
        return self.flash_rows * self.w_weight * self.bytes_per_elem

    @property
    def npu_bytes(self) -> float:
        return self.npu_rows * self.w_weight * self.bytes_per_elem


def fit_tile(tile: TileShape, h_weight: int, w_weight: int, flash: FlashSpec,
             bytes_per_elem: float = 1.0) -> TileShape:
    """Tailor the optimal tile to a concrete matrix (paper: "we tailor each
    weight matrix into this specific shape").

    * Matrix narrower than W_req*: split the width into equal columns
      (avoiding a nearly-empty ragged last column that would waste a full tR
      on idle cores), round W up to a channel multiple, and grow H so each
      compute core still holds ≤ one full page (H rounded down to a
      ccores-per-channel multiple — atomic tiles may underfill a page
      slightly, never overflow it).
    * Matrix smaller than one full tile: the tile degenerates to the whole
      matrix and some cores idle — the Fig. 15 saturation effect.
    """
    pagesize_elems = int(flash.page_bytes / bytes_per_elem)
    ch, cc = flash.channels, flash.ccores_per_channel
    total = ch * cc * pagesize_elems
    ncols = max(1, -(-w_weight // tile.w))
    w = -(-w_weight // (ncols * ch)) * ch  # even columns, channel-aligned
    h = total // max(w, 1) // cc * cc      # atomic tile fits in a page
    if h <= 0:
        h = min(cc, h_weight)
    if h > h_weight:
        h = max(h_weight, 1)
        w = min(total // h, w_weight)
    return TileShape(h=h, w=w)


def plan_matrix(h_weight: int, w_weight: int, flash: FlashSpec,
                bytes_per_elem: float = 1.0,
                alpha_override: float | None = None,
                tile_override: TileShape | None = None) -> MatrixPlan:
    """Build the §V plan for an ``(h_weight, w_weight)`` GeMV."""
    tile = tile_override or optimal_tile(flash, bytes_per_elem)
    tile = fit_tile(tile, h_weight, w_weight, flash, bytes_per_elem)
    alpha = alpha_split(flash, tile, bytes_per_elem) if alpha_override is None else alpha_override
    # Tile rows assigned to flash; the final tile may be partial (same tR,
    # fewer rows) so small matrices aren't forced to all-or-nothing splits.
    flash_rows = int(round(alpha * h_weight))
    flash_rows = max(0, min(flash_rows, h_weight))
    npu_rows = h_weight - flash_rows
    tiles_h = math.ceil(flash_rows / tile.h) if tile.h else 0
    tiles_w = math.ceil(w_weight / tile.w) if tile.w else 0
    n_tiles = tiles_h * tiles_w
    n_read_pages = math.ceil(npu_rows * w_weight * bytes_per_elem / flash.page_bytes)
    return MatrixPlan(
        h_weight=h_weight, w_weight=w_weight, tile=tile, alpha=alpha,
        flash_rows=flash_rows, npu_rows=npu_rows, n_tiles=n_tiles,
        n_read_pages=n_read_pages, bytes_per_elem=bytes_per_elem,
    )


def matrix_time_analytic(plan: MatrixPlan, flash: FlashSpec,
                         npu: NPUSpec | None = None) -> float:
    """Analytic steady-state execution time of one matrix (used by the planner;
    the event simulator in sim/ validates this within a few percent).

    Flash path: n_tiles read-compute requests, each t_rc, but all ccores work
    in parallel — a tile occupies every ccore for max(tR, input stream time).
    NPU path: npu_bytes through leftover channel bandwidth.
    Total = max(flash_path, npu_path) since they overlap by construction.
    """
    npu = npu or NPUSpec()
    t_rc = read_compute_time(flash, plan.tile, plan.bytes_per_elem)
    flash_time = plan.n_tiles * t_rc
    rate = min(rc_channel_utilization(flash, plan.tile, plan.bytes_per_elem), 0.999)
    leftover_bw = (1.0 - rate) * flash.total_channel_bw
    npu_stream_time = plan.npu_bytes / leftover_bw if plan.npu_bytes else 0.0
    npu_compute_time = 2.0 * plan.npu_rows * plan.w_weight / npu.ops_per_s
    return max(flash_time, npu_stream_time, npu_compute_time)
