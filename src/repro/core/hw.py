"""Hardware descriptions for Cambricon-LLM and its TPU adaptation.

Two families of hardware specs live here:

* :class:`FlashSpec` / :class:`NPUSpec` — the paper's edge hardware (NAND flash
  with on-die compute cores behind shared channels, a small systolic NPU with
  LPDDR5X).  These drive the §V tiling formulas and the ``sim/`` event
  simulator that reproduces the paper's evaluation.
* :class:`TPUSpec` — the TPU v5e target used by the multi-pod framework.  The
  same α-split planner (``core/partition_plan.py``) consumes it to divide each
  matrix between "ship-activations" (reduce-scatter) and "ship-weights"
  (all-gather) paths — the TPU-native realization of read-compute vs read
  requests.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FlashSpec:
    """NAND flash organisation (paper Table II).

    ``bw_channel`` is bytes/s on one channel bus (1000 MT/s × 8-bit = 1 GB/s).
    ``t_r`` is the page read time (NAND array -> data register), seconds.
    """

    channels: int = 8
    chips_per_channel: int = 2
    dies_per_chip: int = 2
    planes_per_die: int = 2
    ccores_per_die: int = 1
    page_bytes: int = 16 * 1024
    t_r: float = 30e-6
    t_cmd: float = 1e-6  # per-request command/address + FTL overhead (ONFI)
    bw_channel: float = 1.0e9  # 1000 MT/s, 8-bit bus
    # On-die compute core rating: must match array read speed (paper §IV-B).
    ccore_ops_per_s: float = 1.6e9

    @property
    def ccores_per_channel(self) -> int:
        return self.chips_per_channel * self.dies_per_chip * self.ccores_per_die

    @property
    def total_ccores(self) -> int:
        return self.channels * self.ccores_per_channel

    @property
    def total_channel_bw(self) -> float:
        return self.channels * self.bw_channel

    @property
    def page_read_bw_per_ccore(self) -> float:
        """Sustained array->register bandwidth one pipelined compute core sees."""
        return self.page_bytes / self.t_r

    @property
    def in_flash_bw(self) -> float:
        """Aggregate in-flash weight-processing bandwidth (all ccores)."""
        return self.total_ccores * self.page_read_bw_per_ccore


# Paper Table II configurations. S/M/L differ only in channel & chip counts.
CAMBRICON_LLM_S = FlashSpec(channels=8, chips_per_channel=2)
CAMBRICON_LLM_M = FlashSpec(channels=16, chips_per_channel=4)
CAMBRICON_LLM_L = FlashSpec(channels=32, chips_per_channel=8)

FLASH_CONFIGS = {
    "S": CAMBRICON_LLM_S,
    "M": CAMBRICON_LLM_M,
    "L": CAMBRICON_LLM_L,
}


@dataclasses.dataclass(frozen=True)
class NPUSpec:
    """The paper's edge NPU: 16x16 systolic @1GHz = 2 TOPS INT8, LPDDR5X DRAM."""

    ops_per_s: float = 2.0e12
    dram_bw: float = 40.0e9  # LPDDR5X ~40 GB/s, holds only the KV cache
    sfu_ops_per_s: float = 32.0e9  # special functions (softmax, sin/cos, ...)


DEFAULT_NPU = NPUSpec()


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """TPU v5e-class chip constants used for roofline + the TPU-mode planner."""

    peak_flops_bf16: float = 197e12
    peak_ops_int8: float = 394e12
    hbm_bw: float = 819e9
    ici_bw_per_link: float = 50e9  # ~50 GB/s per ICI link
    hbm_bytes: int = 16 * 1024**3
    vmem_bytes: int = 128 * 1024**2  # ~128MB VMEM on v5e-class
    mxu_dim: int = 128


TPU_V5E = TPUSpec()
