"""Hybrid NPU+flash GeMV as a composable JAX module (paper C1).

A weight matrix is partitioned by the §V plan into a *flash region*
(``flash_rows`` rows, executed tile-by-tile by the paged int8 kernel — the
compute-core analogue, with the outlier-ECC decode fused in front) and an
*NPU region* (remaining rows, plain dense GeMV — the weights that stream over
the channel).  Numerically the two paths agree exactly; structurally they
mirror the hardware mapping, and the flash path's Pallas kernel is the TPU
hot-spot implementation.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import ecc as ecc_mod
from repro.core import tiling
from repro.core.hw import FlashSpec
from repro.quant.int8 import quantize_weight


class HybridWeights(NamedTuple):
    """A planned, quantized, (optionally) ECC-protected weight matrix."""

    flash_wq: jax.Array        # int8 [flash_rows, w]
    flash_scale: jax.Array     # f32  [flash_rows]
    npu_wq: jax.Array          # int8 [npu_rows, w]
    npu_scale: jax.Array       # f32  [npu_rows]
    ecc: Optional[ecc_mod.PageECC]  # sidecar for the flash region's pages
    tile_h: int
    tile_w: int


def plan_and_quantize(w: jax.Array, flash: FlashSpec,
                      with_ecc: bool = False,
                      plan: tiling.MatrixPlan | None = None) -> HybridWeights:
    """Quantize + split a float weight matrix per the §V plan."""
    h, width = w.shape
    plan = plan or tiling.plan_matrix(h, width, flash)
    q = quantize_weight(w)
    fr = plan.flash_rows
    flash_wq, npu_wq = q.w_q[:fr], q.w_q[fr:]
    flash_scale, npu_scale = q.scale[:fr], q.scale[fr:]
    ecc = None
    if with_ecc and fr:
        pages = _to_pages(flash_wq)
        ecc = ecc_mod.encode_pages(pages)
    return HybridWeights(flash_wq=flash_wq, flash_scale=flash_scale,
                         npu_wq=npu_wq, npu_scale=npu_scale, ecc=ecc,
                         tile_h=plan.tile.h, tile_w=plan.tile.w)


def _to_pages(w_q: jax.Array, page_elems: int = ecc_mod.PAGE_ELEMS) -> jax.Array:
    flat = jax.lax.bitcast_convert_type(w_q.reshape(-1), jnp.uint8)
    pad = (-flat.shape[0]) % page_elems
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, page_elems)


def _from_pages(pages: jax.Array, shape: tuple[int, int]) -> jax.Array:
    n = shape[0] * shape[1]
    flat = pages.reshape(-1)[:n]
    return jax.lax.bitcast_convert_type(flat, jnp.int8).reshape(shape)


def corrupt_flash_region(hw: HybridWeights, ber: float, key: jax.Array,
                         corrupt_ecc: bool = True) -> HybridWeights:
    """Inject NAND bit flips into the flash-resident region (+ its ECC)."""
    pages = _to_pages(hw.flash_wq)
    k1, k2 = jax.random.split(key)
    noisy = ecc_mod.inject_bitflips(pages, ber, k1)
    new_ecc = hw.ecc
    if hw.ecc is not None and corrupt_ecc:
        new_ecc = ecc_mod.inject_ecc_bitflips(hw.ecc, ber, k2)
    return hw._replace(flash_wq=_from_pages(noisy, hw.flash_wq.shape),
                       ecc=new_ecc)


def hybrid_gemv(hw: HybridWeights, x: jax.Array,
                use_kernel: bool = True, interpret: bool = True) -> jax.Array:
    """y = W x through the two paths; ECC decode precedes the flash path."""
    flash_wq = hw.flash_wq
    if hw.ecc is not None and flash_wq.shape[0]:
        pages = _to_pages(flash_wq)
        corrected = ecc_mod.decode_pages(pages, hw.ecc)
        flash_wq = _from_pages(corrected, flash_wq.shape)
    parts = []
    if flash_wq.shape[0]:
        if use_kernel:
            from repro.kernels.int8_pagegemv.ops import paged_int8_gemv
            y_f = paged_int8_gemv(flash_wq, hw.flash_scale, x,
                                  tile_h=hw.tile_h, interpret=interpret)
        else:
            from repro.kernels.int8_pagegemv.ref import paged_int8_gemv_ref
            y_f = paged_int8_gemv_ref(flash_wq, hw.flash_scale, x)
        parts.append(y_f)
    if hw.npu_wq.shape[0]:
        from repro.kernels.int8_pagegemv.ref import paged_int8_gemv_ref
        parts.append(paged_int8_gemv_ref(hw.npu_wq, hw.npu_scale, x))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]
