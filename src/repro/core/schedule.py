"""Slice Control (paper §IV-C): request types and channel scheduling policies.

A matrix plan expands into, per flash channel:

* ``n_tiles`` READ-COMPUTE requests — input-vector broadcast down the channel,
  ~tR of in-die work on every compute core, result partials back up;
* ``reads_per_channel`` plain READ requests (pages bound for the NPU), each
  optionally segmented into ``slice_bytes`` slices that are interposed into
  the channel-occupancy bubbles between read-compute transfers;
* ``n_writes`` plain WRITE requests (pages bound for the flash dies) — the
  Fig. 6 model extended for the tiered KV cache: when the serving engine
  spills cold KV pages to the flash tier (``serving/kv_cache.py``,
  ``TieredPageAllocator``), the spilled page rides the channel bus NPU→die
  and the later prefetch rides it die→NPU.  Both directions are sliced and
  interposed into the same bubbles as plain reads (writes program an idle
  plane, so like NPU-bound reads they contend only for the bus in this
  model).  See the "Flash-resident KV pages" design note in ROADMAP.md for
  the tier diagram and eviction policy.

Three policies reproduce paper Fig. 6:
  RC_ONLY      (a) only read-compute requests (channel mostly idle),
  RC_UNSLICED  (b) whole-page reads/writes block subsequent read-compute
                   requests,
  RC_SLICED    (c) sliced reads/writes fill the bubbles (ours/paper's).
"""

from __future__ import annotations

import dataclasses
import enum


class Policy(enum.Enum):
    RC_ONLY = "rc_only"
    RC_UNSLICED = "rc_unsliced"
    RC_SLICED = "rc_sliced"


DEFAULT_SLICE_BYTES = 2048  # read/write-request slice granularity


@dataclasses.dataclass(frozen=True)
class ChannelWorkload:
    """Per-channel request load for one weight matrix (symmetric channels)."""

    n_tiles: int              # read-compute requests (global tile count)
    rc_input_bytes: float     # per tile, per channel: W_req/channels * act_bytes
    rc_result_bytes: float    # per tile, per channel: H_req * result_bytes
    n_reads: int              # plain page reads bound for the NPU, this channel
    page_bytes: int
    t_r: float                # NAND array read time
    bw: float                 # channel bus bandwidth, bytes/s
    n_writes: int = 0         # plain page writes (KV spill), this channel

    @property
    def rc_bus_bytes(self) -> float:
        return self.n_tiles * (self.rc_input_bytes + self.rc_result_bytes)

    @property
    def read_bus_bytes(self) -> float:
        return self.n_reads * self.page_bytes

    @property
    def write_bus_bytes(self) -> float:
        return self.n_writes * self.page_bytes


def channel_workload(plan, flash, act_bytes: float = 1.0,
                     result_bytes: float = 1.0,
                     kv_write_pages: int = 0) -> ChannelWorkload:
    """Build the per-channel workload from a core.tiling.MatrixPlan."""
    import math

    reads = math.ceil(plan.n_read_pages / flash.channels)
    return ChannelWorkload(
        n_tiles=plan.n_tiles,
        rc_input_bytes=plan.tile.w / flash.channels * act_bytes,
        rc_result_bytes=plan.tile.h * result_bytes,
        n_reads=reads,
        page_bytes=flash.page_bytes,
        t_r=flash.t_r,
        bw=flash.bw_channel,
        n_writes=kv_write_pages,
    )
