"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per measured point).
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks.paper_figs import ALL_FIGS

    print("name,us_per_call,derived")
    failures = 0
    for fig in ALL_FIGS:
        try:
            for name, us, derived in fig():
                print(f"{name},{us},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fig.__name__},0,ERROR:{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
