"""Serving benchmark: wave vs continuous admission under a Poisson trace.

Wave admission (the legacy shared-cursor cache) only starts new requests when
the whole batch drains; continuous admission (paged per-slot KV cache) refills
any freed slot immediately.  At batch pressure > 1 (more requests than slots)
the paged engine keeps slots busy and should be no slower end-to-end while
cutting admission latency.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py \
          --arch smollm-360m --requests 12 --rate 4 --max-batch 4
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs.registry import get_arch
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine

# a small prompt-length menu keeps the per-shape jit retrace count bounded
PROMPT_LENS = (4, 6, 8, 12)


def poisson_arrivals(n: int, rate_rps: float, seed: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return np.cumsum(gaps)


def make_requests(n: int, cfg, max_new: int, seed: int) -> list[Request]:
    rng = np.random.RandomState(seed + 1)
    reqs = []
    for rid in range(n):
        plen = int(rng.choice(PROMPT_LENS))
        prompt = rng.randint(0, cfg.vocab_size, size=plen).tolist()
        # ragged decode lengths are what hurt wave admission: the whole
        # batch drains at the pace of its longest request
        n_new = int(rng.randint(max(2, max_new // 4), max_new + 1))
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=n_new))
    return reqs


def drive(eng: ServingEngine, reqs: list[Request],
          arrivals: np.ndarray) -> float:
    """Feed requests at their arrival times; returns wall seconds."""
    t0 = time.monotonic()
    i = 0
    while True:
        now = time.monotonic() - t0
        while i < len(reqs) and arrivals[i] <= now:
            eng.submit(reqs[i])
            i += 1
        worked = eng.step()
        if not worked:
            if i >= len(reqs):
                break
            wait = arrivals[i] - (time.monotonic() - t0)
            time.sleep(max(0.0, min(0.001, wait)))
    return time.monotonic() - t0


def bench_mode(mode: str, cfg, params, args, timed_seed: int) -> dict:
    # warmup pass populates the shared jit caches (prefill shape buckets,
    # decode step) so the timed pass measures steady-state serving
    warm = ServingEngine(cfg, params, max_batch=args.max_batch,
                         max_seq=args.max_seq, eos_id=-1, mode=mode,
                         page_size=args.page_size)
    # one warmup request per prompt length, each run to completion, so wave
    # mode compiles every [B, plen] prefill shape the trace can produce
    for i, plen in enumerate(PROMPT_LENS):
        warm.submit(Request(rid=-1 - i, prompt=[1] * plen, max_new_tokens=2))
        warm.run()

    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, eos_id=-1, mode=mode,
                        page_size=args.page_size)
    reqs = make_requests(args.requests, cfg, args.max_new, timed_seed)
    arrivals = poisson_arrivals(args.requests, args.rate, timed_seed)
    wall = drive(eng, reqs, arrivals)
    s = eng.stats
    assert all(r.done for r in reqs)
    return {
        "mode": mode,
        "wall_s": wall,
        "tokens": s.tokens_out,
        "tok_per_s": s.tokens_out / wall,
        "tok_per_step": s.tokens_out / max(s.decode_steps, 1),
        "tok_per_decode_s": s.tokens_out / max(s.wall_decode_s, 1e-9),
        "prefills": s.prefills,
        "admission_p50": s.percentiles("admission_wait_s")["p50"],
        "admission_p99": s.percentiles("admission_wait_s")["p99"],
        "latency_p50": s.percentiles("latency_s")["p50"],
        "latency_p99": s.percentiles("latency_s")["p99"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=16.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0),
                                   max_seq=args.max_seq)
    pressure = args.requests / args.max_batch
    print(f"arch={cfg.name} requests={args.requests} rate={args.rate}/s "
          f"max_batch={args.max_batch} batch_pressure={pressure:.1f}")

    rows = [bench_mode(m, cfg, params, args, timed_seed=args.seed)
            for m in ("wave", "continuous")]
    hdr = ("mode", "wall_s", "tok/s", "tok/step", "tok/dec_s", "prefills",
           "adm_p50", "adm_p99", "lat_p50", "lat_p99")
    print(" ".join(f"{h:>10}" for h in hdr))
    for r in rows:
        print(f"{r['mode']:>10} {r['wall_s']:>10.2f} {r['tok_per_s']:>10.1f} "
              f"{r['tok_per_step']:>10.2f} {r['tok_per_decode_s']:>10.1f} "
              f"{r['prefills']:>10d} "
              f"{r['admission_p50']:>10.3f} {r['admission_p99']:>10.3f} "
              f"{r['latency_p50']:>10.3f} {r['latency_p99']:>10.3f}")
    wave, cont = rows
    speedup = cont["tok_per_s"] / wave["tok_per_s"]
    occup = cont["tok_per_step"] / wave["tok_per_step"]
    print(f"\ncontinuous/wave: throughput x{speedup:.2f}, "
          f"occupancy x{occup:.2f}, admission p99 "
          f"{wave['admission_p99']:.3f}s -> {cont['admission_p99']:.3f}s")
    if pressure > 1 and speedup < 0.95:  # 5% = wall-clock noise floor
        print("WARNING: continuous materially slower than wave "
              "at batch pressure > 1")
    return rows


if __name__ == "__main__":
    main()
