"""Serving benchmarks: admission, tiered-KV capacity, and policy traces.

Three traces, all Poisson arrivals:

* ``admission`` — wave vs continuous admission.  Wave (the legacy
  shared-cursor cache) only starts new requests when the whole batch drains;
  continuous (paged per-slot KV cache) refills any freed slot immediately.
  At batch pressure > 1 the paged engine keeps slots busy and should be no
  slower end-to-end while cutting admission latency.
* ``kvtier`` — the KV-capacity-constrained trace: the hot page pool is sized
  BELOW total trace demand (``--pool-pages``), and three engines race it:
  ``reject`` fails requests the pool can't take (the flash-less baseline),
  ``requeue`` restarts starved requests later (graceful but stally), and
  ``tiered`` spills cold pages to the simulated NAND flash tier and
  prefetches them back through the Slice Control bubbles
  (``kv_tier="flash"``).  Tiered must complete 100% of the trace; the report
  prices its spill/prefetch traffic with the channel simulator
  (``sim.llm_perf.kv_swap_overhead_s``) to show the bubble-bandwidth cost of
  every evicted page.
* ``policy`` — the scheduler bake-off: mixed prompt lengths (including long
  prompts that exercise chunked prefill), mixed priorities, and per-request
  SLO deadlines race the capacity-constrained tiered pool under each
  admission policy (fcfs / priority / sjf / drr / edf,
  ``serving.scheduler``).  Every policy must complete 100% of the trace;
  the report compares per-policy TTFT and latency percentiles, the
  deadline-miss rate (the EDF policy's target metric), plus
  per-priority-class TTFT p99 so the priority policy's SLO effect is
  visible.
* ``overlap`` — the overlapped decode loop (``overlap=True``): the fused
  decode+sample dispatch with one-step-delayed host readback vs the
  synchronous two-dispatch loop, same trace.  Both must complete 100% with
  bit-identical outputs; the report pins the tentpole metric — jitted
  dispatches per decode step drop from 2 (decode + sample) to 1 — and
  shows dispatches per decoded token.  ``--overlap`` additionally runs the
  admission trace's continuous engine overlapped.
* ``prefix`` — the prefix-caching trace: multi-turn chat sessions (a shared
  per-session system prompt plus history grown from each run's own outputs,
  with an immediate "regenerate" of every turn) race four engines: ``cold``
  (prefix caching off), ``warm`` (``prefix_cache=True``), ``warm-tiered``
  (a hot pool sized below the working set, so idle shared pages spill to
  flash and prefetch back on the next hit), and ``warm-2rep`` (two replicas
  under ``session_affinity`` routing — the replica whose cache holds the
  session's pages wins).  All variants must complete 100% with outputs
  bit-identical to cold (greedy AND seed-pinned stochastic sessions), and
  hit-turn TTFT p50 must improve >= 2x over the cold run — regenerates are
  exact-prompt resume hits (zero prefill dispatches), follow-up turns are
  partial page hits that only prefill the uncached suffix.
* ``router`` — multi-replica serving through the Router/EngineCore split:
  ``--replicas N`` small replicas under least-loaded routing with
  cross-replica slot migration vs ONE N-wide replica with the same total
  slot and page budget.  Both must complete 100%; the report compares
  wall clock and TTFT p99 and counts slot migrations (each one drains a
  page-starved replica's victim slot into a peer with headroom,
  bit-identical — the N-replica fleet should hold the single-replica
  latency profile despite the partitioned KV pools).

* ``quant`` — the int8-KV trace: bf16 vs ``kv_dtype="int8"`` page pools on
  the capacity-constrained tiered pool (the kvtier workload), plus an
  int8-KV + w8a8-weight engine.  Every variant must complete 100%; the
  int8 tiered outputs must be bit-identical to an int8 all-resident run
  (spill/prefetch relocates quantized pages, it never re-quantizes), and
  the int8 runs must spill >= 1.8x fewer bytes than bf16 (each page moves
  1B/elem + 4B/row scales instead of 2B/elem — 2*Dh/(Dh+4)); the report
  shows the TTFT/throughput deltas and reprices the spill traffic on the
  flash channel model.

* ``fleet`` — the failover trace (serving/fleet/): N workers behind the
  fleet transport (``--transport loopback`` in-process behind the wire
  codec, ``socket`` real subprocesses), one worker killed once ~40% of
  the trace's tokens have been delivered.  The fleet must complete 100%
  of the requests with every stream bit-identical to an undisturbed
  single-engine run (greedy AND seed-pinned stochastic); the report
  prices the failover: recovery latency and tokens replayed.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py \
          --arch smollm-360m --requests 12 --rate 4 --max-batch 4
      PYTHONPATH=src python benchmarks/bench_serving.py --smoke
      PYTHONPATH=src python benchmarks/bench_serving.py --trace policy --smoke
      PYTHONPATH=src python benchmarks/bench_serving.py --trace router \
          --smoke --replicas 2
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

import jax

from repro.configs.registry import get_arch
from repro.core.hw import CAMBRICON_LLM_S
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import Router
from repro.serving.scheduler import POLICIES, SamplingParams, make_scheduler
from repro.sim.llm_perf import kv_swap_overhead_s, prefill_ttft_s

# a small prompt-length menu keeps the per-shape jit retrace count bounded
PROMPT_LENS = (4, 6, 8, 12)


def poisson_arrivals(n: int, rate_rps: float, seed: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return np.cumsum(gaps)


def make_requests(n: int, cfg, max_new: int, seed: int) -> list[Request]:
    rng = np.random.RandomState(seed + 1)
    reqs = []
    for rid in range(n):
        plen = int(rng.choice(PROMPT_LENS))
        prompt = rng.randint(0, cfg.vocab_size, size=plen).tolist()
        # ragged decode lengths are what hurt wave admission: the whole
        # batch drains at the pace of its longest request
        n_new = int(rng.randint(max(2, max_new // 4), max_new + 1))
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=n_new))
    return reqs


def drive(eng, reqs: list[Request], arrivals: np.ndarray) -> float:
    """Feed requests at their arrival times; returns wall seconds.

    ``eng`` is anything with the ``submit(req) / step() / has_work``
    surface — a ServingEngine or a multi-replica Router.
    """
    t0 = time.monotonic()
    i = 0
    while True:
        now = time.monotonic() - t0
        while i < len(reqs) and arrivals[i] <= now:
            eng.submit(reqs[i])
            i += 1
        eng.step()
        if not eng.has_work:
            if i >= len(reqs):
                break
            wait = arrivals[i] - (time.monotonic() - t0)
            time.sleep(max(0.0, min(0.001, wait)))
    return time.monotonic() - t0


def _warm(cfg, params, args, **eng_kw):
    # warmup pass populates the shared jit caches (prefill shape buckets,
    # decode step) so the timed pass measures steady-state serving
    warm = ServingEngine(cfg, params, max_batch=args.max_batch,
                         max_seq=args.max_seq, eos_id=-1,
                         page_size=args.page_size, **eng_kw)
    # one warmup request per prompt length, each run to completion, so wave
    # mode compiles every [B, plen] prefill shape the trace can produce
    for i, plen in enumerate(PROMPT_LENS):
        warm.submit(Request(rid=-1 - i, prompt=[1] * plen, max_new_tokens=2))
        warm.run()


def bench_mode(mode: str, cfg, params, args, timed_seed: int) -> dict:
    overlap = bool(getattr(args, "overlap", False)) and mode == "continuous"
    _warm(cfg, params, args, mode=mode, overlap=overlap)
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, eos_id=-1, mode=mode,
                        page_size=args.page_size, overlap=overlap)
    reqs = make_requests(args.requests, cfg, args.max_new, timed_seed)
    arrivals = poisson_arrivals(args.requests, args.rate, timed_seed)
    wall = drive(eng, reqs, arrivals)
    s = eng.stats
    assert all(r.done for r in reqs)
    return {
        "mode": mode,
        "wall_s": wall,
        "tokens": s.tokens_out,
        "tok_per_s": s.tokens_out / wall,
        "tok_per_step": s.tokens_out / max(s.decode_steps, 1),
        "tok_per_decode_s": s.tokens_out / max(s.wall_decode_s, 1e-9),
        "prefills": s.prefills,
        "admission_p50": s.percentiles("admission_wait_s")["p50"],
        "admission_p99": s.percentiles("admission_wait_s")["p99"],
        "latency_p50": s.percentiles("latency_s")["p50"],
        "latency_p99": s.percentiles("latency_s")["p99"],
    }


def bench_admission(cfg, params, args) -> list[dict]:
    pressure = args.requests / args.max_batch
    print(f"[admission] arch={cfg.name} requests={args.requests} "
          f"rate={args.rate}/s max_batch={args.max_batch} "
          f"batch_pressure={pressure:.1f}")

    rows = [bench_mode(m, cfg, params, args, timed_seed=args.seed)
            for m in ("wave", "continuous")]
    hdr = ("mode", "wall_s", "tok/s", "tok/step", "tok/dec_s", "prefills",
           "adm_p50", "adm_p99", "lat_p50", "lat_p99")
    print(" ".join(f"{h:>10}" for h in hdr))
    for r in rows:
        print(f"{r['mode']:>10} {r['wall_s']:>10.2f} {r['tok_per_s']:>10.1f} "
              f"{r['tok_per_step']:>10.2f} {r['tok_per_decode_s']:>10.1f} "
              f"{r['prefills']:>10d} "
              f"{r['admission_p50']:>10.3f} {r['admission_p99']:>10.3f} "
              f"{r['latency_p50']:>10.3f} {r['latency_p99']:>10.3f}")
    wave, cont = rows
    speedup = cont["tok_per_s"] / wave["tok_per_s"]
    occup = cont["tok_per_step"] / wave["tok_per_step"]
    print(f"\ncontinuous/wave: throughput x{speedup:.2f}, "
          f"occupancy x{occup:.2f}, admission p99 "
          f"{wave['admission_p99']:.3f}s -> {cont['admission_p99']:.3f}s")
    if pressure > 1 and speedup < 0.95:  # 5% = wall-clock noise floor
        print("WARNING: continuous materially slower than wave "
              "at batch pressure > 1")
    return rows


def bench_overlap_variant(name: str, cfg, params, args, overlap: bool) -> dict:
    _warm(cfg, params, args, mode="continuous", overlap=overlap)
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, eos_id=-1, mode="continuous",
                        page_size=args.page_size, overlap=overlap)
    reqs = make_requests(args.requests, cfg, args.max_new, args.seed)
    arrivals = poisson_arrivals(args.requests, args.rate, args.seed)
    wall = drive(eng, reqs, arrivals)
    s = eng.stats
    assert all(r.done for r in reqs)
    return {
        "variant": name, "wall_s": wall,
        "completed_pct": 100.0 * sum(1 for r in reqs if not r.rejected)
        / len(reqs),
        "tokens": s.tokens_out, "tok_per_s": s.tokens_out / wall,
        "decode_steps": s.decode_steps, "dispatches": s.decode_dispatches,
        "disp_per_step": s.decode_dispatches / max(s.decode_steps, 1),
        "disp_per_tok": s.decode_dispatches / max(s.tokens_out, 1),
        "latency_p99": s.percentiles("latency_s")["p99"],
        "out_tokens": {r.rid: list(r.out_tokens) for r in reqs
                       if not r.rejected},
    }


def bench_overlap(cfg, params, args) -> list[dict]:
    """Synchronous two-dispatch loop vs the overlapped fused loop."""
    print(f"\n[overlap] arch={cfg.name} requests={args.requests} "
          f"max_batch={args.max_batch}")
    rows = [bench_overlap_variant("sync", cfg, params, args, False),
            bench_overlap_variant("overlap", cfg, params, args, True)]
    hdr = ("variant", "wall_s", "done%", "tokens", "tok/s", "steps",
           "disp", "disp/step", "disp/tok", "lat_p99")
    print(" ".join(f"{h:>9}" for h in hdr))
    for r in rows:
        print(f"{r['variant']:>9} {r['wall_s']:>9.2f} "
              f"{r['completed_pct']:>9.1f} {r['tokens']:>9d} "
              f"{r['tok_per_s']:>9.1f} {r['decode_steps']:>9d} "
              f"{r['dispatches']:>9d} {r['disp_per_step']:>9.2f} "
              f"{r['disp_per_tok']:>9.3f} {r['latency_p99']:>9.3f}")
    sync, olap = rows
    for r in rows:
        assert r["completed_pct"] == 100.0, \
            f"{r['variant']} dropped requests on the overlap trace"
    # the overlapped loop relocates WHEN tokens are read back, never WHAT
    # they are: outputs must match the synchronous loop bit for bit
    assert olap["out_tokens"] == sync["out_tokens"], \
        "overlapped outputs diverge from the synchronous loop"
    assert sync["disp_per_step"] == 2.0  # decode + sample
    assert olap["disp_per_step"] == 1.0  # the fused step: tentpole metric
    print(f"\noverlap: 100% completed, bit-identical; dispatches per decode "
          f"step 2 -> 1 ({sync['disp_per_tok']:.3f} -> "
          f"{olap['disp_per_tok']:.3f} per decoded token)")
    return rows


def make_kv_requests(n: int, cfg, max_new: int, seed: int) -> list[Request]:
    """Uniform worst-case requests: every one carries the full prompt and
    decode budget, so concurrent footprint reliably exceeds the pool."""
    rng = np.random.RandomState(seed + 2)
    plen = max(PROMPT_LENS)
    return [Request(rid=rid,
                    prompt=rng.randint(0, cfg.vocab_size, size=plen).tolist(),
                    max_new_tokens=max_new)
            for rid in range(n)]


def bench_kvtier_variant(name: str, cfg, params, args, pool: int) -> dict:
    kw = {"resident": dict(),  # unconstrained pool: the reference run
          "reject": dict(num_pages=pool + 1, exhaust_policy="reject"),
          "requeue": dict(num_pages=pool + 1, exhaust_policy="requeue"),
          "tiered": dict(num_pages=pool + 1, kv_tier="flash")}[name]
    _warm(cfg, params, args, mode="continuous")
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, eos_id=-1, mode="continuous",
                        page_size=args.page_size, **kw)
    reqs = make_kv_requests(args.requests, cfg, args.max_new, args.seed)
    arrivals = poisson_arrivals(args.requests, args.rate, args.seed)
    wall = drive(eng, reqs, arrivals)
    s = eng.stats
    assert all(r.done for r in reqs)
    ok = sum(1 for r in reqs if not r.rejected)
    return {
        "variant": name, "wall_s": wall, "eng": eng,
        "completed_pct": 100.0 * ok / len(reqs),
        "tokens": s.tokens_out,
        "pool_exhausted": s.pool_exhausted, "rejected": s.rejected,
        "preemptions": s.preemptions,
        "spill_pages": s.kv_spill_pages, "prefetch_pages": s.kv_prefetch_pages,
        "spill_bytes": s.kv_spill_bytes, "prefetch_bytes": s.kv_prefetch_bytes,
        "out_tokens": {r.rid: list(r.out_tokens) for r in reqs
                       if not r.rejected},
    }


def bench_kvtier(cfg, params, args) -> list[dict]:
    # demand: every request's whole-lifetime page footprint at once
    from repro.serving.kv_cache import kv_page_elems, pages_needed
    per_req = pages_needed(min(args.max_seq, max(PROMPT_LENS) + args.max_new),
                           args.page_size)
    demand = args.requests * per_req
    pool = args.pool_pages
    if pool <= 0:
        # default: one request's lifetime footprint + 1 page — any two
        # concurrent requests exceed the pool, so the tier must work
        pool = per_req + 1
    print(f"\n[kvtier] arch={cfg.name} requests={args.requests} "
          f"hot_pool={pool} pages (trace demand ~{demand} pages)")

    rows = [bench_kvtier_variant(v, cfg, params, args, pool)
            for v in ("resident", "reject", "requeue", "tiered")]
    hdr = ("variant", "wall_s", "done%", "tokens", "exhaust", "rejected",
           "preempt", "spill_pg", "fetch_pg")
    print(" ".join(f"{h:>9}" for h in hdr))
    for r in rows:
        print(f"{r['variant']:>9} {r['wall_s']:>9.2f} "
              f"{r['completed_pct']:>9.1f} {r['tokens']:>9d} "
              f"{r['pool_exhausted']:>9d} {r['rejected']:>9d} "
              f"{r['preemptions']:>9d} {r['spill_pages']:>9d} "
              f"{r['prefetch_pages']:>9d}")

    resident, reject, requeue, tiered = rows
    assert tiered["completed_pct"] == 100.0, "tiered must complete the trace"
    # spill/prefetch roundtrips must not change a single output token: the
    # tier relocates pages, it never approximates (unlike requeue's restart,
    # where prefill-vs-decode numerics can flip a near-tie argmax)
    assert tiered["out_tokens"] == resident["out_tokens"], \
        "tiered outputs diverge from the all-resident run"

    # price the tiered engine's page traffic on the paper's flash channels
    s = tiered["eng"].stats
    kv_pg = tiered["eng"].kv_page_bytes
    per_tok_spill = s.kv_spill_bytes / max(s.tokens_out, 1)
    per_tok_fetch = s.kv_prefetch_bytes / max(s.tokens_out, 1)
    cost = kv_swap_overhead_s(cfg, CAMBRICON_LLM_S, per_tok_spill,
                              per_tok_fetch, seq_len=args.max_seq)
    print(f"\ntiered: 100% completed (reject baseline "
          f"{reject['completed_pct']:.0f}%); "
          f"{s.kv_spill_pages} pages spilled / {s.kv_prefetch_pages} "
          f"prefetched ({(s.kv_spill_bytes + s.kv_prefetch_bytes) / 1e6:.2f} "
          f"MB at {kv_pg / 1024:.0f} KiB/page)")
    print(f"simulated bubble-bandwidth cost: {cost * 1e6:.2f} us/token "
          f"({per_tok_spill + per_tok_fetch:.0f} B/token through the "
          f"Slice Control bubbles)")
    if cfg.family in ("mla_moe", "hybrid"):
        # the page-byte accounting is family-aware: MLA spills compressed
        # ckv+krope rows, hybrid only its shared-attn groups — show how much
        # cheaper each evicted page is than a full-K/V page of the same arch
        itemsize = kv_pg // max(1, kv_page_elems(cfg, args.page_size))
        full = (2 * cfg.n_layers * args.page_size * cfg.n_kv_heads
                * cfg.d_head * itemsize)
        print(f"{cfg.family} page: {kv_pg} B vs full-K/V equivalent "
              f"{full} B — x{full / kv_pg:.1f} cheaper per evicted page")
    return rows


def _policy_prompt_lens(max_seq: int, max_new: int) -> list[int]:
    """Prompt-length menu for the policy trace; bench_policy sizes the hot
    pool from this same list, so every request passes the submit guard."""
    long_lens = (max_seq // 4, max_seq // 2 - max_new)
    return list(PROMPT_LENS) + [p for p in long_lens
                                if p > max(PROMPT_LENS)]


def make_policy_requests(n: int, cfg, max_new: int, seed: int,
                         max_seq: int, page_size: int) -> list[Request]:
    """Mixed trace: short interactive prompts AND long prompts (chunked
    prefill territory), priorities 0..2, and a per-request SLO deadline
    (tight for the short interactive requests, loose for the long ones) —
    the workload where admission policy actually changes TTFT and where
    the EDF policy has deadlines to order by."""
    rng = np.random.RandomState(seed + 3)
    lens = _policy_prompt_lens(max_seq, max_new)
    reqs = []
    for rid in range(n):
        plen = int(lens[rid % len(lens)])
        n_new = int(rng.randint(max(2, max_new // 4), max_new + 1))
        # SLO scales with the request's own service demand (so misses
        # measure scheduling, not model speed), floored for tiny requests
        deadline = max(1.0, 0.15 * (plen + n_new))
        reqs.append(Request(
            rid=rid, prompt=rng.randint(0, cfg.vocab_size, size=plen).tolist(),
            max_new_tokens=n_new, priority=int(rng.randint(0, 3)),
            deadline_s=float(deadline)))
    return reqs


def bench_policy_variant(policy: str, cfg, params, args, pool: int) -> dict:
    sched = make_scheduler(policy, chunk_tokens=args.chunk_prefill or None)
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, eos_id=-1, mode="continuous",
                        page_size=args.page_size, num_pages=pool + 1,
                        kv_tier="flash", scheduler=sched)
    reqs = make_policy_requests(args.requests, cfg, args.max_new, args.seed,
                                args.max_seq, args.page_size)
    arrivals = poisson_arrivals(args.requests, args.rate, args.seed)
    wall = drive(eng, reqs, arrivals)
    s = eng.stats
    assert all(r.done for r in reqs)
    ok = sum(1 for r in reqs if not r.rejected)
    by_prio = {}
    for p in sorted({r.priority for r in reqs}):
        xs = [r.ttft_s for r in reqs if r.priority == p and not r.rejected]
        by_prio[p] = float(np.percentile(xs, 99)) if xs else 0.0
    with_slo = [r for r in reqs if r.deadline_s is not None
                and not r.rejected]
    missed = sum(1 for r in with_slo if r.deadline_missed)
    return {
        "policy": policy, "wall_s": wall,
        "completed_pct": 100.0 * ok / len(reqs),
        "miss_pct": 100.0 * missed / max(1, len(with_slo)),
        "tokens": s.tokens_out,
        "ttft_p50": s.percentiles("ttft_s")["p50"],
        "ttft_p99": s.percentiles("ttft_s")["p99"],
        "latency_p50": s.percentiles("latency_s")["p50"],
        "latency_p99": s.percentiles("latency_s")["p99"],
        "preemptions": s.preemptions,
        "prefill_chunks": s.prefill_chunks,
        "ttft_p99_by_prio": by_prio,
    }


def bench_policy(cfg, params, args) -> list[dict]:
    """Scheduler bake-off on the capacity-constrained tiered pool."""
    from repro.serving.kv_cache import pages_needed
    long_plen = max(_policy_prompt_lens(args.max_seq, args.max_new))
    per_req = pages_needed(min(args.max_seq, long_plen + args.max_new),
                           args.page_size)
    pool = args.pool_pages if args.pool_pages > 0 else per_req + 1
    print(f"\n[policy] arch={cfg.name} requests={args.requests} "
          f"hot_pool={pool} pages chunk_prefill="
          f"{args.chunk_prefill or 'off'} policies={sorted(POLICIES)}")

    # extra warmup: compile the chunked-prefill trace + the tiered paths
    # once so the per-policy runs measure scheduling, not compilation
    warm = ServingEngine(cfg, params, max_batch=args.max_batch,
                         max_seq=args.max_seq, eos_id=-1, mode="continuous",
                         page_size=args.page_size, num_pages=pool + 1,
                         kv_tier="flash",
                         scheduler=make_scheduler(
                             "fcfs", chunk_tokens=args.chunk_prefill or None))
    warm.submit(Request(rid=-1, prompt=[1] * long_plen, max_new_tokens=2))
    warm.run()

    rows = [bench_policy_variant(p, cfg, params, args, pool)
            for p in sorted(POLICIES)]
    hdr = ("policy", "wall_s", "done%", "miss%", "tokens", "ttft_p50",
           "ttft_p99", "lat_p50", "lat_p99", "preempt", "chunks")
    print(" ".join(f"{h:>9}" for h in hdr))
    for r in rows:
        print(f"{r['policy']:>9} {r['wall_s']:>9.2f} "
              f"{r['completed_pct']:>9.1f} {r['miss_pct']:>9.1f} "
              f"{r['tokens']:>9d} "
              f"{r['ttft_p50']:>9.3f} {r['ttft_p99']:>9.3f} "
              f"{r['latency_p50']:>9.3f} {r['latency_p99']:>9.3f} "
              f"{r['preemptions']:>9d} {r['prefill_chunks']:>9d}")
    for r in rows:
        prio = " ".join(f"p{k}={v:.3f}s"
                        for k, v in r["ttft_p99_by_prio"].items())
        print(f"  {r['policy']}: TTFT p99 by priority class: {prio}")
    for r in rows:
        assert r["completed_pct"] == 100.0, \
            f"{r['policy']} dropped requests on the tiered trace"
    return rows


def bench_router_variant(name: str, cfg, params, args, pool: int,
                         replicas: int, route: str = "least_loaded") -> dict:
    """One Poisson run over a Router fleet.  ``replicas`` small replicas
    vs one replica holding the same TOTAL slot+page budget."""
    if replicas == 1:
        eng = Router.build(cfg, params, replicas=1,
                           max_batch=args.max_batch * args.replicas,
                           max_seq=args.max_seq, eos_id=-1,
                           mode="continuous", page_size=args.page_size,
                           num_pages=args.replicas * pool + 1,
                           kv_tier="flash")
    else:
        eng = Router.build(cfg, params, replicas=replicas, policy=route,
                           max_batch=args.max_batch, max_seq=args.max_seq,
                           eos_id=-1, mode="continuous",
                           page_size=args.page_size, num_pages=pool + 1,
                           kv_tier="flash")
    reqs = make_kv_requests(args.requests, cfg, args.max_new, args.seed)
    if route == "session_affinity":
        # skewed session mix: most requests belong to one hot session, so
        # affinity piles them onto one replica — the hotspot slot migration
        # exists to drain (the cold replica is the donor)
        for r in reqs:
            r.session = "hot" if r.rid % 4 else f"cold-{r.rid}"
    arrivals = poisson_arrivals(args.requests, args.rate, args.seed)
    wall = drive(eng, reqs, arrivals)
    assert all(r.done for r in reqs)
    ok = [r for r in reqs if not r.rejected]
    ttft = [r.ttft_s for r in ok]
    tokens = sum(s.tokens_out for s in eng.stats)
    return {
        "variant": name, "wall_s": wall,
        "completed_pct": 100.0 * len(ok) / len(reqs),
        "tokens": tokens, "tok_per_s": tokens / wall,
        "ttft_p50": float(np.percentile(ttft, 50)) if ttft else 0.0,
        "ttft_p99": float(np.percentile(ttft, 99)) if ttft else 0.0,
        "migrations": eng.migrations,
        "preemptions": sum(s.preemptions for s in eng.stats),
        "out_tokens": {r.rid: list(r.out_tokens) for r in ok},
    }


def bench_router(cfg, params, args) -> list[dict]:
    """Multi-replica Router vs one wide replica, same total budget."""
    from repro.serving.kv_cache import pages_needed
    per_req = pages_needed(min(args.max_seq, max(PROMPT_LENS) + args.max_new),
                           args.page_size)
    pool = args.pool_pages if args.pool_pages > 0 else per_req + 1
    print(f"\n[router] arch={cfg.name} requests={args.requests} "
          f"replicas={args.replicas} x (batch={args.max_batch}, "
          f"pool={pool}) vs 1 x (batch={args.replicas * args.max_batch}, "
          f"pool={args.replicas * pool})")
    _warm(cfg, params, args, mode="continuous")
    n = args.replicas
    rows = [bench_router_variant("1-wide", cfg, params, args, pool, 1),
            bench_router_variant(f"{n}-balanced", cfg, params, args, pool,
                                 n, route="least_loaded"),
            bench_router_variant(f"{n}-affinity", cfg, params, args, pool,
                                 n, route="session_affinity")]
    hdr = ("variant", "wall_s", "done%", "tokens", "tok/s", "ttft_p50",
           "ttft_p99", "preempt", "migrate")
    print(" ".join(f"{h:>10}" for h in hdr))
    for r in rows:
        print(f"{r['variant']:>10} {r['wall_s']:>10.2f} "
              f"{r['completed_pct']:>10.1f} {r['tokens']:>10d} "
              f"{r['tok_per_s']:>10.1f} {r['ttft_p50']:>10.3f} "
              f"{r['ttft_p99']:>10.3f} {r['preemptions']:>10d} "
              f"{r['migrations']:>10d}")
    wide = rows[0]
    for r in rows:
        assert r["completed_pct"] == 100.0, \
            f"{r['variant']} dropped requests on the router trace"
        # partitioning the pool must not change any output: migration
        # relocates a slot's pages across replicas exactly like the tier
        # relocates them across pids — never approximates
        assert r["out_tokens"] == wide["out_tokens"], \
            f"{r['variant']} outputs diverge from the single-replica run"
    fleet, skew = rows[1], rows[2]
    print(f"\n{n}-replica fleet: 100% completed on both routes; "
          f"TTFT p99 {wide['ttft_p99']:.3f}s (1-wide) -> "
          f"{fleet['ttft_p99']:.3f}s (balanced) / {skew['ttft_p99']:.3f}s "
          f"(skewed affinity, {skew['migrations']} hotspot slot "
          f"migration(s) drained)")
    return rows


def make_prefix_sessions(cfg, args, n_turns: int = 3, user_len: int = 4):
    """Static skeleton of the multi-turn chat trace: per-session system
    prompts (the cacheable mass, page-aligned so full pages hit) and
    per-turn user spans.  Histories are grown live from each run's OWN
    outputs, so a variant's prompts depend only on its outputs — which the
    bit-identity assertion pins to the cold run's."""
    n_sessions = max(2, args.max_batch)
    m = min(args.max_new, 4)
    ps = args.page_size
    # final turn must fit: sys + n_turns * (user + out) <= max_seq
    sys_len = ((args.max_seq - n_turns * (user_len + m)) // ps) * ps
    assert sys_len >= ps, "max_seq too small for the prefix trace"
    rng = np.random.RandomState(args.seed + 5)
    sessions = []
    for s in range(n_sessions):
        sessions.append({
            "sid": f"sess-{s}",
            "system": rng.randint(0, cfg.vocab_size, size=sys_len).tolist(),
            "users": [rng.randint(0, cfg.vocab_size, size=user_len).tolist()
                      for _ in range(n_turns)],
            # odd sessions sample stochastically with a pinned seed — the
            # resume replay must stay bit-identical under BOTH modes
            "sampling": (None if s % 2 == 0 else
                         SamplingParams(temperature=0.8, top_k=20,
                                        seed=1000 + s)),
        })
    return sessions, m, n_turns, user_len


def bench_prefix_variant(name: str, cfg, params, args, make_eng) -> dict:
    """One pass over the chat trace: sessions interleave turn by turn (so a
    session's idle pages feel other sessions' allocation pressure between
    its own turns — the tiered variant spills and prefetches them), and
    every turn is immediately regenerated (exact-prompt resubmission, the
    resume-hit case)."""
    eng = make_eng()
    sessions, m, n_turns, _ = make_prefix_sessions(cfg, args)
    history = {s["sid"]: list(s["system"]) for s in sessions}
    recs: list[tuple[str, float]] = []   # (cold|hit, ttft_s)
    outs: dict[int, list[int]] = {}
    rid = 0
    t0 = time.monotonic()
    for t in range(n_turns):
        for sess in sessions:
            prompt = history[sess["sid"]] + sess["users"][t]
            first_out = None
            for kind in ("turn", "regen"):
                req = Request(rid=rid, prompt=list(prompt),
                              max_new_tokens=m, session=sess["sid"],
                              sampling=sess["sampling"])
                rid += 1
                eng.submit(req)
                while eng.has_work:
                    eng.step()
                assert req.done and not req.rejected, \
                    f"{name}: request {req.rid} did not complete"
                outs[req.rid] = list(req.out_tokens)
                # a warm cache only ever misses each session's very first
                # submission; every later turn shares pages with it
                recs.append(("cold" if (t == 0 and kind == "turn")
                             else "hit", req.ttft_s))
                if kind == "turn":
                    first_out = list(req.out_tokens)
            history[sess["sid"]] = prompt + first_out
    wall = time.monotonic() - t0
    stats = eng.stats
    if isinstance(stats, list):  # Router: sum the fleet's counters
        agg = {k: sum(getattr(s, k) for s in stats)
               for k in ("prefix_lookups", "prefix_hits", "prefix_hit_pages",
                         "prefix_tokens_reused", "cow_copies",
                         "kv_spill_pages", "kv_prefetch_pages")}
    else:
        agg = {k: getattr(stats, k)
               for k in ("prefix_lookups", "prefix_hits", "prefix_hit_pages",
                         "prefix_tokens_reused", "cow_copies",
                         "kv_spill_pages", "kv_prefetch_pages")}
    hit = sorted(t for k, t in recs if k == "hit")
    return {
        "variant": name, "wall_s": wall, "outs": outs,
        "n_requests": rid, "completed_pct": 100.0,
        "ttft_hit_p50": float(np.percentile(hit, 50)),
        "ttft_hit_p99": float(np.percentile(hit, 99)),
        **agg,
    }


def bench_prefix(cfg, params, args) -> list[dict]:
    """Prefix caching: warm variants must be bit-identical to cold with
    hit-turn TTFT collapsing >= 2x."""
    from repro.serving.kv_cache import pages_needed
    # the trace needs a system prompt with real prefill mass (the thing the
    # cache elides) even under --smoke, so it floors max_seq independently
    args = argparse.Namespace(**{**vars(args),
                                 "max_seq": max(args.max_seq, 256)})
    sessions, m, n_turns, user_len = make_prefix_sessions(cfg, args)
    sys_len = len(sessions[0]["system"])
    final_plen = sys_len + (n_turns - 1) * (user_len + m) + user_len
    per_req = pages_needed(min(args.max_seq, final_plen + m), args.page_size)
    # roomy pool for the untiered variants (every session's cache stays
    # hot); the tiered pool is sized BELOW the combined working set so
    # idle shared pages must spill to flash between a session's turns
    roomy = 2 * len(sessions) * per_req
    tight = per_req + 2
    print(f"\n[prefix] arch={cfg.name} sessions={len(sessions)} "
          f"turns={n_turns} (+1 regenerate each) sys_prompt={sys_len} tok "
          f"tiered_pool={tight} pages (working set ~"
          f"{len(sessions) * per_req})")

    def mk(**kw):
        base = dict(max_batch=args.max_batch, max_seq=args.max_seq,
                    eos_id=-1, mode="continuous", page_size=args.page_size)
        return lambda: ServingEngine(cfg, params, **{**base, **kw})

    factories = {
        "cold": mk(num_pages=roomy + 1),
        "warm": mk(num_pages=roomy + 1, prefix_cache=True),
        "warm-tiered": mk(num_pages=tight + 1, kv_tier="flash",
                          prefix_cache=True),
        "warm-2rep": lambda: Router.build(
            cfg, params, replicas=2, policy="session_affinity",
            max_batch=args.max_batch, max_seq=args.max_seq, eos_id=-1,
            mode="continuous", page_size=args.page_size,
            num_pages=roomy + 1, prefix_cache=True),
    }
    rows = []
    for name, f in factories.items():
        bench_prefix_variant(name, cfg, params, args, f)  # compile warmup
        rows.append(bench_prefix_variant(name, cfg, params, args, f))
    hdr = ("variant", "wall_s", "done%", "reqs", "hits", "hit_pg", "tok_re",
           "cow", "spill", "fetch", "ttft_hit_p50", "ttft_hit_p99")
    print(" ".join(f"{h:>12}" for h in hdr))
    for r in rows:
        print(f"{r['variant']:>12} {r['wall_s']:>12.2f} "
              f"{r['completed_pct']:>12.1f} {r['n_requests']:>12d} "
              f"{r['prefix_hits']:>12d} {r['prefix_hit_pages']:>12d} "
              f"{r['prefix_tokens_reused']:>12d} {r['cow_copies']:>12d} "
              f"{r['kv_spill_pages']:>12d} {r['kv_prefetch_pages']:>12d} "
              f"{r['ttft_hit_p50']:>12.4f} {r['ttft_hit_p99']:>12.4f}")
    cold = rows[0]
    for r in rows[1:]:
        # the whole point: reusing pages must never change a token — every
        # warm variant (incl. tiered spill/prefetch and 2-replica affinity)
        # replays the cold run bit for bit, greedy and stochastic sessions
        assert r["outs"] == cold["outs"], \
            f"{r['variant']} outputs diverge from the cold-cache run"
        assert r["prefix_hits"] > 0, f"{r['variant']} never hit the cache"
    warm = rows[1]
    speedup = cold["ttft_hit_p50"] / max(warm["ttft_hit_p50"], 1e-9)
    tiered = rows[2]
    assert tiered["kv_spill_pages"] > 0 and tiered["kv_prefetch_pages"] > 0, \
        "tiered prefix variant never exercised the flash tier"
    hit_rate = warm["prefix_hits"] / max(warm["prefix_lookups"], 1)
    print(f"\nprefix: 100% completed, all warm variants bit-identical to "
          f"cold; hit rate {100 * hit_rate:.0f}% "
          f"({warm['prefix_hits']}/{warm['prefix_lookups']}), "
          f"{warm['prefix_tokens_reused']} prompt tokens served from cache, "
          f"{warm['cow_copies']} copy-on-write page copies")
    print(f"hit-turn TTFT p50 {cold['ttft_hit_p50'] * 1e3:.2f} ms (cold) -> "
          f"{warm['ttft_hit_p50'] * 1e3:.2f} ms (warm): x{speedup:.1f}")
    assert speedup >= 2.0, \
        f"hit-turn TTFT p50 improved only x{speedup:.2f} (< 2x)"
    # the channel model prices the same collapse: cached tokens drop their
    # serialized NPU attention phases out of the prefill critical path
    t_cold = prefill_ttft_s(cfg, CAMBRICON_LLM_S, final_plen)
    t_warm = prefill_ttft_s(cfg, CAMBRICON_LLM_S, final_plen,
                            cached_tokens=sys_len)
    print(f"modeled TTFT ({final_plen}-token prompt, {sys_len} cached): "
          f"{t_cold * 1e3:.2f} ms -> {t_warm * 1e3:.2f} ms "
          f"(x{t_cold / t_warm:.1f})")
    return rows


def bench_quant_variant(name: str, cfg, params, args, pool: int) -> dict:
    kw = {"bf16-tiered": dict(num_pages=pool + 1, kv_tier="flash"),
          "int8-resident": dict(kv_dtype="int8"),
          "int8-tiered": dict(num_pages=pool + 1, kv_tier="flash",
                              kv_dtype="int8"),
          "int8+w8a8": dict(num_pages=pool + 1, kv_tier="flash",
                            kv_dtype="int8")}[name]
    if name == "int8+w8a8":
        from repro.quant.convert import quantize_params
        params = quantize_params(params, mode="w8a8")
    _warm(cfg, params, args, mode="continuous",
          kv_dtype=kw.get("kv_dtype", "bf16"))
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq, eos_id=-1, mode="continuous",
                        page_size=args.page_size, **kw)
    reqs = make_kv_requests(args.requests, cfg, args.max_new, args.seed)
    arrivals = poisson_arrivals(args.requests, args.rate, args.seed)
    wall = drive(eng, reqs, arrivals)
    s = eng.stats
    assert all(r.done for r in reqs)
    ok = sum(1 for r in reqs if not r.rejected)
    return {
        "variant": name, "wall_s": wall, "eng": eng,
        "completed_pct": 100.0 * ok / len(reqs),
        "tokens": s.tokens_out, "tok_per_s": s.tokens_out / wall,
        "ttft_p50": s.percentiles("ttft_s")["p50"],
        "ttft_p99": s.percentiles("ttft_s")["p99"],
        "spill_pages": s.kv_spill_pages, "prefetch_pages": s.kv_prefetch_pages,
        "spill_bytes": s.kv_spill_bytes, "prefetch_bytes": s.kv_prefetch_bytes,
        "page_bytes": eng.kv_page_bytes,
        "out_tokens": {r.rid: list(r.out_tokens) for r in reqs
                       if not r.rejected},
    }


def bench_quant(cfg, params, args) -> list[dict]:
    """bf16 vs int8 KV pages under KV-capacity pressure."""
    import dataclasses

    from repro.serving.kv_cache import pages_needed
    from repro.sim.llm_perf import family_kv_page_bytes

    # reduced configs pin d_head=16, where the int8 page (1B/elem payload
    # plus a 4B per-row scale) is only 1.6x smaller than bf16; real archs
    # carry d_head 64-128, so the trace bumps d_head to 64 and prices the
    # paper-scale ratio (2*Dh/(Dh+4) = 1.88x) while staying CPU-sized
    if cfg.d_head < 36:
        cfg = dataclasses.replace(cfg, name=cfg.name + "-qkv", d_head=64)
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0),
                                       max_seq=args.max_seq)
    per_req = pages_needed(min(args.max_seq, max(PROMPT_LENS) + args.max_new),
                           args.page_size)
    pool = args.pool_pages if args.pool_pages > 0 else per_req + 1
    print(f"\n[quant] arch={cfg.name} d_head={cfg.d_head} "
          f"requests={args.requests} hot_pool={pool} pages")

    rows = [bench_quant_variant(v, cfg, params, args, pool)
            for v in ("bf16-tiered", "int8-resident", "int8-tiered",
                      "int8+w8a8")]
    hdr = ("variant", "wall_s", "done%", "tokens", "tok/s", "ttft_p50",
           "ttft_p99", "spill_pg", "spill_MB", "pg_KiB")
    print(" ".join(f"{h:>13}" for h in hdr))
    for r in rows:
        print(f"{r['variant']:>13} {r['wall_s']:>13.2f} "
              f"{r['completed_pct']:>13.1f} {r['tokens']:>13d} "
              f"{r['tok_per_s']:>13.1f} {r['ttft_p50']:>13.3f} "
              f"{r['ttft_p99']:>13.3f} {r['spill_pages']:>13d} "
              f"{r['spill_bytes'] / 1e6:>13.3f} "
              f"{r['page_bytes'] / 1024:>13.1f}")

    bf16, resident, int8, w8 = rows
    for r in rows:
        assert r["completed_pct"] == 100.0, \
            f"{r['variant']} dropped requests on the quant trace"
    # the tier relocates quantized pages, it never re-quantizes: the
    # int8 engine's outputs must survive spill/prefetch bit for bit
    assert int8["out_tokens"] == resident["out_tokens"], \
        "int8 tiered outputs diverge from the int8 all-resident run"
    assert int8["spill_pages"] > 0, "quant trace never exercised the tier"
    ratio = bf16["spill_bytes"] / max(int8["spill_bytes"], 1)
    page_ratio = bf16["page_bytes"] / int8["page_bytes"]
    assert ratio >= 1.8, \
        f"int8 KV spilled only x{ratio:.2f} fewer bytes (< 1.8x)"
    # greedy streams on random prompts may flip argmax near-ties; report
    # agreement rather than asserting it (the serving tests pin exact
    # matches on margin-checked prompts)
    agree = sum(1 for k, v in int8["out_tokens"].items()
                if bf16["out_tokens"].get(k) == v)
    print(f"\nquant: 100% completed on all variants; int8 tiered "
          f"bit-identical to int8 resident; spill bytes "
          f"{bf16['spill_bytes'] / 1e6:.3f} MB -> "
          f"{int8['spill_bytes'] / 1e6:.3f} MB (x{ratio:.2f} less, "
          f"x{page_ratio:.2f}/page); {agree}/{len(int8['out_tokens'])} "
          f"greedy streams match bf16")
    print(f"TTFT p50 {bf16['ttft_p50'] * 1e3:.2f} ms (bf16) -> "
          f"{int8['ttft_p50'] * 1e3:.2f} ms (int8 KV) -> "
          f"{w8['ttft_p50'] * 1e3:.2f} ms (int8 KV + w8a8); tok/s "
          f"{bf16['tok_per_s']:.1f} -> {int8['tok_per_s']:.1f} -> "
          f"{w8['tok_per_s']:.1f}")
    # reprice the same traffic on the flash channel model: the halved page
    # moves the per-token tier cost with it
    for r, dt in ((bf16, "bf16"), (int8, "int8")):
        sim_pg = family_kv_page_bytes(cfg, args.page_size, kv_dtype=dt)
        assert sim_pg == r["page_bytes"], \
            f"sim {dt} page bytes {sim_pg} != engine {r['page_bytes']}"
        s = r["eng"].stats
        cost = kv_swap_overhead_s(
            cfg, CAMBRICON_LLM_S, s.kv_spill_bytes / max(s.tokens_out, 1),
            s.kv_prefetch_bytes / max(s.tokens_out, 1),
            seq_len=args.max_seq)
        print(f"modeled bubble-bandwidth cost ({dt} pages): "
              f"{cost * 1e6:.2f} us/token")
    return rows


def bench_fleet(cfg, params, args) -> list[dict]:
    """The fleet failover trace: N workers behind the fleet transport,
    one of them killed mid-trace.  The fleet must complete 100% of the
    requests with every output stream bit-identical to an undisturbed
    single-engine run (greedy AND seed-pinned stochastic); the report
    prices the failover — recovery latency and tokens replayed."""
    import os as _os
    import signal as _signal

    from repro.serving.fleet.router import FleetRouter

    def mk_reqs():
        base = make_requests(args.requests, cfg, args.max_new, args.seed)
        # odd rids go stochastic with pinned seeds: failover replay must
        # hold bit-identity for sampled streams too
        return [Request(rid=r.rid, prompt=list(r.prompt),
                        max_new_tokens=r.max_new_tokens,
                        sampling=None if r.rid % 2 == 0 else SamplingParams(
                            temperature=0.8, top_k=20, seed=1000 + r.rid))
                for r in base]

    print(f"[fleet] arch={cfg.name} requests={args.requests} "
          f"workers={args.workers} spares={args.spares} "
          f"transport={args.transport}")
    _warm(cfg, params, args)

    # reference: ONE undisturbed in-process engine (per-request streams
    # are batch-composition-invariant, so this is the oracle)
    solo_reqs = mk_reqs()
    solo = ServingEngine(cfg, params, max_batch=args.max_batch,
                         max_seq=args.max_seq, eos_id=-1,
                         page_size=args.page_size)
    for r in solo_reqs:
        solo.submit(r)
    t0 = time.monotonic()
    solo.run()
    solo_wall = time.monotonic() - t0
    ref = {r.rid: list(r.out_tokens) for r in solo_reqs}

    if args.transport == "socket":
        fl = FleetRouter.build_socket(
            args.arch, workers=args.workers, spares=args.spares,
            checkpoint_every=4, migrate=False, reduced=bool(args.reduced),
            max_batch=args.max_batch, max_seq=args.max_seq,
            page_size=args.page_size, eos_id=-1)
    else:
        fl = FleetRouter.build_loopback(
            cfg, params, workers=args.workers, spares=args.spares,
            checkpoint_every=4, migrate=False, max_batch=args.max_batch,
            max_seq=args.max_seq, eos_id=-1, page_size=args.page_size)
    reqs = mk_reqs()
    arrivals = poisson_arrivals(args.requests, args.rate, args.seed)
    total_expected = sum(r.max_new_tokens for r in reqs)
    t0 = time.monotonic()
    i = 0
    killed = False
    while True:
        now = time.monotonic() - t0
        while i < len(reqs) and arrivals[i] <= now:
            fl.submit(reqs[i])
            i += 1
        fl.step()
        delivered = sum(len(r.out_tokens) for r in reqs)
        if not killed and delivered >= 0.4 * total_expected:
            w = fl.workers[0]
            if args.transport == "socket":
                _os.kill(w.transport.pid, _signal.SIGKILL)
            else:
                w.transport.kill()
            killed = True
        if not fl.has_work:
            if i >= len(reqs):
                break
            time.sleep(max(0.0, min(0.001,
                                    arrivals[i] - (time.monotonic() - t0))))
    wall = time.monotonic() - t0
    assert killed, "trace finished before the scripted kill fired"
    assert all(r.done for r in reqs), \
        f"lost requests: {[r.rid for r in reqs if not r.done]}"
    for r in reqs:
        assert list(r.out_tokens) == ref[r.rid], \
            f"rid {r.rid} diverged after failover"
    tokens = sum(len(r.out_tokens) for r in reqs)
    recovery = float(np.median(fl.recovery_s)) if fl.recovery_s else 0.0
    rows = [{
        "transport": args.transport,
        "workers": args.workers,
        "wall_s": wall,
        "solo_wall_s": solo_wall,
        "tokens": tokens,
        "tok_per_s": tokens / wall,
        "workers_lost": fl.fleet.workers_lost,
        "failovers": fl.fleet.failovers,
        "requests_replayed": fl.fleet.requests_replayed,
        "tokens_replayed": fl.fleet.tokens_replayed,
        "recovery_s": recovery,
    }]
    print(f"  completed 100% ({len(reqs)} requests, {tokens} tokens), "
          f"all streams bit-identical to the undisturbed run")
    print(f"  wall {wall:.1f}s (solo {solo_wall:.1f}s)  "
          f"failovers={fl.fleet.failovers} "
          f"requests_replayed={fl.fleet.requests_replayed} "
          f"tokens_replayed={fl.fleet.tokens_replayed} "
          f"recovery={recovery * 1e3:.0f} ms")
    print(fl.summary())
    fl.close()
    return rows


def bench_sanitize(cfg, params, args) -> dict:
    """Sanitizer-rails smoke: the overlapped + tiered + prefix-cache decode
    path (every rail armed at once — shadow allocators, dispatch aliasing
    guard, retrace budget) driven under ``REPRO_SANITIZE=1`` and raced
    against the identical un-sanitized engine.  Asserts the rails actually
    ran, reported nothing, changed no output token, and cost < 2x wall."""
    from repro.serving.kv_cache import pages_needed

    # the shared prefix must cover whole pages to be cacheable: two pages
    # of system prompt + a short random tail per request
    common_len = 2 * args.page_size
    tail_len = 4
    rng = np.random.RandomState(args.seed + 7)
    common = rng.randint(0, cfg.vocab_size, size=common_len).tolist()
    per_req = pages_needed(min(args.max_seq,
                               common_len + tail_len + args.max_new),
                           args.page_size)
    pool = per_req + 1  # two concurrent requests exceed it even with the
    # common pages deduped by the prefix cache, so the tier must spill

    def trace(sanitized: bool) -> tuple[float, dict, object]:
        prev = os.environ.get("REPRO_SANITIZE")
        os.environ["REPRO_SANITIZE"] = "1" if sanitized else "0"
        try:
            kw = dict(mode="continuous", overlap=True, kv_tier="flash",
                      num_pages=pool, prefix_cache=True)
            _warm(cfg, params, args, **kw)
            eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                                max_seq=args.max_seq, eos_id=-1,
                                page_size=args.page_size, **kw)
            # shared system-prompt prefix + random tail: requests hit the
            # prefix cache against each other, so the refcounted/CoW page
            # path runs under the shadow allocator too
            req_rng = np.random.RandomState(args.seed + 8)
            reqs = [Request(rid=rid, prompt=common + req_rng.randint(
                        0, cfg.vocab_size, size=tail_len).tolist(),
                        max_new_tokens=args.max_new)
                    for rid in range(args.requests)]
            # everything arrives at once: max concurrency, so the tight
            # pool actually forces spill/prefetch traffic under the shadow
            arrivals = np.zeros(args.requests)
            wall = drive(eng, reqs, arrivals)
            assert all(r.done and not r.rejected for r in reqs)
            outs = {r.rid: list(r.out_tokens) for r in reqs}
            return wall, outs, eng
        finally:
            if prev is None:
                os.environ.pop("REPRO_SANITIZE", None)
            else:
                os.environ["REPRO_SANITIZE"] = prev

    print(f"\n[sanitize] arch={cfg.name} requests={args.requests} "
          f"hot_pool={pool} pages, overlapped+tiered+prefix, rails armed")
    from repro import _sanitize
    prev = os.environ.get("REPRO_SANITIZE")
    os.environ["REPRO_SANITIZE"] = "1"   # load() gates on the env var
    try:
        san = _sanitize.load()
    finally:
        if prev is None:
            os.environ.pop("REPRO_SANITIZE", None)
        else:
            os.environ["REPRO_SANITIZE"] = prev
    assert san is not None, "tools.analysis.sanitize not importable"
    trace(sanitized=False)  # discarded: first pass pays the jit compiles
    plain_wall, plain_outs, _ = trace(sanitized=False)
    san.reset_counters()
    san_wall, san_outs, eng = trace(sanitized=True)

    assert san.report_count() == 0, \
        f"sanitizer reported {san.report_count()} violation(s) on a clean run"
    assert san.check_count() > 0, "rails never executed — hooks are dead"
    assert getattr(eng.allocator, "_shadow", None) is not None or \
        getattr(getattr(eng.allocator, "hot", None), "_shadow", None) \
        is not None, "page shadow not attached"
    assert san_outs == plain_outs, \
        "sanitized run changed output tokens — rails must be pure observers"
    slowdown = san_wall / max(plain_wall, 1e-9)
    print(f"{'variant':>10} {'wall_s':>8} {'checks':>8} {'reports':>8}")
    print(f"{'plain':>10} {plain_wall:>8.2f} {'-':>8} {'-':>8}")
    print(f"{'sanitized':>10} {san_wall:>8.2f} {san.check_count():>8d} "
          f"{san.report_count():>8d}")
    print(f"[sanitize] slowdown {slowdown:.2f}x "
          f"(spill={eng.stats.kv_spill_pages} pages, "
          f"prefetch={eng.stats.kv_prefetch_pages} pages, "
          f"prefix_hits={eng.stats.prefix_hits})")
    assert slowdown < 2.0, \
        f"sanitizer slowdown {slowdown:.2f}x breaches the 2x budget"
    return {"wall_plain_s": plain_wall, "wall_sanitized_s": san_wall,
            "slowdown": slowdown, "checks": san.check_count(),
            "reports": san.report_count()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=16.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="hot KV pool size for the kvtier trace "
                         "(0 = auto, sized below trace demand)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="replica count for the router trace (raced "
                         "against ONE replica with the same total "
                         "slot+page budget)")
    ap.add_argument("--workers", type=int, default=2,
                    help="fleet trace: workers behind the fleet transport")
    ap.add_argument("--spares", type=int, default=1,
                    help="fleet trace: hot spares promoted on failover")
    ap.add_argument("--transport", default="loopback",
                    choices=("loopback", "socket"),
                    help="fleet trace transport (socket = real subprocess "
                         "workers, SIGKILLed mid-trace)")
    ap.add_argument("--trace", choices=("admission", "overlap", "kvtier",
                                        "policy", "prefix", "router",
                                        "quant", "fleet", "sanitize",
                                        "all"),
                    default="all")
    ap.add_argument("--overlap", action="store_true",
                    help="run the admission trace's continuous engine with "
                         "the overlapped decode loop (fused dispatch, "
                         "one-step-delayed readback)")
    ap.add_argument("--chunk-prefill", type=int, default=8,
                    help="chunked-prefill token budget for the policy "
                         "trace (0 = one-shot prefill)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast preset for CI (overrides sizes)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 8)
        args.max_new = min(args.max_new, 10)
        args.max_batch = min(args.max_batch, 3)
        args.max_seq = min(args.max_seq, 64)
        args.page_size = min(args.page_size, 8)
        args.rate = 32.0

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0),
                                   max_seq=args.max_seq)
    out = {}
    if args.trace in ("admission", "all"):
        out["admission"] = bench_admission(cfg, params, args)
    if args.trace in ("overlap", "all"):
        out["overlap"] = bench_overlap(cfg, params, args)
    if args.trace in ("kvtier", "all"):
        out["kvtier"] = bench_kvtier(cfg, params, args)
    if args.trace in ("policy", "all"):
        out["policy"] = bench_policy(cfg, params, args)
    if args.trace in ("prefix", "all"):
        out["prefix"] = bench_prefix(cfg, params, args)
    if args.trace in ("router", "all"):
        out["router"] = bench_router(cfg, params, args)
    if args.trace in ("quant", "all"):
        out["quant"] = bench_quant(cfg, params, args)
    if args.trace in ("fleet", "all"):
        out["fleet"] = bench_fleet(cfg, params, args)
    if args.trace in ("sanitize", "all"):
        out["sanitize"] = bench_sanitize(cfg, params, args)
    return out


if __name__ == "__main__":
    main()
