"""One benchmark per paper table/figure.  Each returns a list of CSV rows
``(name, us_per_call, derived)`` where ``derived`` carries the figure's
headline quantity (tok/s, speedup, utilization, ratio...).
"""

from __future__ import annotations

import dataclasses
import time

from repro.configs.registry import ARCHS
from repro.core import tiling
from repro.core.hw import CAMBRICON_LLM_L, CAMBRICON_LLM_S, FLASH_CONFIGS
from repro.core.schedule import Policy, channel_workload
from repro.sim import baselines, energy
from repro.sim.engine import simulate_channel
from repro.sim.llm_perf import decode_token_time, flash_only_token_time


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def fig6_slice_trace():
    """Slice-control channel schedules: completion time per policy."""
    rows = []
    plan = tiling.plan_matrix(4096, 4096, CAMBRICON_LLM_S)
    w = channel_workload(plan, CAMBRICON_LLM_S)
    for pol in Policy:
        res, us = _timed(lambda p=pol: simulate_channel(w, p, keep_trace=True))
        rows.append((f"fig6/{pol.value}", f"{us:.1f}",
                     f"time_us={res.time*1e6:.1f};util={res.util:.3f};"
                     f"segments={len(res.segments)}"))
    return rows


def fig9_end2end():
    """Decode speed vs Flexgen/MLC-LLM for OPT + Llama2 families."""
    rows = []
    for model in ("opt-6.7b", "opt-13b", "opt-30b", "opt-66b",
                  "llama2-7b", "llama2-13b", "llama2-70b"):
        cfg = ARCHS[model]
        for fname in ("S", "M", "L"):
            tt, us = _timed(lambda c=cfg, f=fname: decode_token_time(
                c, FLASH_CONFIGS[f], seq_len=1000))
            rows.append((f"fig9/{model}/{fname}", f"{us:.0f}",
                         f"tok_s={tt.tokens_per_s:.2f};util={tt.channel_util:.2f}"))
        fg = baselines.flexgen_ssd_tokens_per_s(cfg)
        fd = baselines.flexgen_dram_tokens_per_s(cfg)
        ours = decode_token_time(cfg, CAMBRICON_LLM_L, seq_len=1000).tokens_per_s
        rows.append((f"fig9/{model}/speedup_vs_flexgen_ssd", "0",
                     f"x{ours/fg:.1f}"))
        rows.append((f"fig9/{model}/speedup_vs_flexgen_dram", "0",
                     f"x{ours/fd:.1f}"))
    mlc = baselines.mlc_llm_tokens_per_s(ARCHS["llama2-7b"])
    rows.append(("fig9/mlc-llm/llama2-7b", "0", f"tok_s={mlc:.2f}"))
    return rows


def fig10_ecc_accuracy():
    """Model-quality retention under BER, with and without on-die ECC.

    Proxy metric (no eval harness offline): top-1 logit agreement of a
    reduced OPT-6.7B-family model vs its clean self under injected flash
    errors on the quantized weights."""
    import jax
    import jax.numpy as jnp

    from repro.core.hw import CAMBRICON_LLM_S
    from repro.core.hybrid_gemv import (corrupt_flash_region, hybrid_gemv,
                                        plan_and_quantize)

    rows = []
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (2048, 2048)) * 0.05
    xs = jax.random.normal(jax.random.fold_in(key, 1), (2048, 16))
    clean = w @ xs
    hw = plan_and_quantize(w, CAMBRICON_LLM_S, with_ecc=True)
    for ber in (1e-5, 1e-4, 2e-4, 8e-4):
        k = jax.random.fold_in(key, int(ber * 1e7))
        noisy = corrupt_flash_region(hw, ber, k)

        def cos(y):
            num = jnp.sum(y * clean)
            den = jnp.linalg.norm(y) * jnp.linalg.norm(clean)
            return float(num / den)

        (y_ecc, us) = _timed(lambda: hybrid_gemv(noisy, xs))
        y_raw = hybrid_gemv(noisy._replace(ecc=None), xs)
        rows.append((f"fig10/ber{ber:.0e}", f"{us:.0f}",
                     f"cos_ecc={cos(y_ecc):.4f};cos_raw={cos(y_raw):.4f}"))
    return rows


def fig11_w4a16():
    rows = []
    for model in ("opt-6.7b", "opt-13b", "opt-30b", "opt-66b"):
        for fname in ("S", "L"):
            cfg = ARCHS[model]
            t8, us = _timed(lambda: decode_token_time(
                cfg, FLASH_CONFIGS[fname], bytes_per_elem=1.0))
            t4 = decode_token_time(cfg, FLASH_CONFIGS[fname],
                                   bytes_per_elem=0.5)
            rows.append((f"fig11/{model}/{fname}", f"{us:.0f}",
                         f"w8a8={t8.tokens_per_s:.2f};w4a16={t4.tokens_per_s:.2f};"
                         f"gain={t8.total/t4.total - 1:.1%}"))
    return rows


def fig12_slicing():
    rows = []
    for model in ("opt-6.7b", "opt-13b", "opt-30b", "llama2-7b"):
        cfg = ARCHS[model]
        ts, us = _timed(lambda: decode_token_time(
            cfg, CAMBRICON_LLM_S, policy=Policy.RC_SLICED))
        tu = decode_token_time(cfg, CAMBRICON_LLM_S, policy=Policy.RC_UNSLICED)
        rows.append((f"fig12/{model}", f"{us:.0f}",
                     f"speedup={tu.total/ts.total:.2f}x;"
                     f"util_sliced={ts.channel_util:.2f};"
                     f"util_unsliced={tu.channel_util:.2f}"))
    return rows


def fig13_tile_sizes():
    rows = []
    cfg = ARCHS["opt-6.7b"]
    for name, tile in [("256x2048_opt", None),
                       ("128x4096", tiling.TileShape(128, 4096)),
                       ("4096x128", tiling.TileShape(4096, 128))]:
        tt, us = _timed(lambda t=tile: decode_token_time(
            cfg, CAMBRICON_LLM_S, tile_override=t))
        rows.append((f"fig13/{name}", f"{us:.0f}",
                     f"tok_s={tt.tokens_per_s:.2f}"))
    return rows


def fig14_tiling():
    rows = []
    for model in ("opt-6.7b", "opt-13b", "llama2-7b"):
        cfg = ARCHS[model]
        th, us = _timed(lambda: decode_token_time(cfg, CAMBRICON_LLM_S))
        tf = flash_only_token_time(cfg, CAMBRICON_LLM_S)
        rows.append((f"fig14/{model}", f"{us:.0f}",
                     f"speedup={tf.total/th.total:.2f}x;"
                     f"util_hybrid={th.channel_util:.2f};"
                     f"util_flashonly={tf.channel_util:.2f}"))
    return rows


def fig15_scalability():
    rows = []
    cfg = ARCHS["opt-6.7b"]
    base = CAMBRICON_LLM_S
    for ch in (1, 2, 4, 8, 16, 32, 64):
        f = dataclasses.replace(base, channels=ch, chips_per_channel=4)
        tt, us = _timed(lambda ff=f: decode_token_time(cfg, ff))
        rows.append((f"fig15/channels{ch}", f"{us:.0f}",
                     f"tok_s={tt.tokens_per_s:.2f};util={tt.channel_util:.2f}"))
    for chips in (1, 2, 4, 8, 16, 32, 64, 128):
        f = dataclasses.replace(base, channels=8, chips_per_channel=chips)
        tt, us = _timed(lambda ff=f: decode_token_time(cfg, ff))
        rows.append((f"fig15/chips{chips}", f"{us:.0f}",
                     f"tok_s={tt.tokens_per_s:.2f};util={tt.channel_util:.2f}"))
    return rows


def fig16_transfer_energy():
    rows = []
    for model in ("opt-6.7b", "opt-13b", "opt-30b", "opt-66b"):
        cfg = ARCHS[model]
        tt, us = _timed(lambda: decode_token_time(cfg, CAMBRICON_LLM_S,
                                                  seq_len=1000))
        from repro.core import planner

        kv = planner.kv_cache_bytes(cfg, 1000, 1, 1)
        ours = energy.cambricon_per_token(cfg, CAMBRICON_LLM_S,
                                          tt.channel_bytes,
                                          tt.flash_array_bytes, kv)
        theirs = energy.flexgen_ssd_per_token(cfg, kv)
        rows.append((f"fig16/{model}", f"{us:.0f}",
                     f"transfer_ratio={theirs.transferred_bytes/ours.transferred_bytes:.1f}x;"
                     f"energy_ratio={ours.energy_j/theirs.energy_j:.2f};"
                     f"ours_mj={ours.energy_mj:.1f}"))
    return rows


ALL_FIGS = [fig6_slice_trace, fig9_end2end, fig10_ecc_accuracy, fig11_w4a16,
            fig12_slicing, fig13_tile_sizes, fig14_tiling, fig15_scalability,
            fig16_transfer_energy]
