"""Quickstart: the paper's technique end to end on one weight matrix.

1. Plan a GeMV with the §V hardware-aware tiling (optimal tile + α split);
2. quantize to INT8 and protect the flash-resident region with the §VI
   outlier ECC;
3. inject NAND-grade bit flips, run the hybrid NPU+flash GeMV (Pallas paged
   kernel for the flash path), and watch ECC keep the result accurate;
4. estimate the end-to-end decode speed of Llama2-70B on Cambricon-LLM-L.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.core import tiling
from repro.core.hw import CAMBRICON_LLM_L, CAMBRICON_LLM_S
from repro.core.hybrid_gemv import (corrupt_flash_region, hybrid_gemv,
                                    plan_and_quantize)
from repro.sim.llm_perf import decode_token_time

key = jax.random.PRNGKey(0)

# -- 1. plan ---------------------------------------------------------------
h, w = 4096, 4096
plan = tiling.plan_matrix(h, w, CAMBRICON_LLM_S)
print(f"matrix {h}x{w} on Cambricon-LLM-S:")
print(f"  optimal tile  : {plan.tile.h} x {plan.tile.w} "
      f"(paper Fig.13 optimum: 256 x 2048)")
print(f"  alpha (flash) : {plan.alpha:.2f} -> {plan.flash_rows} rows in-flash,"
      f" {plan.npu_rows} rows streamed to NPU")

# -- 2/3. quantize + ECC + errors + hybrid execution ------------------------
W = jax.random.normal(key, (h, w)) * 0.05
x = jax.random.normal(jax.random.fold_in(key, 1), (w,))
ref = W @ x
hw = plan_and_quantize(W, CAMBRICON_LLM_S, with_ecc=True)
noisy = corrupt_flash_region(hw, ber=2e-4, key=jax.random.fold_in(key, 2))


def rel(y):
    return float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))


print(f"\nhybrid GeMV rel-error vs float:")
print(f"  clean weights        : {rel(hybrid_gemv(hw, x)):.4f} (int8 noise)")
print(f"  BER 2e-4, with ECC   : {rel(hybrid_gemv(noisy, x)):.4f}")
print(f"  BER 2e-4, without ECC: "
      f"{rel(hybrid_gemv(noisy._replace(ecc=None), x)):.4f}")

# -- 4. end-to-end estimate --------------------------------------------------
tt = decode_token_time(ARCHS["llama2-70b"], CAMBRICON_LLM_L, seq_len=1000)
print(f"\nLlama2-70B INT8 on Cambricon-LLM-L: {tt.tokens_per_s:.2f} tok/s "
      f"(paper: 3.44), channel util {tt.channel_util:.0%}")
