"""Fig-10 style study: model-output fidelity vs NAND bit-error rate, with and
without the on-die outlier ECC, on a real (reduced) transformer.

Run:  PYTHONPATH=src python examples/ecc_resilience.py
"""

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core import ecc
from repro.models import model as model_lib
from repro.quant.convert import quantize_params

cfg = get_arch("smollm-360m").reduced()
key = jax.random.PRNGKey(0)
params = model_lib.init_params(cfg, key, dtype=jnp.float32, max_seq=64)
qparams = quantize_params(params)
toks = jax.random.randint(key, (4, 24), 0, cfg.vocab_size)
clean_logits = model_lib.forward(qparams, cfg, toks, {})
clean_top1 = jnp.argmax(clean_logits, -1)


def corrupt_tree(tree, ber, k, with_ecc):
    """Bit-flip every int8 weight; optionally protect each 16K page with ECC."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        if getattr(leaf, "dtype", None) == jnp.int8:
            k = jax.random.fold_in(k, hash(str(path)) % 2**30)
            flat_w = jax.lax.bitcast_convert_type(leaf.reshape(-1), jnp.uint8)
            pad = (-flat_w.shape[0]) % ecc.PAGE_ELEMS
            pages = jnp.pad(flat_w, (0, pad)).reshape(-1, ecc.PAGE_ELEMS)
            code = ecc.encode_pages(pages) if with_ecc else None
            noisy = ecc.inject_bitflips(pages, ber, k)
            if with_ecc:
                code = ecc.inject_ecc_bitflips(code, ber,
                                               jax.random.fold_in(k, 1))
                noisy = ecc.decode_pages(noisy, code)
            w = jax.lax.bitcast_convert_type(
                noisy.reshape(-1)[:flat_w.shape[0]], jnp.int8)
            out.append(w.reshape(leaf.shape))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, [o for o in out])


print(f"{'BER':>8} | {'top1 agree (ECC)':>17} | {'top1 agree (raw)':>17}")
for ber in (1e-5, 1e-4, 2e-4, 8e-4, 2e-3):
    k = jax.random.fold_in(key, int(ber * 1e7))
    agree = {}
    for with_ecc in (True, False):
        noisy = corrupt_tree(qparams, ber, k, with_ecc)
        logits = model_lib.forward(noisy, cfg, toks, {})
        agree[with_ecc] = float((jnp.argmax(logits, -1) == clean_top1).mean())
    print(f"{ber:8.0e} | {agree[True]:16.1%} | {agree[False]:16.1%}")
print("\n(paper Fig. 10: ECC holds 92-95% accuracy at 2e-4 where the "
      "unprotected model collapses)")
