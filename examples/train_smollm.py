"""Train a ~100M-class llama-family model for a few hundred steps with
checkpoint/restart fault tolerance.

A width-reduced smollm (4 layers, d=256) keeps CPU wall-time sane while
exercising the full substrate: data pipeline -> microbatched AdamW ->
checkpoint -> crash -> resume.

Run:  PYTHONPATH=src python examples/train_smollm.py  [--steps 300]
"""

import argparse
import dataclasses
import shutil
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.distributed.checkpoint import restore_checkpoint, save_checkpoint
from repro.models import model as model_lib
from repro.training.data import DataState, make_batch
from repro.training.optimizer import init_adamw
from repro.training.train_loop import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

cfg = dataclasses.replace(
    get_arch("smollm-360m"), n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
    d_head=64, d_ff=768, vocab_size=2048, name="smollm-mini")
params = model_lib.init_params(cfg, jax.random.PRNGKey(0),
                               dtype=jnp.float32, max_seq=args.seq)
n = sum(p.size for p in jax.tree.leaves(params))
print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps of "
      f"{args.batch}x{args.seq}")

opt = init_adamw(params)
step_fn = jax.jit(make_train_step(cfg, microbatches=2, lr=1e-3, remat=False))
ds = DataState(seed=0, step=0)
ckpt = "/tmp/repro_train_ckpt"
shutil.rmtree(ckpt, ignore_errors=True)

t0 = time.time()
first = last = None
for i in range(args.steps):
    toks, ds = make_batch(ds, args.batch, args.seq, cfg.vocab_size)
    params, opt, loss = step_fn(params, opt, toks, None)
    if first is None:
        first = float(loss)
    last = float(loss)
    if i % 20 == 0:
        print(f"step {i:4d} loss {float(loss):.4f}", flush=True)
    if i == args.steps // 2:
        save_checkpoint(ckpt, i, (params, opt), extra={"data_step": ds.step})
        print(f"-- checkpoint at step {i}; simulating crash + restart --")
        (params, opt), extra = restore_checkpoint(ckpt, (params, opt))
        ds = DataState(seed=0, step=extra["data_step"])

tps = args.steps * args.batch * args.seq / (time.time() - t0)
print(f"\nloss {first:.3f} -> {last:.3f} "
      f"({'LEARNING' if last < first else 'NOT LEARNING'}), {tps:,.0f} tok/s")
assert last < first, "training failed to reduce loss"
