"""End-to-end serving demo on the attention/SSM-hybrid family, through the
three-layer serving API: a ServingClient hands requests to a Router, which
spreads them over two EngineCore replicas (least-loaded) and migrates slots
between them when one runs out of KV pages — zamba2's shared-attention KV is
paged like any dense cache while the per-slot Mamba state lives in the
slot-indexed state pool, and BOTH travel inside a migration snapshot.

Each replica runs the paper's deployment scenario (W8A8 weights, continuous
batching over the paged per-slot KV cache, straggler watchdog); with 6
requests and only 2 slots per replica, queued requests admit the moment a
slot frees anywhere in the fleet.

Run:  PYTHONPATH=src python examples/serve_hybrid.py
"""

import time

import jax

from repro.configs.registry import get_arch
from repro.models import model as model_lib
from repro.quant.convert import quantize_params
from repro.serving.client import ServingClient

cfg = get_arch("zamba2-7b").reduced()  # hybrid: paged shared-attn KV + SSM state pool
params = model_lib.init_params(cfg, jax.random.PRNGKey(0), max_seq=128)
params = quantize_params(params)  # the paper's W8A8 deployment mode

slow_steps = {3}  # pretend decode step 3 straggles -> engine re-dispatches
watchdog = lambda step, dt: step in slow_steps and not slow_steps.discard(step)

client = ServingClient(cfg, params, replicas=2, route="least_loaded",
                       max_batch=2, max_seq=128, eos_id=-1,
                       watchdog=watchdog, mode="continuous", page_size=16)
prompts = [[1, 2, 3], [7, 8], [11, 12, 13, 14], [21], [31, 32], [41, 42, 43]]
handles = [client.submit(p, max_new_tokens=12 - i)
           for i, p in enumerate(prompts)]

t0 = time.time()
client.run()
dt = time.time() - t0
for h in handles:
    r = h.request
    print(f"req {r.rid}: prompt={r.prompt} -> {r.out_tokens} "
          f"(reason={h.finish_reason})")
stats = client.router.stats
tokens = sum(s.tokens_out for s in stats)
print(f"\n{tokens} tokens in {dt:.1f}s ({tokens/dt:.1f} tok/s), "
      f"single-slot prefills={sum(s.prefills for s in stats)}, "
      f"straggler re-dispatches={sum(s.straggler_events for s in stats)}")
print(client.summary())
