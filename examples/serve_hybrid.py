"""End-to-end serving driver: batched requests, W8A8 weights, continuous
batching over the paged per-slot KV cache, straggler watchdog — the paper's
deployment scenario as a server, on the attention/SSM-hybrid family it is
named for: zamba2's shared-attention KV is paged like any dense cache while
the per-slot Mamba state lives in the slot-indexed state pool.

With 6 requests and only 2 slots, the paged cache admits each queued request
the moment a slot frees (single-slot prefill while the other slot keeps
decoding) instead of waiting for the whole batch to drain.

Run:  PYTHONPATH=src python examples/serve_hybrid.py
"""

import time

import jax

from repro.configs.registry import get_arch
from repro.models import model as model_lib
from repro.quant.convert import quantize_params
from repro.serving.engine import Request, ServingEngine

cfg = get_arch("zamba2-7b").reduced()  # hybrid: paged shared-attn KV + SSM state pool
params = model_lib.init_params(cfg, jax.random.PRNGKey(0), max_seq=128)
params = quantize_params(params)  # the paper's W8A8 deployment mode

slow_steps = {3}  # pretend decode step 3 straggles -> engine re-dispatches
watchdog = lambda step, dt: step in slow_steps and not slow_steps.discard(step)

eng = ServingEngine(cfg, params, max_batch=2, max_seq=128, eos_id=-1,
                    watchdog=watchdog, mode="continuous", page_size=16)
prompts = [[1, 2, 3], [7, 8], [11, 12, 13, 14], [21], [31, 32], [41, 42, 43]]
reqs = [Request(rid=i, prompt=p, max_new_tokens=12 - i)
        for i, p in enumerate(prompts)]
for r in reqs:
    eng.submit(r)

t0 = time.time()
stats = eng.run()
dt = time.time() - t0
for r in reqs:
    print(f"req {r.rid}: prompt={r.prompt} -> {r.out_tokens}")
print(f"\n{stats.tokens_out} tokens in {dt:.1f}s "
      f"({stats.tokens_out/dt:.1f} tok/s), single-slot prefills="
      f"{stats.prefills}, straggler re-dispatches={stats.straggler_events}")
print(stats.summary())
