import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.configs.base import SHAPES
from repro.distributed import ctx as dctx
from repro.distributed import sharding as shd
from repro.launch.dryrun import act_constraint, build_step
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as specs_lib
from repro.models import model as model_lib
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = make_production_mesh()
shape = SHAPES["train_4k"]
base = get_arch("command-r-plus-104b")


def measure(tag, cfg, kind="train", microbatches=8, grad_only=False,
            no_head=False):
    with dctx.lowering_ctx(constrain=act_constraint(mesh), remat=True,
                           mesh=mesh):
        with mesh:
            if not grad_only and not no_head:
                jf, argspecs = build_step(cfg, shape, mesh, microbatches)
            else:
                pspecs = specs_lib.param_specs(cfg, max_seq=4096, quant=False)
                pshard = shd.params_shardings(pspecs, mesh)
                tok_shard = NamedSharding(mesh, shd.batch_pspec(mesh, 256, 2))
                toks = jax.ShapeDtypeStruct((256, 4096), jnp.int32)

                def lfn(params, tokens):
                    logits = model_lib.forward(params, cfg, tokens, None)
                    if no_head:
                        return logits.astype(jnp.float32).sum()
                    lse = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
                    return lse.mean()

                def fn(params, tokens):
                    return jax.grad(lfn)(params, tokens)

                jf = jax.jit(fn, in_shardings=(pshard, tok_shard),
                             donate_argnums=())
                argspecs = (pspecs, toks)
            comp = jf.lower(*argspecs).compile()
    mem = comp.memory_analysis()
    print(f"{tag:32s} temp={mem.temp_size_in_bytes/1e9:7.2f}GB "
          f"args={mem.argument_size_in_bytes/1e9:6.2f}GB", flush=True)


measure("full(mb8)", base)
measure("grad-only (no adam, mb1)", base, grad_only=True)
measure("grad-only, sum-loss (no lse)", base, no_head=True, grad_only=True)
measure("8 layers full", dataclasses.replace(base, n_layers=8))
measure("untied full", dataclasses.replace(base, tie_embeddings=False))
