import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import re
import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.distributed import ctx as dctx
from repro.distributed import sharding as shd
from repro.launch.dryrun import act_constraint
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as specs_lib
from repro.models import model as model_lib
from jax.sharding import NamedSharding

mesh = make_production_mesh()
cfg = dataclasses.replace(get_arch("command-r-plus-104b"), n_layers=1)

with dctx.lowering_ctx(constrain=act_constraint(mesh), remat=True, mesh=mesh):
    with mesh:
        pspecs = specs_lib.param_specs(cfg, max_seq=4096, quant=False)
        pshard = shd.params_shardings(pspecs, mesh)
        tok_shard = NamedSharding(mesh, shd.batch_pspec(mesh, 256, 2))
        toks = jax.ShapeDtypeStruct((256, 4096), jnp.int32)

        def lfn(params, tokens):
            logits = model_lib.forward(params, cfg, tokens, None)
            lse = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
            return lse.mean()

        jf = jax.jit(lambda p, t: jax.grad(lfn)(p, t),
                     in_shardings=(pshard, tok_shard))
        comp = jf.lower(pspecs, toks).compile()

mem = comp.memory_analysis()
print(f"temp={mem.temp_size_in_bytes/1e9:.2f}GB")
text = comp.as_text()
DT = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1, "pred": 1,
      "f16": 2, "u16": 2, "s16": 2, "f64": 8, "s64": 8, "u64": 8}
sizes = {}
for m in re.finditer(r"= (\w+)\[([\d,]+)\]", text):
    dt, dims = m.group(1), m.group(2)
    if dt not in DT:
        continue
    n = 1
    for d in dims.split(","):
        n *= int(d)
    key = f"{dt}[{dims}]"
    b = n * DT[dt]
    if b > 100e6:
        sizes[key] = (b, sizes.get(key, (0, 0))[1] + 1)
for k, (b, c) in sorted(sizes.items(), key=lambda kv: -kv[1][0])[:15]:
    print(f"{b/1e9:8.2f}GB x{c:3d}  {k}")
