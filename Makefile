# Convenience targets; CI runs the same commands (.github/workflows/ci.yml).

.PHONY: test test-fast test-slow test-families test-fleet \
	test-fleet-socket test-quant test-sanitize lint bench-serving \
	bench-serving-smoke bench-serving-policy bench-serving-kvtier-mla \
	bench-serving-router bench-serving-overlap bench-serving-prefix \
	bench-serving-fleet bench-serving-quant bench-serving-sanitize

# every family where supports_paged() is true — the serving conformance
# matrix (test ids are fam_<family>, substring-safe: fam_moe != fam_mla_moe)
FAMILIES := dense moe vlm mla_moe hybrid

# full tier-1 (ROADMAP verify command)
test:
	PYTHONPATH=src python -m pytest -x -q

# fast tier: skips the interpret-mode Pallas kernel sweeps
test-fast:
	python -m pytest -q -m "not slow"

# nightly tier: only the slow interpret-mode kernel sweeps
test-slow:
	python -m pytest -q -m slow

# static analysis: the repo-specific hazard-class rules (reprolint) plus
# ruff's baseline if it is installed (CI always installs it; the dev
# container may not have it)
lint:
	PYTHONPATH=src python -m tools.analysis.reprolint src/ tests/
	@command -v ruff >/dev/null 2>&1 \
		&& ruff check src tests tools benchmarks \
		|| echo "ruff not installed; skipped (reprolint ran)"

# the analysis suite (rule fixture corpus + shadow-model properties) and
# one serving family end-to-end with every sanitizer rail armed
test-sanitize:
	python -m pytest -x -q tests/test_analysis.py
	REPRO_SANITIZE=1 python -m pytest -x -q tests/test_serving.py \
		-m "not slow" -k fam_dense

# cross-family serving conformance suite, one family at a time (mirrors the
# CI family-matrix job): mid-stream-admission oracle, eos/max-token
# termination, page recycling, streaming terminals, preempt-resume AND
# cross-replica slot-migration bit-identity — per paged family — plus the
# overlapped-decode-loop bit-identity suite (fused dispatch vs sync loop)
# and the prefix-cache conformance suite (warm-vs-cold bit-identity,
# refcounted release, tiered spill/prefetch of shared pages, migration)
test-families:
	@set -e; for f in $(FAMILIES); do \
		echo "=== conformance: $$f ==="; \
		python -m pytest -x -q tests/test_serving.py \
			tests/test_tiered_kv.py tests/test_router.py \
			tests/test_overlap.py tests/test_prefix_cache.py \
			tests/test_fleet.py tests/test_quant_serving.py \
			-k "fam_$$f"; \
	done

# quantization tier: weight/activation round-trip properties, kernel-vs-ref
# parity (int8 pagegemv per-column scales, w4a16 tile clamp), the
# quantize_params router exemption, and int8-KV serving — cross-path
# bit-identity (overlap, tiered spill, migration, fleet failover), greedy
# parity vs bf16, and the halved spill-byte accounting
test-quant:
	python -m pytest -x -q tests/test_quant.py tests/test_quant_serving.py

# fleet serving over the loopback transport: wire-codec/framing adversity,
# per-family snapshot byte round-trips, and kill-mid-decode failover with
# bit-identical recovered streams (everything except the subprocess tests)
test-fleet:
	python -m pytest -x -q tests/test_fleet.py -k "not sock"

# nightly chaos tier: real subprocess workers over TCP, one SIGKILLed
# mid-decode — 100% completion, streams bit-identical to an undisturbed run
test-fleet-socket:
	python -m pytest -x -q tests/test_fleet.py -k sock

bench-serving:
	PYTHONPATH=src python benchmarks/bench_serving.py

# CI smoke: tiny admission + kvtier + policy traces
bench-serving-smoke:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke

# scheduler bake-off only: fcfs/priority/sjf/drr on the capacity-constrained
# tiered trace, per-policy TTFT/latency percentiles
bench-serving-policy:
	PYTHONPATH=src python benchmarks/bench_serving.py --trace policy --smoke

# the MLA compressed-page tier: kvtier trace on the reduced
# deepseek-v2-lite-16b config (pages carry ckv+krope rows; must hit 100%
# completion bit-identical to the all-resident run)
bench-serving-kvtier-mla:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke \
		--arch deepseek-v2-lite-16b --trace kvtier

# overlapped decode loop vs the synchronous two-dispatch loop: 100%
# completion, bit-identical outputs, and the tentpole metric — jitted
# dispatches per decode step drop from 2 to 1 (reported per decoded token)
bench-serving-overlap:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke \
		--trace overlap

# prefix caching on a multi-turn chat trace: warm engines (flat, tiered,
# 2-replica session-affinity) vs a cold-cache run — 100% completion,
# outputs bit-identical to cold on every variant, >= 2x TTFT p50 collapse
# on hit turns; reports prefix-hit-rate, tokens reused, COW copies, and
# the hit-vs-miss TTFT split
bench-serving-prefix:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke \
		--trace prefix

# multi-replica Router trace: Poisson over 2 replicas (least-loaded +
# skewed-affinity routes, with cross-replica slot migration) vs 1
# double-size replica — 100% completion required on every variant, outputs
# bit-identical to the single-replica run, reports migration count + TTFT
# p99
bench-serving-router:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke \
		--trace router --replicas 2

# fleet failover trace: N workers behind the fleet transport, one killed
# once ~40% of the trace's tokens are out — 100% completion, every stream
# bit-identical to an undisturbed single-engine run; reports failover
# recovery latency and tokens replayed (--transport socket for real
# subprocess workers)
bench-serving-fleet:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke \
		--trace fleet --workers 2 --spares 1

# int8-KV trace: bf16 vs int8 page pools racing the capacity-constrained
# tiered trace (d_head bumped to 64 so the page ratio prices real head
# dims) — 100% completion on every variant, int8 tiered bit-identical to
# int8 resident, >= 1.8x fewer spill bytes; reports TTFT/tok-s deltas and
# reprices the traffic on the flash channel model
bench-serving-quant:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke \
		--trace quant

# sanitizer rails smoke: overlapped + tiered + prefix-cache decode under
# REPRO_SANITIZE=1 (shadow allocators, dispatch aliasing guard, retrace
# budget all armed) vs the identical plain engine — zero reports, rails
# demonstrably exercised, bit-identical tokens, < 2x wall
bench-serving-sanitize:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke \
		--trace sanitize
