# Convenience targets; CI runs the same commands (.github/workflows/ci.yml).

.PHONY: test test-fast test-slow bench-serving bench-serving-smoke

# full tier-1 (ROADMAP verify command)
test:
	PYTHONPATH=src python -m pytest -x -q

# fast tier: skips the interpret-mode Pallas kernel sweeps
test-fast:
	python -m pytest -q -m "not slow"

# nightly tier: only the slow interpret-mode kernel sweeps
test-slow:
	python -m pytest -q -m slow

bench-serving:
	PYTHONPATH=src python benchmarks/bench_serving.py

# CI smoke: tiny admission + kvtier traces
bench-serving-smoke:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke
