# Convenience targets; CI runs the same commands (.github/workflows/ci.yml).

.PHONY: test test-fast test-slow bench-serving bench-serving-smoke \
	bench-serving-policy

# full tier-1 (ROADMAP verify command)
test:
	PYTHONPATH=src python -m pytest -x -q

# fast tier: skips the interpret-mode Pallas kernel sweeps
test-fast:
	python -m pytest -q -m "not slow"

# nightly tier: only the slow interpret-mode kernel sweeps
test-slow:
	python -m pytest -q -m slow

bench-serving:
	PYTHONPATH=src python benchmarks/bench_serving.py

# CI smoke: tiny admission + kvtier + policy traces
bench-serving-smoke:
	PYTHONPATH=src python benchmarks/bench_serving.py --smoke

# scheduler bake-off only: fcfs/priority/sjf/drr on the capacity-constrained
# tiered trace, per-policy TTFT/latency percentiles
bench-serving-policy:
	PYTHONPATH=src python benchmarks/bench_serving.py --trace policy --smoke
