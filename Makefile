# Convenience targets; CI runs the same commands (.github/workflows/ci.yml).

.PHONY: test test-fast bench-serving

# full tier-1 (ROADMAP verify command)
test:
	PYTHONPATH=src python -m pytest -x -q

# fast tier: skips the interpret-mode Pallas kernel sweeps
test-fast:
	python -m pytest -q -m "not slow"

bench-serving:
	PYTHONPATH=src python benchmarks/bench_serving.py
