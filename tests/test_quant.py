"""Quant-layer conformance: round-trip properties, the per-column activation
scale fix, the w4a16 tile clamp, and path-predicate router exemption.

These are small/fast (no slow marker) so `make test-quant` rides tier-1;
the big interpret-mode tile sweeps stay in test_kernels.py under -m slow.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant.int4 import GROUP, dequantize4, int4_matvec, quantize_weight4
from repro.quant.int8 import (dequantize, int8_matvec, quantize_activation,
                              quantize_weight)

KEY = jax.random.PRNGKey(7)


# ------------------------------------------------------------ round trips
@pytest.mark.parametrize("h,w", [(8, 64), (5, 130), (16, GROUP - 2),
                                 (4, 2 * GROUP + 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_weight_round_trip(h, w, dtype):
    W = (jax.random.normal(jax.random.fold_in(KEY, h * w), (h, w))
         * 0.3).astype(dtype).astype(jnp.float32)
    q = quantize_weight(W)
    assert q.w_q.dtype == jnp.int8 and q.scale.shape == (h,)
    err = jnp.abs(dequantize(q.w_q, q.scale) - W)
    # symmetric rounding: reconstruction error <= half a quantization step
    assert bool(jnp.all(err <= q.scale[:, None] * 0.5 + 1e-7))


def test_int8_all_zero_rows_hit_scale_clamp():
    W = jnp.zeros((4, 32), jnp.float32).at[1].set(0.5)
    q = quantize_weight(W)
    zero_rows = np.array([0, 2, 3])
    np.testing.assert_allclose(np.asarray(q.scale)[zero_rows], 1e-8)
    deq = np.asarray(dequantize(q.w_q, q.scale))
    np.testing.assert_array_equal(deq[zero_rows], 0.0)
    np.testing.assert_allclose(np.asarray(deq[1]), np.asarray(W[1]),
                               atol=0.5 / 127 / 2 + 1e-7)


@pytest.mark.parametrize("h,w", [(8, GROUP), (6, GROUP - 2),
                                 (4, 2 * GROUP + 2), (3, 390)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int4_weight_round_trip(h, w, dtype):
    W = (jax.random.normal(jax.random.fold_in(KEY, h + w), (h, w))
         * 0.2).astype(dtype).astype(jnp.float32)
    q = quantize_weight4(W)
    deq = dequantize4(q)
    assert deq.shape == (h, w)
    g = min(GROUP, w)
    ng = -(-w // g)
    Wp = jnp.pad(W, ((0, 0), (0, ng * g - w))).reshape(h, ng, g)
    step = jnp.maximum(jnp.max(jnp.abs(Wp), axis=2) / 7.0, 1e-8)  # [h, ng]
    err = jnp.abs(deq - W)
    bound = jnp.repeat(step, g, axis=1)[:, :w] * 0.5 + 1e-7
    assert bool(jnp.all(err <= bound))


def test_int4_all_zero_rows_round_trip_to_zero():
    W = jnp.zeros((2, GROUP + 2), jnp.float32)
    q = quantize_weight4(W)
    np.testing.assert_array_equal(np.asarray(dequantize4(q)), 0.0)


def test_quantize_activation_per_column():
    x = jax.random.normal(KEY, (64, 5), jnp.float32)
    x = x.at[:, 2].multiply(100.0)  # outlier column
    x_q, x_scale = quantize_activation(x)
    assert x_scale.shape == (5,)
    # each column reconstructs within half its own step — the outlier
    # column does not degrade its batchmates
    err = jnp.abs(x_q.astype(jnp.float32) * x_scale[None, :] - x)
    assert bool(jnp.all(err <= x_scale[None, :] * 0.5 + 1e-7))
    # 1-D input keeps the scalar-scale contract
    xq1, s1 = quantize_activation(x[:, 0])
    assert s1.ndim == 0
    np.testing.assert_array_equal(np.asarray(xq1), np.asarray(x_q[:, 0]))


# ------------------------------------- the per-column outlier bugfix pin
def test_int8_matvec_outlier_batch_accuracy():
    """One 100x-outlier column must not crush the other columns' resolution:
    max-abs-error vs the f32 reference is pinned far below what the old
    per-tensor activation scale produced."""
    h, w, b = 96, 256, 8
    k1, k2 = jax.random.split(KEY)
    W = jax.random.normal(k1, (h, w), jnp.float32) * 0.1
    x = jax.random.normal(k2, (w, b), jnp.float32)
    x = x.at[:, 3].multiply(100.0)
    q = quantize_weight(W)
    y_ref = dequantize(q.w_q, q.scale) @ x  # weight-quant-only f32 reference

    y = int8_matvec(q, x)
    normal = [j for j in range(b) if j != 3]
    err_new = float(jnp.max(jnp.abs(y - y_ref)[:, normal]))

    # the old per-tensor path, reproduced inline as the baseline
    s_pt = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-8)
    xq_pt = jnp.clip(jnp.round(x / s_pt), -127, 127).astype(jnp.int8)
    acc = jax.lax.dot_general(q.w_q.astype(jnp.int32),
                              xq_pt.astype(jnp.int32),
                              (((1,), (0,)), ((), ())))
    y_pt = acc.astype(jnp.float32) * q.scale[:, None] * s_pt
    err_old = float(jnp.max(jnp.abs(y_pt - y_ref)[:, normal]))

    assert err_new < err_old / 10, (err_new, err_old)
    assert err_new < 0.15, err_new


def test_paged_int8_gemv_outlier_matches_ref_and_is_accurate():
    from repro.kernels.int8_pagegemv.ops import paged_int8_gemv
    from repro.kernels.int8_pagegemv.ref import paged_int8_gemv_ref

    h, w, b = 64, 256, 4
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 3))
    W = jax.random.normal(k1, (h, w), jnp.float32) * 0.1
    x = jax.random.normal(k2, (w, b), jnp.float32)
    x = x.at[:, 1].multiply(100.0)
    q = quantize_weight(W)
    y_k = paged_int8_gemv(q.w_q, q.scale, x)
    y_r = paged_int8_gemv_ref(q.w_q, q.scale, x)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))
    y_ref = dequantize(q.w_q, q.scale) @ x
    normal = [j for j in range(b) if j != 1]
    assert float(jnp.max(jnp.abs(y_k - y_ref)[:, normal])) < 0.15
    # kernel output equals int8_matvec bit-for-bit (same quant decisions)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(int8_matvec(q, x)),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------- w4a16 tile clamp bugfix
@pytest.mark.parametrize("w", [GROUP - 2, GROUP, 2 * GROUP + 2, 390])
def test_w4a16_gemv_tile_clamp_width_sweep(w):
    """Parity vs the dequantize oracle across the clamp's edge widths —
    including w == group, which the old subtract-then-max bounce padded 2x."""
    from repro.kernels.w4a16_gemv.ops import w4a16_gemv
    from repro.kernels.w4a16_gemv.ref import w4a16_gemv_ref

    h = 16
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, w))
    W = jax.random.normal(k1, (h, w), jnp.float32) * 0.1
    x = jax.random.normal(k2, (w, 3), jnp.float32)
    q = quantize_weight4(W)
    y_k = w4a16_gemv(q, x)
    y_r = w4a16_gemv_ref(q, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(int4_matvec(q, x)),
                               np.asarray(y_r), rtol=2e-5, atol=2e-5)


def test_w4a16_tile_width_no_inflation_at_group():
    """The clamp must not round w == group up to 2*group."""
    from repro.kernels.w4a16_gemv import ops as w4ops

    seen = {}
    orig = w4ops.w4a16_gemm

    def spy(wp, sc, xp, *, tile_h, tile_w, group, interpret):
        seen["tile_w"] = tile_w
        seen["padded_w"] = wp.shape[1] * 2
        return orig(wp, sc, xp, tile_h=tile_h, tile_w=tile_w, group=group,
                    interpret=interpret)

    q = quantize_weight4(jnp.ones((8, GROUP), jnp.float32))
    x = jnp.ones((GROUP,), jnp.float32)
    w4ops.w4a16_gemm, _ = spy, None
    try:
        w4ops.w4a16_gemv(q, x)
    finally:
        w4ops.w4a16_gemm = orig
    assert seen["tile_w"] == GROUP
    assert seen["padded_w"] == GROUP  # zero padding, not 2x


# --------------------------------------------- router path exemption
def _moe_tree():
    k = jax.random.PRNGKey(0)
    layer = lambda i: {
        "router": {"w": jax.random.normal(jax.random.fold_in(k, i),
                                          (16, 4), jnp.float32)},
        "up": {"w": jax.random.normal(jax.random.fold_in(k, 10 + i),
                                      (16, 32), jnp.float32)},
        "experts": jax.random.normal(jax.random.fold_in(k, 20 + i),
                                     (4, 16, 32), jnp.float32),
    }
    return {"embed": jax.random.normal(k, (8, 16), jnp.float32),
            "layers": [layer(0), layer(1)]}


def test_quantize_params_router_exempt_through_lists():
    from repro.quant.convert import quantize_params

    qp = quantize_params(_moe_tree())
    for lyr in qp["layers"]:
        # routers nested under the layer *list* keep their float weights
        assert "w" in lyr["router"] and "w_q" not in lyr["router"]
        # ordinary linears in the same layer are quantized
        assert "w_q" in lyr["up"] and lyr["up"]["w_q"].dtype == jnp.int8
        assert lyr["up"]["scale"].shape == (32,)
        # raw expert stacks pass through untouched
        assert lyr["experts"].dtype == jnp.float32
    assert qp["embed"].dtype == jnp.float32


def test_quantize_params_w4a16_mode_same_seam():
    from repro.quant.convert import quantize_params

    qp = quantize_params(_moe_tree(), mode="w4a16")
    for lyr in qp["layers"]:
        assert "w" in lyr["router"]
        assert "w_p4" in lyr["up"] and lyr["up"]["w_p4"].dtype == jnp.uint8
    with pytest.raises(ValueError):
        quantize_params(_moe_tree(), mode="w2a2")


def test_quantized_linear_dispatch_matches_float():
    from repro.models.layers import linear
    from repro.quant.convert import quantize_params

    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 9))
    p = {"w": jax.random.normal(k1, (64, 32), jnp.float32) * 0.2,
         "b": jnp.ones((32,), jnp.float32) * 0.1}
    x = jax.random.normal(k2, (4, 64), jnp.float32)
    x = x.at[0].multiply(50.0)  # outlier token
    y_f = linear(p, x)
    # w4a16's looser bound is the 4-bit weight error, not activation quant
    for mode, bound in (("w8a8", 0.25), ("w4a16", 1.0)):
        y_q = linear(quantize_params(p, mode=mode), x)
        assert y_q.shape == y_f.shape
        # per-token act quant keeps the non-outlier rows tight
        err = float(jnp.max(jnp.abs(y_q - y_f)[1:]))
        assert err < bound, (mode, err)
