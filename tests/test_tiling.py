"""§V hardware-aware tiling: closed forms, AM-GM optimality, plan invariants."""


try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import tiling
from repro.core.hw import (CAMBRICON_LLM_L, CAMBRICON_LLM_M, CAMBRICON_LLM_S,
                           FlashSpec)


def test_paper_optimal_tile_s_config():
    # Paper Fig. 13: optimal tile for Cambricon-LLM-S is 256 x 2048
    t = tiling.optimal_tile(CAMBRICON_LLM_S)
    assert (t.h, t.w) == (256, 2048)


def test_tile_invariant_all_configs():
    for f in (CAMBRICON_LLM_S, CAMBRICON_LLM_M, CAMBRICON_LLM_L):
        t = tiling.optimal_tile(f)
        assert t.h * t.w == f.channels * f.ccores_per_channel * f.page_bytes
        assert t.w % f.channels == 0


flash_strategy = st.builds(
    FlashSpec,
    channels=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
    chips_per_channel=st.sampled_from([1, 2, 4, 8]),
    dies_per_chip=st.sampled_from([1, 2]),
    page_bytes=st.sampled_from([4096, 8192, 16384]),
)


@given(flash_strategy)
@settings(max_examples=60, deadline=None)
def test_optimal_tile_beats_bruteforce(flash):
    """The closed-form tile minimizes Trans among power-of-two H choices."""
    t = tiling.optimal_tile(flash)
    total = flash.channels * flash.ccores_per_channel * flash.page_bytes
    best = tiling.channel_traffic_broadcast(t.h, t.w, flash.channels)
    h = 1
    while h <= total:
        w = total // h
        if w >= flash.channels and w % flash.channels == 0:
            tr = tiling.channel_traffic_broadcast(h, w, flash.channels)
            assert best <= tr + 1e-9, (h, w, tr, best, t)
        h *= 2


@given(flash_strategy)
@settings(max_examples=60, deadline=None)
def test_broadcast_scheme_never_worse(flash):
    """Paper §V-A: input-broadcast scheme (b) beats no-reuse scheme (c)."""
    t = tiling.optimal_tile(flash)
    tb = tiling.channel_traffic_broadcast(t.h, t.w, flash.channels)
    tc = tiling.channel_traffic_no_reuse(t.h, t.w, flash.channels,
                                         flash.ccores_per_channel)
    assert tb <= tc


@given(flash_strategy)
@settings(max_examples=60, deadline=None)
def test_alpha_in_unit_interval(flash):
    a = tiling.alpha_split(flash)
    ar = tiling.alpha_requests(flash)
    assert 0.0 < a < 1.0
    assert 0.0 < ar < 1.0


@given(flash_strategy,
       st.sampled_from([1024, 2048, 4096, 8192, 32000, 51865]),
       st.sampled_from([768, 2048, 4096, 12288]))
@settings(max_examples=60, deadline=None)
def test_plan_partition_exact(flash, h, w):
    """flash_rows + npu_rows == h; tiles cover the flash region."""
    p = tiling.plan_matrix(h, w, flash)
    assert p.flash_rows + p.npu_rows == h
    assert 0 <= p.alpha <= 1
    if p.flash_rows:
        assert p.n_tiles * p.tile.h >= p.flash_rows


def test_fitted_tile_never_exceeds_page():
    for flash in (CAMBRICON_LLM_S, CAMBRICON_LLM_M, CAMBRICON_LLM_L):
        for (h, w) in [(4096, 4096), (9216, 9216), (3352, 768), (1408, 2048)]:
            t = tiling.fit_tile(tiling.optimal_tile(flash), h, w, flash)
            atomic = (t.h / flash.ccores_per_channel) * (t.w / flash.channels)
            if t.h >= flash.ccores_per_channel and t.w >= flash.channels:
                assert atomic <= flash.page_bytes + 1e-9


def test_min_traffic_formula():
    for flash in (CAMBRICON_LLM_S, CAMBRICON_LLM_L):
        t = tiling.optimal_tile(flash)
        got = tiling.channel_traffic_broadcast(t.h, t.w, flash.channels)
        want = tiling.min_channel_traffic(flash)
        assert got <= want * 1.02  # integer rounding tolerance
