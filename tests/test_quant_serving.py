"""int8 KV pages through the paged serving path.

The quantized-pool contract: a page's bits are written ONCE (rows
quantize at the prefill scatter / decode append with a per-row scale)
and only ever relocated afterwards — so an int8 engine's decode streams
must be bit-identical to THEMSELVES across every path that moves pages:

* sync vs overlapped decode loops,
* tiered spill/prefetch through the flash tier,
* slot migration (snapshot -> wire bytes -> inject),
* fleet failover (worker killed mid-decode, checkpoint replay),
* prefix-cache resume hits (exact-prompt replay of stored bits).

Accuracy rides separately: greedy streams on margin-checked prompts
match the bf16 reference, and decode logits stay within quantization
tolerance of it.  Capacity is the payoff: an int8 page spills
1B/elem + 4B per-row f32 scales instead of 2B/elem, priced identically
by the engine's ``kv_page_bytes`` and the channel sim
(``family_kv_page_bytes``) — >= 1.8x fewer spill bytes at real head
dims (2*Dh/(Dh+4), so Dh >= 36).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED_ARCHS
from repro.models import model as M
from repro.serving.core import EngineCore, Request, SlotSnapshot
from repro.serving.scheduler import SamplingParams

KEY = jax.random.PRNGKey(0)
ENG_KW = dict(max_batch=2, max_seq=48, eos_id=-1, page_size=8)


@pytest.fixture(scope="module")
def smollm():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    return cfg, params


def _reqs(n, max_new=8, stochastic=False):
    out = []
    for rid in range(n):
        sp = None
        if stochastic and rid % 2 == 1:
            sp = SamplingParams(temperature=0.9, top_k=20, seed=100 + rid)
        out.append(Request(rid=rid, prompt=[3 + rid, 11, 7, 19, 2 + rid],
                           max_new_tokens=max_new, sampling=sp))
    return out


def _run(cfg, params, reqs, **kw):
    eng = EngineCore(cfg, params, **{**ENG_KW, **kw})
    for r in reqs:
        eng.add_request(r)
    eng.run()
    assert all(r.done and not r.rejected for r in reqs)
    return {r.rid: list(r.out_tokens) for r in reqs}, eng


# ------------------------------------------------- cross-path bit-identity
def test_int8_kv_bit_identical_across_paths(fam):
    """Per family: the int8 engine's streams survive the overlapped loop
    and tiered spill/prefetch bit for bit (pages relocate, bits don't)."""
    family, cfg, params = fam
    sync, _ = _run(cfg, params, _reqs(3, stochastic=True), kv_dtype="int8")
    olap, _ = _run(cfg, params, _reqs(3, stochastic=True), kv_dtype="int8",
                   overlap=True)
    assert olap == sync, f"{family}: overlap diverged under int8 KV"
    # hot pool below two requests' concurrent footprint (2 pages each
    # incl. the null page), so admission pressure forces spills
    tiered, eng = _run(cfg, params, _reqs(3, stochastic=True),
                       kv_dtype="int8", kv_tier="flash", num_pages=4)
    assert tiered == sync, f"{family}: tiered spill diverged under int8 KV"
    assert eng.stats.kv_spill_pages > 0, "tier never exercised"


def test_int8_kv_matches_bf16_greedy(fam):
    """Greedy streams match the bf16 reference on the reduced config.

    The prompts are margin-checked: KV quantization drifts decode logits
    by ~5e-3 on these random-init weights, so arbitrary prompts can flip
    argmax near-ties without any real error — this seed was verified to
    keep the bf16 top-1 margin above the drift for every family (the
    logits-tolerance pin below bounds the drift itself)."""
    family, cfg, params = fam
    rng = np.random.RandomState(5)
    reqs = lambda: [Request(rid=r, prompt=p, max_new_tokens=8)
                    for r, p in enumerate(prompts)]
    prompts = [rng.randint(0, cfg.vocab_size, size=5).tolist()
               for _ in range(3)]
    ref, _ = _run(cfg, params, reqs())
    i8, _ = _run(cfg, params, reqs(), kv_dtype="int8")
    assert i8 == ref, f"{family}: int8 KV flipped a greedy stream"


def test_int8_kv_decode_logits_close_to_bf16(smollm):
    """Model-level tolerance pin: one decode step over an int8-paged
    cache stays within quantization-sized error of the bf16 cache."""
    cfg, params = smollm
    toks = jnp.array([[5, 9, 14, 3, 11, 7, 2, 6]], jnp.int32)
    tls = jnp.array([8], jnp.int32)
    logits = {}
    for kd in ("bf16", "int8"):
        cache = M.init_paged_cache(cfg, 1, 64, page_size=8, kv_dtype=kd)
        lg, cache = M.prefill_into_slots(params, cfg, toks, tls, cache,
                                         jnp.array([0], jnp.int32))
        step, cache = M.decode_step_paged(
            params, cfg, jnp.argmax(lg, -1).astype(jnp.int32), cache,
            jnp.array([True]))
        logits[kd] = np.asarray(step, np.float32)
    drift = np.abs(logits["int8"] - logits["bf16"]).max()
    assert drift < 0.05, f"decode logits drifted {drift} from bf16"


# ------------------------------------------------------------- migration
def test_int8_kv_migration_bit_identical(smollm):
    """Snapshot mid-decode, round-trip the wire bytes (dtype guard set to
    int8), inject into a second engine: the merged stream equals the
    unmigrated run — quantized pages and their scale payloads move as
    one opaque tuple."""
    cfg, params = smollm
    ref, _ = _run(cfg, params, _reqs(2, stochastic=True), kv_dtype="int8")
    src = EngineCore(cfg, params, kv_dtype="int8", **ENG_KW)
    dst = EngineCore(cfg, params, kv_dtype="int8", **ENG_KW)
    reqs = _reqs(2, stochastic=True)
    for r in reqs:
        src.add_request(r)
    for _ in range(3):
        src._advance()
    snap = src.snapshot_slot(0)
    assert len(snap.pages[0]) == 4  # (k, v, k_scale, v_scale)
    assert snap.pages[0][0].dtype == np.int8
    blob = snap.to_bytes()
    with pytest.raises(ValueError):
        SlotSnapshot.from_bytes(blob, expect_dtype="bfloat16")
    snap2 = SlotSnapshot.from_bytes(blob, expect_dtype="int8")
    dst.inject_slot(snap2)   # the wire copy owns the migrated request now
    src.run()
    dst.run()
    assert snap2.req.done and reqs[1].done
    assert list(snap2.req.out_tokens) == ref[0]
    assert list(reqs[1].out_tokens) == ref[1]


def test_int8_kv_fleet_failover_bit_identical(smollm):
    """Kill one of two loopback workers mid-decode: every recovered
    stream (greedy and seed-pinned stochastic) replays bit-identical —
    the checkpoint wire format carries the scale payloads."""
    from repro.serving.fleet.router import FleetRouter

    cfg, params = smollm
    ref, _ = _run(cfg, params, _reqs(4, stochastic=True), kv_dtype="int8")
    fl = FleetRouter.build_loopback(cfg, params, workers=2, spares=1,
                                    checkpoint_every=3, kv_dtype="int8",
                                    **ENG_KW)
    reqs = _reqs(4, stochastic=True)
    for r in reqs:
        fl.submit(r)
    steps, killed = 0, False
    while fl.has_work and steps < 500:
        fl.step()
        steps += 1
        if not killed and steps == 5:
            fl.workers[0].transport.kill()
            killed = True
    assert all(r.done for r in reqs), \
        f"lost: {[r.rid for r in reqs if not r.done]}"
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert fl.fleet.workers_lost == 1 and fl.fleet.failovers == 1
    fl.close()


# ----------------------------------------------------------- prefix cache
def test_int8_kv_prefix_resume_hit_partial_gated(smollm):
    """Resume hits (exact-prompt replay of stored bits) still fire under
    int8 pools; partial hits are gated off — the chunked suffix replay
    only agrees with a fresh prefill to quantization precision, which
    would break the sharing contract."""
    cfg, params = smollm
    base = [5, 9, 14, 3, 11, 7, 2, 6]          # one full page
    runs = {}
    for name, kw in (("cold", dict(kv_dtype="int8")),
                     ("warm", dict(kv_dtype="int8", prefix_cache=True)),
                     ("bf16", dict(prefix_cache=True))):
        eng = EngineCore(cfg, params, **{**ENG_KW, **kw})
        outs = {}
        for rid, prompt in ((0, base), (1, base),          # exact repeat
                            (2, base + [4, 13, 8])):       # page-run superset
            r = Request(rid=rid, prompt=list(prompt), max_new_tokens=6)
            eng.add_request(r)
            eng.run()
            assert r.done and not r.rejected
            outs[rid] = list(r.out_tokens)
        runs[name] = (outs, eng.stats)
    assert runs["warm"][0] == runs["cold"][0]
    # the exact repeat resumed, but the superset prompt — a partial page
    # hit under bf16 pools — took the full-prefill path under int8
    assert runs["warm"][1].prefix_hits == 1
    assert runs["bf16"][1].prefix_hits == 2


# ------------------------------------------------------------- capacity
def test_int8_kv_page_bytes_and_spill_ratio(smollm):
    """The engine prices an int8 page at 1B/elem + 4B per-row scales; at
    Dh=64 that is 2*64/(64+4) = 1.88x under the bf16 page, and the spill
    byte counters shrink by the same factor on an identical trace."""
    cfg, params = smollm
    assert M.kv_page_bytes(cfg, 8, jnp.int8) < M.kv_page_bytes(cfg, 8)
    qcfg = dataclasses.replace(cfg, name=cfg.name + "-qkv", d_head=64)
    ratio = M.kv_page_bytes(qcfg, 8) / M.kv_page_bytes(qcfg, 8, jnp.int8)
    assert ratio >= 1.8, f"page ratio only x{ratio:.2f} at d_head=64"
    from repro.sim.llm_perf import family_kv_page_bytes
    assert family_kv_page_bytes(qcfg, 8, kv_dtype="int8") == \
        M.kv_page_bytes(qcfg, 8, jnp.int8)
    qparams = M.init_params(qcfg, KEY, max_seq=64)
    spilled = {}
    for kd in ("bf16", "int8"):
        outs, eng = _run(qcfg, qparams, _reqs(3), kv_dtype=kd,
                         kv_tier="flash", num_pages=4)
        spilled[kd] = (eng.stats.kv_spill_pages, eng.stats.kv_spill_bytes)
    assert spilled["int8"][0] == spilled["bf16"][0] > 0  # same page traffic
    byte_ratio = spilled["bf16"][1] / spilled["int8"][1]
    assert byte_ratio >= 1.8, f"spill bytes shrank only x{byte_ratio:.2f}"


def test_int8_kv_rejects_wave_mode(smollm):
    cfg, params = smollm
    with pytest.raises(ValueError, match="continuous"):
        EngineCore(cfg, params, mode="wave", kv_dtype="int8", **ENG_KW)
    with pytest.raises(ValueError, match="kv_dtype"):
        EngineCore(cfg, params, kv_dtype="fp4", **ENG_KW)
