"""Tiered flash-resident KV cache: allocator, swap ops, engine, sim pricing.

The load-bearing check is bit-identity: spilling a slot's pages to the flash
tier and prefetching them back (onto DIFFERENT hot pids, with the block table
remapped) must leave every subsequent decode logit exactly equal to the
all-resident run — the tier relocates pages, it never approximates.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED_ARCHS
from repro.core.hw import CAMBRICON_LLM_S
from repro.core.schedule import ChannelWorkload, Policy
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import OutOfPages, TieredPageAllocator
from repro.sim.engine import (NpuPhase, RCBlock, simulate_channel,
                              simulate_stream)
from repro.sim.llm_perf import decode_token_time, kv_page_cost_s

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    return cfg, params


# ------------------------------------------------------------ allocator
def test_tiered_allocator_lru_and_counters():
    a = TieredPageAllocator(8)  # 7 usable hot pages
    pids = a.alloc(4)
    # LRU order: insertion order, oldest popped first
    a.mark_evictable(("s0", 0), pids[0])
    a.mark_evictable(("s0", 1), pids[1])
    a.mark_evictable(("s1", 0), pids[2])
    got = a.pop_evictable(2)
    assert [k for k, _ in got] == [("s0", 0), ("s0", 1)]
    assert [p for _, p in got] == pids[:2]
    # exclusion shields a slot's own pages
    got2 = a.pop_evictable(5, exclude=lambda k: k[0] == "s1")
    assert got2 == []
    for key, pid in got:
        a.store(key, "payload-" + str(pid))
        a.free([pid])
    assert a.cold_count == 2
    assert a.fetch(("s0", 0)) == "payload-" + str(pids[0])
    assert a.cold_count == 1
    assert a.cold_keys(lambda k: k[0] == "s0") == [("s0", 1)]
    a.drop_slot(lambda k: k[0] == "s0")
    assert a.cold_count == 0 and a.evictable_count == 1
    a.unmark_slot(lambda k: k[0] == "s1")
    assert a.evictable_count == 0


def test_tiered_allocator_flash_capacity_and_guards():
    a = TieredPageAllocator(6, flash_pages=1)
    p = a.alloc(2)
    assert a.flash_available == 1
    a.store(("s", 0), b"x")
    assert a.flash_available == 0
    with pytest.raises(OutOfPages):
        a.store(("s", 1), b"y")  # cold tier full
    with pytest.raises(ValueError):
        a.store(("s", 0), b"z")  # already cold
    a.mark_evictable(("t", 0), p[0])
    with pytest.raises(ValueError):
        a.mark_evictable(("t", 0), p[0])
    assert TieredPageAllocator(6).flash_available is None


def test_tiered_allocator_invariants_property():
    """Residency invariants under random alloc/spill/prefetch/free
    sequences (hypothesis when available, the vendored fallback otherwise):
    no page key is simultaneously hot-evictable and cold, residency
    counters always match the mirrored block table, and ``free`` never
    accepts a double-free."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

    @given(st.integers(4, 24), st.lists(st.integers(0, 24), max_size=60))
    @settings(max_examples=40, deadline=None)
    def check(num_pages, ops):
        a = TieredPageAllocator(num_pages)
        hot: dict = {}        # key -> pid (the engine's block-table mirror)
        evictable: set = set()
        cold: set = set()
        next_key = 0
        for op in ops:
            r = op % 5
            if r == 0:  # alloc a page for a fresh key
                if a.available >= 1:
                    pid = a.alloc(1)[0]
                    assert pid != 0
                    assert pid not in hot.values()  # no double hand-out
                    hot[next_key] = pid
                    next_key += 1
                else:
                    with pytest.raises(OutOfPages):
                        a.alloc(1)
            elif r == 1:  # mark one resident page evictable
                cands = [k for k in hot if k not in evictable]
                if cands:
                    k = cands[op % len(cands)]
                    a.mark_evictable(k, hot[k])
                    evictable.add(k)
                    with pytest.raises(ValueError):
                        a.mark_evictable(k, hot[k])  # already queued
            elif r == 2:  # spill the LRU candidate (store + free the pid)
                got = a.pop_evictable(1)
                assert len(got) <= 1
                for k, pid in got:
                    assert k in evictable and hot[k] == pid
                    a.store(k, ("payload", pid))
                    a.free([pid])
                    evictable.discard(k)
                    del hot[k]
                    cold.add(k)
                    with pytest.raises(ValueError):
                        a.free([pid])  # double-free must raise
            elif r == 3:  # prefetch one cold page back hot (new pid)
                if cold and a.available >= 1:
                    k = sorted(cold)[op % len(cold)]
                    payload = a.fetch(k)
                    assert payload[0] == "payload"
                    pid = a.alloc(1)[0]
                    cold.discard(k)
                    hot[k] = pid
            else:  # free a hot page outright (slot finished)
                cands = [k for k in hot if k not in evictable]
                if cands:
                    k = cands[op % len(cands)]
                    a.free([hot.pop(k)])
            # --- invariants, every step ---
            assert not (evictable & cold)  # never hot-evictable AND cold
            assert a.cold_count == len(cold)
            assert a.evictable_count == len(evictable)
            # hot residency conservation against the block-table mirror
            assert a.available + len(hot) == num_pages - 1
        # drain: everything recycles, nothing leaked
        for k in list(hot):
            if k in evictable:
                a.unmark_slot(lambda key, k=k: key == k)
            a.free([hot.pop(k)])
        a.drop_slot(lambda key: True)
        assert a.available == num_pages - 1
        assert a.cold_count == 0 and a.evictable_count == 0

    check()


def test_tiered_refcount_shared_cold_property():
    """Prefix-sharing invariants under random alloc/incref/decref/free/
    spill/prefetch sequences: refcounts track the model exactly, a page
    with sharers never frees (the guard raises), only refcount-0 pages ever
    spill, and NO page is simultaneously free, shared, and cold — the
    satellite property of the prefix-cache PR."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

    @given(st.integers(4, 24), st.lists(st.integers(0, 29), max_size=60))
    @settings(max_examples=40, deadline=None)
    def check(num_pages, ops):
        a = TieredPageAllocator(num_pages)
        refs: dict = {}       # key -> model refcount (hot pages only)
        hot: dict = {}        # key -> pid
        cold: set = set()
        next_key = 0
        for op in ops:
            r = op % 6
            if r == 0:  # alloc: refcount 1 by contract
                if a.available >= 1:
                    hot[next_key] = a.alloc(1)[0]
                    refs[next_key] = 1
                    next_key += 1
            elif r == 1:  # incref a hot page (a slot maps the shared page)
                if hot:
                    k = sorted(hot)[op % len(hot)]
                    assert a.incref(hot[k]) == refs[k] + 1
                    refs[k] += 1
            elif r == 2:  # decref (slot released; 0 = idle cached)
                cands = [k for k in hot if refs[k] > 0]
                if cands:
                    k = cands[op % len(cands)]
                    assert a.decref(hot[k]) == refs[k] - 1
                    refs[k] -= 1
                elif hot:  # every refcount is 0: below-zero must raise
                    k = sorted(hot)[op % len(hot)]
                    with pytest.raises(ValueError):
                        a.decref(hot[k])
            elif r == 3:  # free: legal at refcount <= 1, a guard above
                if hot:
                    k = sorted(hot)[op % len(hot)]
                    if refs[k] > 1:
                        with pytest.raises(ValueError):
                            a.free([hot[k]])  # sharers remain: must raise
                    else:
                        a.free([hot.pop(k)])
                        del refs[k]
            elif r == 4:  # spill: ONLY idle (refcount-0) pages may go cold
                cands = [k for k in hot if refs[k] == 0]
                if cands:
                    k = cands[op % len(cands)]
                    a.store(("px", k), ("payload", k))
                    a.free([hot.pop(k)])
                    del refs[k]
                    cold.add(k)
            else:  # prefetch a cold page back hot (idle until increfed)
                if cold and a.available >= 1:
                    k = sorted(cold)[op % len(cold)]
                    assert a.fetch(("px", k)) == ("payload", k)
                    cold.discard(k)
                    hot[k] = a.alloc(1)[0]
                    refs[k] = 1
                    a.decref(hot[k])  # the engine's acquire-then-park dance
                    refs[k] = 0
            # --- invariants, every step ---
            for k, pid in hot.items():
                assert a.refcount(pid) == refs[k]
            shared = {k for k in hot if refs[k] > 0}
            # no page is simultaneously free, shared, and cold: hot pids
            # are allocated (refcount() did not raise above), shared keys
            # are hot by construction, and the two stores never overlap
            assert not (shared & cold)
            assert not ({("px", k) for k in hot} & set(a._cold))
            assert a.available + len(hot) == num_pages - 1
            assert a.cold_count == len(cold)
        for k in list(hot):  # drain: shared pages decref first, then free
            while refs[k] > 1:
                refs[k] = a.decref(hot[k])
            a.free([hot.pop(k)])
        a.drop_slot(lambda key: True)
        assert a.available == num_pages - 1 and a.cold_count == 0

    check()


# ------------------------------------------------------------ model layer
def test_swap_roundtrip_decode_bit_identical(smollm):
    """Decode logits after spilling a slot's pages and prefetching them back
    onto different pids (block table remapped, original pages ZEROED to
    prove the data really came back from the host blobs) are bit-identical
    to the all-resident run."""
    cfg, _ = smollm
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    toks = jax.random.randint(KEY, (1, 7), 0, cfg.vocab_size)
    # pool holds 2 slots x 4 pages + null: pids 1..4 vs 5..8 ping-pong
    pc0 = M.init_paged_cache(cfg, 2, 32, dtype=jnp.float32, page_size=8)
    pps = pc0["block"].shape[1]
    pc0["block"] = pc0["block"].at[0, :].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    padded = jnp.pad(toks, ((0, 0), (0, 9)))
    lg, pc0 = M.prefill_into_slot(params, cfg, padded, jnp.int32(7), pc0,
                                  jnp.int32(0), {})

    def decode_n(pc, block, n, swap_each_step):
        logits = []
        tokb = jnp.zeros((2,), jnp.int32).at[0].set(int(jnp.argmax(lg)))
        active = jnp.array([True, False])
        pids = list(range(1, pps + 1))
        for step in range(n):
            if swap_each_step:
                alt = [p + pps for p in pids] if pids[0] <= pps \
                    else [p - pps for p in pids]
                ks, vs = M.swap_out_pages(pc, jnp.asarray(pids, jnp.int32))
                # round-trip through host numpy, zero the source pages
                ks, vs = np.asarray(ks), np.asarray(vs)
                pc = {**pc,
                      "k": pc["k"].at[:, jnp.asarray(pids)].set(0),
                      "v": pc["v"].at[:, jnp.asarray(pids)].set(0)}
                pc = M.swap_in_pages(pc, jnp.asarray(alt, jnp.int32), ks, vs)
                pids = alt
                block = block.at[0, :].set(
                    jnp.asarray(pids, jnp.int32))
            out, pc = M.decode_step_paged(
                params, cfg, tokb, {**pc, "block": block}, active)
            pc.pop("block")
            logits.append(np.asarray(out[0]))
            tokb = tokb.at[0].set(int(jnp.argmax(out[0])))
        return logits

    base_block = jnp.zeros((2, pps), jnp.int32).at[0, :].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    ref = decode_n(dict(pc0), base_block, 5, swap_each_step=False)
    got = decode_n(dict(pc0), base_block, 5, swap_each_step=True)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


def test_kv_page_bytes(smollm):
    cfg, _ = smollm
    b = M.kv_page_bytes(cfg, 8, jnp.float32)
    assert b == 2 * cfg.n_layers * 8 * cfg.n_kv_heads * cfg.d_head * 4


def test_kv_page_bytes_per_family():
    """Tier pricing must follow the family's actual page row: compressed
    ckv+krope for MLA (NOT 2*L*Hkv*Dh), shared-attn groups only for hybrid."""
    from repro.configs.registry import ASSIGNED_ARCHS as A
    from repro.serving.kv_cache import kv_page_elems

    mla = A["deepseek-v2-lite-16b"].reduced()
    b = M.kv_page_bytes(mla, 8, jnp.float32)
    assert b == mla.n_layers * 8 * (mla.kv_lora_rank + mla.qk_rope_dim) * 4
    # the compressed page is strictly cheaper than a full-K/V page would be
    assert b < 2 * mla.n_layers * 8 * mla.n_kv_heads * mla.d_head * 4

    hyb = A["zamba2-7b"].reduced()
    n_groups = hyb.n_layers // hyb.shared_attn_every
    assert M.kv_page_bytes(hyb, 8, jnp.float32) == \
        2 * n_groups * 8 * hyb.n_kv_heads * hyb.d_head * 4
    # kv_page_elems is the single source of truth both derive from
    for cfg in (mla, hyb):
        assert M.kv_page_bytes(cfg, 8, jnp.float32) == \
            kv_page_elems(cfg, 8) * 4
    with pytest.raises(ValueError):
        kv_page_elems(A["mamba2-130m"].reduced(), 8)


# ------------------------------------------------------------------ engine
def _mk_reqs(n):
    return [Request(rid=i, prompt=[2 + i] * (3 + i), max_new_tokens=12 + 2 * i)
            for i in range(n)]


def _run(cfg, params, reqs, **kw):
    eng = ServingEngine(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                        page_size=8, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng


def test_tiered_engine_outputs_match_all_resident(fam):
    """Conformance (every paged family): with the hot pool sized below
    demand, the tiered engine completes every request with out_tokens
    identical to the unconstrained run, having actually spilled and
    prefetched pages — preempt-resume is bit-identical whether the pages
    carry full K/V, compressed ckv+krope, or shared-attn KV beside a
    masked+checkpointed Mamba state pool."""
    family, cfg, params = fam
    base = _mk_reqs(5)
    _run(cfg, params, base)
    tiered = _mk_reqs(5)
    eng = _run(cfg, params, tiered, num_pages=6, kv_tier="flash")
    assert all(r.done and not r.rejected for r in tiered)
    for a, b in zip(base, tiered):
        assert a.out_tokens == b.out_tokens
    s = eng.stats
    assert s.preemptions > 0 and s.resumes > 0
    assert s.kv_spill_pages > 0
    assert s.kv_prefetch_pages == s.kv_spill_pages  # every page came back
    assert s.kv_spill_bytes == s.kv_spill_pages * eng.kv_page_bytes
    # no leaks: pool fully recycled, flash tier drained, nothing suspended
    assert eng.allocator.available == 5
    assert eng.allocator.cold_count == 0 and eng.allocator.evictable_count == 0
    assert not any(eng.suspended) and eng.resume_order == []
    if family == "hybrid":
        assert eng._ssm_ckpt == {}  # every checkpoint consumed or dropped


def test_hybrid_ssm_checkpoint_restores_scribbled_state():
    """The state-pool seam: a suspended hybrid slot's Mamba state is
    checkpointed host-side, and restore brings the slot's rows back
    bit-identical even if the pool was deliberately scribbled meanwhile."""
    from repro.configs.registry import ASSIGNED_ARCHS as A
    cfg = A["zamba2-7b"].reduced()
    cache = M.init_paged_cache(cfg, 2, 32, dtype=jnp.float32, page_size=8)
    key = jax.random.PRNGKey(3)
    cache["mamba"] = jax.tree.map(
        lambda a: jax.random.normal(key, a.shape, a.dtype), cache["mamba"])
    if cache.get("tail") is not None:
        cache["tail"] = jax.tree.map(
            lambda a: jax.random.normal(key, a.shape, a.dtype), cache["tail"])
    before = jax.tree.map(lambda a: np.asarray(a[:, :, 1]), cache["mamba"])
    ckpt = M.checkpoint_slot_state(cache, 1)
    scribbled = {**cache,
                 "mamba": jax.tree.map(lambda a: a * 0 - 7.0, cache["mamba"])}
    restored = M.restore_slot_state(scribbled, 1, ckpt)
    after = jax.tree.map(lambda a: np.asarray(a[:, :, 1]), restored["mamba"])
    jax.tree.map(np.testing.assert_array_equal, before, after)
    # the other slot's (scribbled) rows are untouched by the restore
    other = jax.tree.map(lambda a: np.asarray(a[:, :, 0]), restored["mamba"])
    jax.tree.map(lambda a: np.testing.assert_array_equal(a, a * 0 - 7.0),
                 other)
    # non-recurrent families have no state to checkpoint
    dcfg = A["smollm-360m"].reduced()
    dcache = M.init_paged_cache(dcfg, 2, 32, page_size=8)
    assert M.checkpoint_slot_state(dcache, 0) is None
    assert M.restore_slot_state(dcache, 0, None) is dcache


def test_tiered_engine_bounded_flash_tier(smollm):
    """A bounded cold tier must degrade gracefully, not crash or leak hot
    pids: spills cap at the tier size, the rest of the pressure falls back
    to the requeue path, and every page is recycled at the end."""
    cfg, params = smollm
    base = _mk_reqs(5)
    _run(cfg, params, base)
    reqs = _mk_reqs(5)
    eng = _run(cfg, params, reqs, num_pages=6, kv_tier="flash",
               flash_pages=2)
    assert all(r.done and not r.rejected for r in reqs)
    for a, b in zip(base, reqs):
        assert a.out_tokens == b.out_tokens
    assert eng.allocator.available == 5  # no leaked hot pids
    assert eng.allocator.cold_count == 0


def test_requeue_policy_survives_exhaustion(smollm):
    """Satellite: OutOfPages during admission/growth must not crash the
    loop — requests requeue (restart) and the counter records the events."""
    cfg, params = smollm
    reqs = _mk_reqs(5)
    eng = _run(cfg, params, reqs, num_pages=6)  # 5 usable hot pages
    assert all(r.done and not r.rejected for r in reqs)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    assert eng.stats.pool_exhausted > 0
    assert eng.allocator.available == 5


def test_reject_policy_counts_rejections(smollm):
    cfg, params = smollm
    reqs = _mk_reqs(5)
    eng = _run(cfg, params, reqs, num_pages=6, exhaust_policy="reject")
    assert all(r.done for r in reqs)
    assert eng.stats.rejected > 0
    assert eng.stats.rejected == sum(1 for r in reqs if r.rejected)
    assert eng.stats.completed == sum(1 for r in reqs if not r.rejected)


def test_submit_rejects_request_larger_than_hot_pool(smollm):
    cfg, params = smollm
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                        page_size=8, num_pages=3, kv_tier="flash")
    with pytest.raises(ValueError):  # needs 3 pages, pool has 2
        eng.submit(Request(rid=0, prompt=[1] * 10, max_new_tokens=12))


def test_kv_tier_requires_continuous():
    cfg = ASSIGNED_ARCHS["mamba2-130m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, max_batch=2, max_seq=32, mode="wave",
                      kv_tier="flash")


# ---------------------------------------------------------------- simulator
def test_channel_write_requests_accounted():
    w = ChannelWorkload(n_tiles=10, rc_input_bytes=256, rc_result_bytes=256,
                        n_reads=4, page_bytes=16384, t_r=30e-6, bw=1e9)
    w_wr = dataclasses.replace(w, n_writes=4)
    for pol in (Policy.RC_SLICED, Policy.RC_UNSLICED):
        r0, r1 = simulate_channel(w, pol), simulate_channel(w_wr, pol)
        assert r1.time >= r0.time - 1e-12
        assert r1.writes_done > 0
        # conservation: the write bytes crossed the bus exactly once
        assert abs((r1.bus_busy - r0.bus_busy) - w_wr.write_bus_bytes / w.bw) \
            < 1e-9
    # RC_ONLY drops plain traffic entirely (Fig. 6a)
    r = simulate_channel(w_wr, Policy.RC_ONLY)
    assert r.writes_done == 0.0


def test_channel_sliced_writes_ride_bubbles_free():
    """The paper's point applied to KV spill: bubble headroom absorbs sliced
    write traffic at zero completion-time cost, while unsliced whole-page
    writes block the read-compute pipeline."""
    w = ChannelWorkload(n_tiles=10, rc_input_bytes=256, rc_result_bytes=256,
                        n_reads=0, page_bytes=16384, t_r=30e-6, bw=1e9,
                        n_writes=4)
    base = simulate_channel(dataclasses.replace(w, n_writes=0),
                            Policy.RC_SLICED)
    sliced = simulate_channel(w, Policy.RC_SLICED)
    unsliced = simulate_channel(w, Policy.RC_UNSLICED)
    assert sliced.time == pytest.approx(base.time)  # absorbed by bubbles
    assert unsliced.time > sliced.time


def _stream():
    blk = RCBlock(n_tiles=6, rc_input_bytes=256.0, rc_result_bytes=256.0,
                  read_bytes=8192.0, t_r=30e-6, bw=1e9)
    return [blk, NpuPhase(2e-4), blk, NpuPhase(2e-4), blk]


def test_stream_kv_traffic_monotone_and_conserved():
    base = simulate_stream(_stream(), Policy.RC_SLICED)
    prev = base.time
    for kv in (0.0, 16384.0, 262144.0, 4e6):
        res = simulate_stream(_stream(), Policy.RC_SLICED,
                              kv_write_bytes=kv, kv_read_bytes=kv)
        if kv == 0.0:
            assert res.time == base.time and res.kv_bus_s == 0.0
        else:
            assert res.kv_done > 0
            # kv traffic crosses the bus in whole slices, exactly once
            slices = -(-int(2 * kv) // 2048)
            assert res.kv_bus_s == pytest.approx(slices * 2048 / 1e9)
            assert res.bus_busy == pytest.approx(base.bus_busy + res.kv_bus_s)
        assert res.time >= prev - 1e-12
        prev = res.time
        assert res.time >= res.kv_done - 1e-12
        assert 0.0 <= res.util <= 1.0 + 1e-9


def test_stream_kv_traffic_follows_policy():
    """Policy consistency with simulate_channel: RC_ONLY drops KV tier
    traffic entirely, RC_UNSLICED moves it in whole pages."""
    rc_only = simulate_stream(_stream(), Policy.RC_ONLY,
                              kv_write_bytes=1e6, kv_read_bytes=1e6)
    assert rc_only.kv_bus_s == 0.0 and rc_only.kv_done == 0.0
    unsliced = simulate_stream(_stream(), Policy.RC_UNSLICED,
                               kv_write_bytes=16384.0, kv_page_bytes=16384.0)
    assert unsliced.kv_bus_s == pytest.approx(16384.0 / 1e9)


def test_token_time_kv_tier_pricing():
    from repro.configs.registry import ARCHS
    cfg = ARCHS["opt-6.7b"]
    base = decode_token_time(cfg, CAMBRICON_LLM_S)
    kv = decode_token_time(cfg, CAMBRICON_LLM_S,
                           kv_spill_bytes=2e6, kv_prefetch_bytes=2e6)
    assert kv.total >= base.total
    assert kv.kv_bus_s > 0 and kv.kv_tier_bytes == 4e6
    assert base.kv_tier_bytes == 0.0
    # one small page of spill+prefetch rides the bubbles ~free; the cost
    # function is monotone in traffic either way
    c1 = kv_page_cost_s(cfg, CAMBRICON_LLM_S, 256 * 1024.0)
    assert c1 >= 0.0
