"""Property tests for the whole-model stream simulator (hypothesis)."""

from hypothesis import given, settings, strategies as st

from repro.core.schedule import Policy
from repro.sim.engine import NpuPhase, RCBlock, simulate_stream


def _block(n_tiles, read_bytes, bw=1e9):
    return RCBlock(n_tiles=n_tiles, rc_input_bytes=256.0,
                   rc_result_bytes=256.0, read_bytes=float(read_bytes),
                   t_r=30e-6, bw=bw, page_bytes=16384.0)


streams = st.lists(
    st.one_of(
        st.builds(_block, st.integers(1, 12),
                  st.sampled_from([0, 8192, 65536, 262144])),
        st.builds(NpuPhase, st.floats(1e-6, 5e-4)),
    ),
    min_size=1, max_size=12)


@given(streams)
@settings(max_examples=60, deadline=None)
def test_stream_time_covers_all_work(items):
    """Completion time >= serial lower bounds; util in [0, 1]."""
    res = simulate_stream(items, Policy.RC_SLICED)
    rc_lb = sum(it.n_tiles * it.t_r for it in items
                if isinstance(it, RCBlock))
    npu_lb = sum(it.duration for it in items if isinstance(it, NpuPhase))
    bus_lb = sum((it.n_tiles * (it.rc_input_bytes + it.rc_result_bytes)
                  + it.read_bytes) / it.bw
                 for it in items if isinstance(it, RCBlock))
    assert res.time >= max(rc_lb, npu_lb, bus_lb) - 1e-12
    assert 0.0 <= res.util <= 1.0 + 1e-9
    assert res.bus_busy <= res.time + 1e-12


@given(streams)
@settings(max_examples=40, deadline=None)
def test_sliced_vs_unsliced_bounded(items):
    """Greedy bubble-filling is NOT universally better than head-of-line
    paging (scheduling anomalies on adversarial streams reach ~1.29x when
    reads vastly exceed bubble capacity); the invariant we hold is that the
    sliced policy never loses badly, while on *model-shaped* streams it wins
    1.38-1.42x (asserted against real configs in test_sim.py)."""
    t_sliced = simulate_stream(items, Policy.RC_SLICED).time
    t_unsliced = simulate_stream(items, Policy.RC_UNSLICED).time
    assert t_sliced <= t_unsliced * 1.35


@given(st.lists(st.integers(2, 12), min_size=1, max_size=8))
@settings(max_examples=40, deadline=None)
def test_sliced_wins_on_balanced_streams(tile_counts):
    """When reads fit the bubble budget (the paper's α-balanced regime),
    slicing is never slower."""
    items = []
    for n in tile_counts:
        bubble_bytes = n * 30e-6 * 1e9 * 0.8
        items.append(_block(n, int(bubble_bytes)))
    t_sliced = simulate_stream(items, Policy.RC_SLICED).time
    t_unsliced = simulate_stream(items, Policy.RC_UNSLICED).time
    assert t_sliced <= t_unsliced * 1.0001


@given(streams)
@settings(max_examples=40, deadline=None)
def test_bus_byte_conservation(items):
    """Every byte scheduled crosses the bus exactly once."""
    res = simulate_stream(items, Policy.RC_SLICED)
    expected = sum((it.n_tiles * (it.rc_input_bytes + it.rc_result_bytes)
                    + it.read_bytes) / it.bw
                   for it in items if isinstance(it, RCBlock))
    assert abs(res.bus_busy - expected) < 1e-9


@given(st.integers(1, 30), st.integers(0, 20))
@settings(max_examples=40, deadline=None)
def test_prefetch_window_nearly_monotone(n_tiles, n_pages):
    """A larger prefetch window never hurts much.

    Strict monotonicity is FALSE for greedy bubble-filling schedulers
    (Graham's anomalies: extra capacity reorders greedy choices and can
    finish later despite identical bus-busy time) — observed up to ~1.57x
    on adversarial streams. We assert the anomaly stays bounded."""
    items = [
        _block(n_tiles, n_pages * 16384),
        NpuPhase(2e-4),
        _block(n_tiles, n_pages * 16384),
    ]
    t_small = simulate_stream(items, Policy.RC_SLICED,
                              prefetch_bytes=16384.0).time
    t_big = simulate_stream(items, Policy.RC_SLICED,
                            prefetch_bytes=1e9).time
    assert t_big <= t_small * 1.7
