"""Property tests for the whole-model stream simulator (hypothesis)."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.schedule import Policy
from repro.sim.engine import NpuPhase, RCBlock, simulate_stream


def _block(n_tiles, read_bytes, bw=1e9):
    return RCBlock(n_tiles=n_tiles, rc_input_bytes=256.0,
                   rc_result_bytes=256.0, read_bytes=float(read_bytes),
                   t_r=30e-6, bw=bw, page_bytes=16384.0)


streams = st.lists(
    st.one_of(
        st.builds(_block, st.integers(1, 12),
                  st.sampled_from([0, 8192, 65536, 262144])),
        st.builds(NpuPhase, st.floats(1e-6, 5e-4)),
    ),
    min_size=1, max_size=12)


@given(streams)
@settings(max_examples=60, deadline=None)
def test_stream_time_covers_all_work(items):
    """Completion time >= serial lower bounds; util in [0, 1]."""
    res = simulate_stream(items, Policy.RC_SLICED)
    rc_lb = sum(it.n_tiles * it.t_r for it in items
                if isinstance(it, RCBlock))
    npu_lb = sum(it.duration for it in items if isinstance(it, NpuPhase))
    bus_lb = sum((it.n_tiles * (it.rc_input_bytes + it.rc_result_bytes)
                  + it.read_bytes) / it.bw
                 for it in items if isinstance(it, RCBlock))
    assert res.time >= max(rc_lb, npu_lb, bus_lb) - 1e-12
    assert 0.0 <= res.util <= 1.0 + 1e-9
    assert res.bus_busy <= res.time + 1e-12


@given(streams)
@settings(max_examples=40, deadline=None)
def test_sliced_vs_unsliced_bounded(items):
    """Greedy bubble-filling is NOT universally better than head-of-line
    paging (scheduling anomalies on adversarial streams reach ~1.29x when
    reads vastly exceed bubble capacity); the invariant we hold is that the
    sliced policy never loses badly, while on *model-shaped* streams it wins
    1.38-1.42x (asserted against real configs in test_sim.py)."""
    t_sliced = simulate_stream(items, Policy.RC_SLICED).time
    t_unsliced = simulate_stream(items, Policy.RC_UNSLICED).time
    assert t_sliced <= t_unsliced * 1.35


@given(st.lists(st.integers(2, 12), min_size=1, max_size=8))
@settings(max_examples=40, deadline=None)
def test_sliced_wins_on_balanced_streams(tile_counts):
    """When reads fit the bubble budget (the paper's α-balanced regime),
    slicing is never slower."""
    items = []
    for n in tile_counts:
        bubble_bytes = n * 30e-6 * 1e9 * 0.8
        items.append(_block(n, int(bubble_bytes)))
    t_sliced = simulate_stream(items, Policy.RC_SLICED).time
    t_unsliced = simulate_stream(items, Policy.RC_UNSLICED).time
    assert t_sliced <= t_unsliced * 1.0001


@given(streams)
@settings(max_examples=40, deadline=None)
def test_bus_byte_conservation(items):
    """Every byte scheduled crosses the bus exactly once."""
    res = simulate_stream(items, Policy.RC_SLICED)
    expected = sum((it.n_tiles * (it.rc_input_bytes + it.rc_result_bytes)
                    + it.read_bytes) / it.bw
                   for it in items if isinstance(it, RCBlock))
    assert abs(res.bus_busy - expected) < 1e-9


@given(st.integers(1, 30), st.integers(0, 20))
@settings(max_examples=40, deadline=None)
def test_prefetch_window_nearly_monotone(n_tiles, n_pages):
    """A larger prefetch window never hurts much.

    Strict monotonicity is FALSE for greedy bubble-filling schedulers
    (Graham's anomalies: extra capacity reorders greedy choices and can
    finish later despite identical bus-busy time) — observed up to ~1.57x
    on adversarial streams. We assert the anomaly stays bounded."""
    items = [
        _block(n_tiles, n_pages * 16384),
        NpuPhase(2e-4),
        _block(n_tiles, n_pages * 16384),
    ]
    t_small = simulate_stream(items, Policy.RC_SLICED,
                              prefetch_bytes=16384.0).time
    t_big = simulate_stream(items, Policy.RC_SLICED,
                            prefetch_bytes=1e9).time
    assert t_big <= t_small * 1.7


# ---------------------------------------------------------------------------
# engine invariants (paged-serving PR): conservation + regime guarantees
# ---------------------------------------------------------------------------

def _balanced_block(n_tiles):
    """Reads sized to ~80% of the block's own bubble budget (the paper's
    alpha-balanced regime): every read fits the bubbles it rides in."""
    return _block(n_tiles, int(n_tiles * 30e-6 * 1e9 * 0.8))


# unlike test_sliced_wins_on_balanced_streams' RC-only streams, these mix in
# NpuPhase gaps, so prefetch-ahead across barriers is exercised too
balanced_streams = st.lists(
    st.one_of(
        st.builds(_balanced_block, st.integers(2, 12)),
        st.builds(NpuPhase, st.floats(1e-6, 5e-4)),
    ),
    min_size=1, max_size=10)


@given(streams)
@settings(max_examples=40, deadline=None)
def test_time_covers_bus_busy_unsliced(items):
    """RC_UNSLICED conservation (test_stream_time_covers_all_work pins the
    RC_SLICED policy): completion time covers every bus-occupied second."""
    res = simulate_stream(items, Policy.RC_UNSLICED)
    assert res.time >= res.bus_busy - 1e-12
    assert 0.0 <= res.util <= 1.0 + 1e-9


@given(balanced_streams)
@settings(max_examples=40, deadline=None)
def test_sliced_never_slower_when_reads_fit_bubbles(items):
    """In the alpha-balanced regime slicing strictly dominates head-of-line
    paging even across NpuPhase barriers (the adversarial counterexamples
    need reads that overflow their block's bubble budget; see
    test_sliced_vs_unsliced_bounded)."""
    t_sliced = simulate_stream(items, Policy.RC_SLICED,
                               prefetch_bytes=1e12).time
    t_unsliced = simulate_stream(items, Policy.RC_UNSLICED,
                                 prefetch_bytes=1e12).time
    assert t_sliced <= t_unsliced * 1.0001


@given(balanced_streams)
@settings(max_examples=40, deadline=None)
def test_no_read_stall_with_unbounded_prefetch(items):
    """With an unbounded prefetch window and bubble-sized reads, every
    block's reads are delivered before its barrier: stalled_on_reads == 0."""
    res = simulate_stream(items, Policy.RC_SLICED, prefetch_bytes=1e12)
    assert res.stalled_on_reads == 0.0


@given(streams)
@settings(max_examples=40, deadline=None)
def test_stalls_absent_without_reads(items):
    """A stream with no NPU-bound reads can never stall on them."""
    import dataclasses as _dc
    stripped = [_dc.replace(it, read_bytes=0.0)
                if isinstance(it, RCBlock) else it for it in items]
    res = simulate_stream(stripped, Policy.RC_SLICED)
    assert res.stalled_on_reads == 0.0
    assert res.time >= res.bus_busy - 1e-12
