"""§VI outlier-oriented ECC: round-trip, protection, f_prot, sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import ecc


def _weights_page(key, n_outliers=100, page=16384):
    k0, k1, k2 = jax.random.split(key, 3)
    bulk = (jax.random.normal(k0, (page,)) * 12).round().clip(-127, 127)
    pos = jax.random.choice(k1, page, (n_outliers,), replace=False)
    vals = jnp.where(jax.random.bernoulli(k2, 0.5, (n_outliers,)), 110., -115.)
    w = bulk.at[pos].set(vals).astype(jnp.int8)
    return jax.lax.bitcast_convert_type(w, jnp.uint8)


def test_ecc_size_matches_paper():
    # 8*9 + (14+5+16)*163 = 5777 bits ≈ 722 B < 1664 B spare area
    assert ecc.ecc_size_bits() == 5777
    assert ecc.ecc_size_bits() / 8 < 1664
    assert ecc.n_outliers() == 163


def test_clean_roundtrip_exact():
    page = _weights_page(jax.random.PRNGKey(0))
    e = ecc.encode_page(page)
    assert bool((ecc.decode_page(page, e) == page).all())


@pytest.mark.parametrize("ber", [1e-5, 1e-4, 2e-4])
def test_correction_reduces_mse(ber):
    page = _weights_page(jax.random.PRNGKey(1))
    e = ecc.encode_page(page)
    k1, k2 = jax.random.split(jax.random.PRNGKey(int(ber * 1e7)))
    noisy = ecc.inject_bitflips(page, ber, k1)
    necc = ecc.inject_ecc_bitflips(e, ber, k2)
    dec = ecc.decode_page(noisy, necc)
    o = page.astype(jnp.int8).astype(jnp.float32)
    raw = float(((noisy.astype(jnp.int8).astype(jnp.float32) - o) ** 2).mean())
    cor = float(((dec.astype(jnp.int8).astype(jnp.float32) - o) ** 2).mean())
    assert cor < raw * 0.5 or raw == 0.0


def test_outliers_survive():
    page = _weights_page(jax.random.PRNGKey(2))
    e = ecc.encode_page(page)
    vals = page.astype(jnp.int8).astype(jnp.int32)
    top = jax.lax.top_k(jnp.abs(vals), 163)[1]
    errs = 0
    for t in range(8):
        k1, k2 = jax.random.split(jax.random.PRNGKey(100 + t))
        noisy = ecc.inject_bitflips(page, 2e-4, k1)
        dec = ecc.decode_page(noisy, ecc.inject_ecc_bitflips(e, 2e-4, k2))
        errs += int((dec[top] != page[top]).sum())
    assert errs == 0, f"{errs} protected outliers corrupted"


def test_fake_outliers_clamped():
    page = _weights_page(jax.random.PRNGKey(3))
    e = ecc.encode_page(page)
    thr = int(ecc._majority_bits(e.threshold, axis=-1))
    # flip a mid-range value's sign bit to fake a huge outlier
    vals = np.asarray(page.astype(jnp.int8)).copy()
    victim = int(np.argmin(np.abs(vals.astype(np.int32))))  # smallest value
    vals[victim] = 127  # way above threshold, not protected
    noisy = jax.lax.bitcast_convert_type(jnp.asarray(vals), jnp.uint8)
    dec = ecc.decode_page(noisy, e)
    assert int(dec.astype(jnp.int8)[victim]) == 0  # clamped to zero


def test_fprot_closed_form_n2():
    # paper: N=2, x=1e-4 -> f_prot = 3x^2 = 3e-8
    assert abs(ecc.protected_flip_rate(1e-4) - 3e-8) < 1e-9


def test_fprot_monte_carlo():
    """Empirical flip rate of majority-of-3 ≈ 3x^2 (within MC noise)."""
    x = 0.02
    key = jax.random.PRNGKey(7)
    n = 200_000
    vals = jnp.zeros((n,), jnp.uint8)
    flips = jax.random.bernoulli(key, x, (3, n, 8))
    weights = (1 << jnp.arange(8, dtype=jnp.uint32))
    copies = [vals ^ (flips[i].astype(jnp.uint32) * weights).sum(-1
                                                                 ).astype(jnp.uint8)
              for i in range(3)]
    voted = ecc._majority3_u8(*copies)
    bit_flip_rate = float(
        jnp.unpackbits(voted).astype(jnp.float32).mean())
    expect = ecc.protected_flip_rate(x)
    assert abs(bit_flip_rate - expect) < 0.3 * expect + 1e-5


@given(st.integers(0, 2**14 - 1))
@settings(max_examples=50, deadline=None)
def test_hamming_single_error_correction(addr):
    a = jnp.array([addr], jnp.uint16)
    p = ecc.hamming_encode(a)
    for bit in range(14):
        corrupted = a ^ (1 << bit)
        fixed, valid = ecc.hamming_correct(corrupted, p)
        assert int(fixed[0]) == addr and bool(valid[0])
    # parity-bit errors leave the address intact
    for bit in range(5):
        fixed, valid = ecc.hamming_correct(a, p ^ (1 << bit))
        assert int(fixed[0]) == addr and bool(valid[0])


def test_batched_pages():
    pages = jnp.stack([_weights_page(jax.random.PRNGKey(i)) for i in range(4)])
    e = ecc.encode_pages(pages)
    dec = ecc.decode_pages(pages, e)
    assert bool((dec == pages).all())
