"""Fleet serving: wire codec, transports, worker protocol, and failover.

Load-bearing checks, per the fleet contract (serving/fleet/README.md):

* The wire codec round-trips the full command surface — scalars,
  ndarrays (incl. bf16), and the serving dataclasses — and the frame
  decoder survives adversity: byte-by-byte feeds, messages split across
  recv boundaries, oversized payloads, and garbage bytes all either
  reassemble cleanly or raise ProtocolError (never hang).
* SlotSnapshot.to_bytes()/from_bytes() round-trips byte-identically for
  every paged family, and the versioned header's geometry guard
  (family / page_size / dtype) rejects mismatched receivers before the
  body is decoded.
* Killing one loopback worker mid-decode loses zero requests: queued
  requests replay from the client's record, in-flight slots restore
  from the periodic checkpoint, and every recovered stream is
  bit-identical to an undisturbed single-engine run — greedy AND
  seed-pinned stochastic, for every paged family.
* A straggler (blown reply deadlines under the miss limit) recovers
  without failover — its late replies are delivered and counted as
  heartbeat misses; past the miss limit it is failed over like a death.
* The socket transport drives real subprocess workers, and SIGKILLing
  one mid-decode meets the same zero-loss bit-identity bar
  (``-k sock``; dense + the recurrent hybrid family).
"""

import os
import signal

import jax
import numpy as np
import pytest

try:
    import ml_dtypes
except ImportError:  # pragma: no cover - ships with jax
    ml_dtypes = None

from repro.configs.registry import ASSIGNED_ARCHS
from repro.models import model as M
from repro.serving.client import ServingClient
from repro.serving.core import (EngineCore, Request, RequestOutput,
                                SlotSnapshot)
from repro.serving.fleet import wire
from repro.serving.fleet.router import FleetRouter
from repro.serving.fleet.transport import (LoopbackTransport, RemoteError,
                                           TransportClosed, unwrap)
from repro.serving.fleet.worker import WorkerHost
from repro.serving.scheduler import SamplingParams

KEY = jax.random.PRNGKey(0)
ENG_KW = dict(max_batch=2, max_seq=48, eos_id=-1, page_size=8)


@pytest.fixture(scope="module")
def smollm():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    return cfg, params


def _reqs(n, max_new=10, stochastic=True):
    """Mixed greedy/stochastic requests; odd rids get pinned seeds so
    failover replay is checked for sampled streams too."""
    out = []
    for rid in range(n):
        sp = None
        if stochastic and rid % 2 == 1:
            sp = SamplingParams(temperature=0.9, top_k=20, seed=100 + rid)
        out.append(Request(rid=rid, prompt=[2 + rid, 5, 9 + rid],
                           max_new_tokens=max_new, sampling=sp))
    return out


def _solo_ref(cfg, params, reqs):
    """The oracle: one undisturbed in-process engine, same requests."""
    eng = EngineCore(cfg, params, **ENG_KW)
    for r in reqs:
        eng.add_request(r)
    eng.run()
    assert all(r.done for r in reqs)
    return {r.rid: list(r.out_tokens) for r in reqs}


# ------------------------------------------------------------- wire codec
def test_codec_roundtrips_scalars_containers_and_arrays():
    objs = [None, True, False, np.bool_(True), 0, -7, 2**40, 3.5, "héllo",
            b"\x00\xff", [1, "a", None], (1, (2, 3)),
            {"k": [1.5, b"x"], "n": None},
            np.arange(12, dtype=np.int32).reshape(3, 4),
            np.zeros((0, 4), dtype=np.float64)]
    if ml_dtypes is not None:
        objs.append((np.arange(8).astype(ml_dtypes.bfloat16) * 1.5)
                    .astype(ml_dtypes.bfloat16))
    for o in objs:
        d = wire.decode(wire.encode(o))
        if isinstance(o, np.ndarray):
            assert d.dtype == o.dtype and d.shape == o.shape
            assert (d == o).all()
        else:
            assert d == o


def test_codec_roundtrips_serving_dataclasses():
    sp = SamplingParams(temperature=0.8, top_k=40, seed=7)
    req = Request(rid=3, prompt=[1, 2, 3], max_new_tokens=8, sampling=sp,
                  session="s1", priority=2)
    req.out_tokens.extend([5, 6])
    r2 = wire.decode(wire.encode(req))
    assert (r2.rid, r2.prompt, r2.out_tokens) == (3, [1, 2, 3], [5, 6])
    assert r2.sampling.temperature == 0.8 and r2.sampling.seed == 7
    ev = RequestOutput(rid=1, token=9, n_out=2, finished=True,
                       finish_reason="eos",
                       sched={"chunks": 1, "preemptions": 0, "wait_s": 0.1})
    e2 = wire.decode(wire.encode(ev))
    assert e2.token == 9 and e2.finish_reason == "eos"
    assert e2.sched["chunks"] == 1


def test_codec_rejects_truncation_and_unknown_tags():
    with pytest.raises(wire.ProtocolError):
        wire.decode(wire.encode([1, 2, 3])[:-2])
    with pytest.raises(wire.ProtocolError):
        wire.decode(b"\xffgarbage")
    with pytest.raises(wire.ProtocolError):
        wire.decode(wire.encode("x") + b"trailing")


# ------------------------------------------------------ framing adversity
def test_frame_decoder_byte_by_byte():
    payload = wire.encode({"a": [1, 2], "b": "x"})
    f = wire.frame(payload)
    dec = wire.FrameDecoder()
    outs = []
    for i in range(len(f)):
        outs += dec.feed(f[i:i + 1])
    assert len(outs) == 1 and outs[0] == payload


def test_frame_decoder_split_across_recv_boundaries():
    f = wire.frame(wire.encode([1, 2])) + wire.frame(wire.encode("x")) \
        + wire.frame(wire.encode(None))
    for cut in range(1, len(f) - 1):
        dec = wire.FrameDecoder()
        outs = dec.feed(f[:cut]) + dec.feed(f[cut:])
        assert [wire.decode(p) for p in outs] == [[1, 2], "x", None]


def test_frame_decoder_rejects_oversized_payload():
    with pytest.raises(wire.ProtocolError):
        wire.FrameDecoder(max_payload=4).feed(
            wire.frame(wire.encode("this is way past four bytes")))
    # the frame() side refuses to build it too
    with pytest.raises(wire.ProtocolError):
        wire.frame(b"x" * 8, max_payload=4)


def test_frame_decoder_rejects_garbage_bytes():
    with pytest.raises(wire.ProtocolError):
        wire.FrameDecoder().feed(b"\x00" * wire.HEADER_SIZE)
    # bad version in an otherwise valid header
    hdr = bytearray(wire.frame(wire.encode(1)))
    hdr[2] = 99
    with pytest.raises(wire.ProtocolError):
        wire.FrameDecoder().feed(bytes(hdr))


def test_worker_replies_cleanly_to_malformed_command(smollm):
    """A garbage command through the transport gets an error REPLY (with
    the load heartbeat), not a hang or a worker crash."""
    cfg, params = smollm
    host = WorkerHost(EngineCore(cfg, params, **ENG_KW))
    rep = host.handle("not-a-command-dict")
    assert rep["ok"] is False and rep["e"]["type"] == "ProtocolError"
    assert "queue_depth" in rep["load"]
    with pytest.raises(RemoteError):
        unwrap(rep)
    # and the host still serves real commands afterwards
    t = LoopbackTransport(host)
    assert unwrap(t.call("ping", {})) == "worker"


# ------------------------------------------- snapshot bytes (per family)
def test_snapshot_bytes_roundtrip_all_families(fam):
    """Property test: snapshots taken at random decode depths round-trip
    byte-identically (to_bytes -> from_bytes -> to_bytes) for every
    paged family, and the geometry guard rejects wrong receivers."""
    family, cfg, params = fam
    rng = np.random.RandomState(0)
    eng = EngineCore(cfg, params, **ENG_KW)
    reqs = _reqs(2, max_new=12)
    for r in reqs:
        eng.add_request(r)
    for round_ in range(3):
        for _ in range(int(rng.randint(1, 4))):
            eng.step()
        active = [r for r in eng.slots if r is not None]
        if not active:
            break
        req = active[int(rng.randint(len(active)))]
        snap = eng.snapshot_slot(req.rid, release=False)
        blob = snap.to_bytes()
        hdr, _ = wire.peek_snapshot_header(blob)
        assert hdr["family"] == family
        assert hdr["page_size"] == ENG_KW["page_size"]
        s2 = SlotSnapshot.from_bytes(
            blob, expect_family=family,
            expect_page_size=ENG_KW["page_size"], expect_dtype=hdr["dtype"])
        assert s2.to_bytes() == blob, "re-encode is not byte-identical"
        assert s2.slot_len == snap.slot_len
        assert s2.req.out_tokens == snap.req.out_tokens
        assert len(s2.pages) == len(snap.pages)
        for (k1, v1), (k2, v2) in zip(snap.pages, s2.pages):
            k1, v1 = np.asarray(k1), np.asarray(v1)
            assert k1.dtype == k2.dtype and (k1 == k2).all()
            assert v1.dtype == v2.dtype and (v1 == v2).all()
        with pytest.raises(ValueError):
            SlotSnapshot.from_bytes(blob, expect_family="no-such-family")
        with pytest.raises(ValueError):
            SlotSnapshot.from_bytes(
                blob, expect_page_size=ENG_KW["page_size"] + 1)
        with pytest.raises(ValueError):
            SlotSnapshot.from_bytes(blob, expect_dtype="no-such-dtype")
        with pytest.raises(wire.ProtocolError):
            SlotSnapshot.from_bytes(blob[:len(blob) // 2])


def test_checkpoint_snapshot_is_non_destructive(smollm):
    """release=False must leave the slot running: the request finishes
    normally after being checkpointed every step."""
    cfg, params = smollm
    eng = EngineCore(cfg, params, **ENG_KW)
    reqs = _reqs(2, max_new=6)
    for r in reqs:
        eng.add_request(r)
    ref = _solo_ref(cfg, params, _reqs(2, max_new=6))
    steps = 0
    while eng.has_work and steps < 200:
        eng.step()
        for r in eng.slots:
            if r is not None:
                eng.snapshot_slot(r.rid, release=False)
        steps += 1
    assert all(r.done for r in reqs)
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert eng.stats.migrated_out == 0   # checkpoints are not migrations


# ------------------------------------------------- loopback fleet + kill
def test_loopback_kill_mid_decode_bit_identical(fam):
    """THE acceptance bar, per family: kill one of two loopback workers
    mid-decode; zero requests lost, every stream (greedy and seed-pinned
    stochastic) bit-identical to the undisturbed single-engine run."""
    family, cfg, params = fam
    ref = _solo_ref(cfg, params, _reqs(4))
    fl = FleetRouter.build_loopback(cfg, params, workers=2, spares=1,
                                    checkpoint_every=3, **ENG_KW)
    reqs = _reqs(4)
    for r in reqs:
        fl.submit(r)
    steps, killed = 0, False
    while fl.has_work and steps < 500:
        fl.step()
        steps += 1
        if not killed and steps == 5:
            fl.workers[0].transport.kill()
            killed = True
    assert all(r.done for r in reqs), \
        f"lost: {[r.rid for r in reqs if not r.done]}"
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert fl.fleet.workers_lost == 1 and fl.fleet.failovers == 1
    assert fl.fleet.requests_replayed >= 1
    assert fl.spares_left == 0          # the spare was promoted
    assert len(fl.recovery_s) == 1
    s = fl.summary()
    assert "workers_lost=1" in s and "failovers=1" in s
    fl.close()


def test_from_scratch_replay_without_checkpoints(smollm):
    """checkpoint_every=0 disables snapshots entirely: failover falls
    back to replaying from the client's request record — slower (every
    delivered token re-decodes) but still bit-identical."""
    cfg, params = smollm
    ref = _solo_ref(cfg, params, _reqs(4))
    fl = FleetRouter.build_loopback(cfg, params, workers=2, spares=0,
                                    checkpoint_every=0, migrate=False,
                                    **ENG_KW)
    reqs = _reqs(4)
    for r in reqs:
        fl.submit(r)
    steps, killed = 0, False
    while fl.has_work and steps < 500:
        fl.step()
        steps += 1
        if not killed and steps == 6:
            w0 = fl.workers[0]
            n_delivered = sum(len(r.out_tokens) for r in reqs
                              if fl._owner.get(r.rid) is w0)
            w0.transport.kill()
            killed = True
    assert all(r.done for r in reqs)
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    # every token delivered before the kill was re-decoded and suppressed
    assert fl.fleet.tokens_replayed >= n_delivered
    fl.close()


def test_straggler_recovers_without_failover(smollm):
    """Blown deadlines under the miss limit mark the worker SUSPECT and
    count heartbeat misses; its late replies are then delivered and the
    output stays bit-identical — no failover."""
    cfg, params = smollm
    ref = _solo_ref(cfg, params, _reqs(4))
    fl = FleetRouter.build_loopback(cfg, params, workers=2, spares=0,
                                    checkpoint_every=0, migrate=False,
                                    miss_limit=10, **ENG_KW)
    reqs = _reqs(4)
    for r in reqs:
        fl.submit(r)
    steps, stalled = 0, False
    while fl.has_work and steps < 500:
        fl.step()
        steps += 1
        if not stalled and steps == 4:
            fl.workers[0].transport.stall(3)
            stalled = True
    assert all(r.done for r in reqs)
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert fl.fleet.heartbeat_misses == 3
    assert fl.fleet.workers_lost == 0 and fl.fleet.failovers == 0
    assert all(w.state == "alive" for w in fl.workers)
    fl.close()


def test_straggler_past_miss_limit_fails_over(smollm):
    """A straggler that never comes back crosses the miss limit and is
    failed over exactly like a death — with the same bit-identity bar."""
    cfg, params = smollm
    ref = _solo_ref(cfg, params, _reqs(4))
    fl = FleetRouter.build_loopback(cfg, params, workers=2, spares=0,
                                    checkpoint_every=3, migrate=False,
                                    miss_limit=2, **ENG_KW)
    reqs = _reqs(4)
    for r in reqs:
        fl.submit(r)
    steps, stalled = 0, False
    while fl.has_work and steps < 500:
        fl.step()
        steps += 1
        if not stalled and steps == 5:
            fl.workers[0].transport.stall(1000)   # never recovers
            stalled = True
    assert all(r.done for r in reqs)
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert fl.fleet.failovers == 1 and fl.fleet.heartbeat_misses >= 3
    fl.close()


def test_fleet_abort_and_duplicate_rid_guard(smollm):
    cfg, params = smollm
    fl = FleetRouter.build_loopback(cfg, params, workers=2, spares=0,
                                    **ENG_KW)
    reqs = _reqs(3, max_new=12)
    for r in reqs:
        fl.submit(r)
    with pytest.raises(ValueError, match="already submitted"):
        fl.submit(Request(rid=0, prompt=[1], max_new_tokens=2))
    for _ in range(3):
        fl.step()
    assert fl.abort(1)
    steps = 0
    events = []
    while fl.has_work and steps < 200:
        events += fl.step()
        steps += 1
    finals = {e.rid: e for e in events if e.finished}
    assert finals[1].finish_reason == "aborted"
    assert reqs[0].done and reqs[2].done
    assert sum(1 for e in events if e.finished and e.rid == 1) == 1
    fl.close()


def test_serving_client_over_loopback_fleet(smollm):
    """The client surface composes with the fleet unchanged: workers=N
    builds a loopback FleetRouter, handles stream through a mid-run
    worker kill, and the summary surfaces the fleet counters."""
    cfg, params = smollm
    solo = ServingClient(cfg, params, replicas=1, seed_base=7, **ENG_KW)
    ref_handles = [solo.submit([3 + i, 5], max_new_tokens=8,
                               sampling=SamplingParams(temperature=0.8,
                                                       top_k=20)
                               if i % 2 else None)
                   for i in range(4)]
    solo.run()
    ref = {h.rid: list(h.request.out_tokens) for h in ref_handles}

    client = ServingClient(cfg, params, workers=2, spares=1, seed_base=7,
                           **ENG_KW)
    assert isinstance(client.router, FleetRouter)
    handles = [client.submit([3 + i, 5], max_new_tokens=8,
                             sampling=SamplingParams(temperature=0.8,
                                                     top_k=20)
                             if i % 2 else None)
               for i in range(4)]
    for _ in range(4):
        client.pump()
    client.router.workers[0].transport.kill()
    client.run()
    assert all(h.finished for h in handles)
    assert {h.rid: list(h.request.out_tokens) for h in handles} == ref
    assert client.router.fleet.workers_lost == 1
    assert "fleet:" in client.summary()
    client.router.close()

    with pytest.raises(ValueError, match="loopback fleets only"):
        ServingClient(cfg, params, workers=2, transport="socket", **ENG_KW)


# --------------------------------------------- socket workers (-k sock)
@pytest.mark.parametrize("arch", ["smollm-360m", "zamba2-7b"],
                         ids=["sock_dense", "sock_hybrid"])
def test_socket_sigkill_mid_decode_bit_identical(arch):
    """Real subprocess workers over TCP: SIGKILL one mid-decode; zero
    requests lost, all streams bit-identical to an undisturbed run."""
    cfg = ASSIGNED_ARCHS[arch].reduced()
    params = M.init_params(cfg, KEY, max_seq=ENG_KW["max_seq"])
    ref = _solo_ref(cfg, params, _reqs(4, max_new=8))
    fl = FleetRouter.build_socket(arch, workers=2, spares=0,
                                  checkpoint_every=3, migrate=False,
                                  max_batch=ENG_KW["max_batch"],
                                  max_seq=ENG_KW["max_seq"],
                                  page_size=ENG_KW["page_size"])
    try:
        reqs = _reqs(4, max_new=8)
        for r in reqs:
            fl.submit(r)
        steps, killed = 0, False
        while fl.has_work and steps < 500:
            fl.step()
            steps += 1
            if not killed and steps == 5:
                os.kill(fl.workers[0].transport.pid, signal.SIGKILL)
                killed = True
        assert all(r.done for r in reqs), \
            f"lost: {[r.rid for r in reqs if not r.done]}"
        assert {r.rid: list(r.out_tokens) for r in reqs} == ref
        assert fl.fleet.workers_lost == 1
    finally:
        fl.close()


def test_socket_transport_survives_split_frames(smollm):
    """Socket-level framing adversity: a reply split across many tiny
    TCP segments reassembles; the decoder never delivers a torn frame."""
    # pure FrameDecoder drill at socket-realistic sizes: a big ndarray
    # reply chopped into 7-byte segments
    arr = np.arange(4096, dtype=np.float32).reshape(64, 64)
    f = wire.frame(wire.encode({"r": arr, "ok": True}))
    dec = wire.FrameDecoder()
    outs = []
    for i in range(0, len(f), 7):
        outs += dec.feed(f[i:i + 7])
    assert len(outs) == 1
    rep = wire.decode(outs[0])
    assert rep["ok"] is True and (rep["r"] == arr).all()


def test_transport_closed_after_kill(smollm):
    cfg, params = smollm
    t = LoopbackTransport(WorkerHost(EngineCore(cfg, params, **ENG_KW)))
    assert unwrap(t.call("ping", {})) == "worker"
    t.kill()
    with pytest.raises(TransportClosed):
        t.call("ping", {})
