"""Substrate: quantization, hybrid GeMV + ECC, training, checkpoint/fault,
serving engine, grad compression, planner."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, ASSIGNED_ARCHS

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ quant
def test_int8_quant_roundtrip_error():
    from repro.quant.int8 import dequantize, quantize_weight

    w = jax.random.normal(KEY, (64, 128)) * 0.3
    q = quantize_weight(w)
    err = float(jnp.abs(dequantize(q.w_q, q.scale) - w).max())
    step = float((jnp.abs(w).max(axis=1) / 127.0).max())
    assert err <= step * 0.51


def test_int4_pack_unpack_exact():
    from repro.quant.int4 import pack_nibbles, unpack_nibbles

    w_q = jax.random.randint(KEY, (16, 64), -8, 8).astype(jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack_nibbles(pack_nibbles(w_q))), np.asarray(w_q))


def test_quantize_params_structure():
    from repro.models import model as M
    from repro.quant.convert import quantize_params

    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    p = M.init_params(cfg, KEY, max_seq=32)
    q = quantize_params(p)
    lw = q["layers"]["attn"]["q"]
    assert "w_q" in lw and lw["w_q"].dtype == jnp.int8
    assert lw["scale"].dtype == jnp.float32
    # quantized params still run the full model
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    logits = M.forward(q, cfg, toks, {})
    assert not bool(jnp.isnan(logits).any())


def test_quantized_vs_float_model_close():
    from repro.models import model as M
    from repro.quant.convert import quantize_params

    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    p = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=32)
    q = quantize_params(p)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    lf = M.forward(p, cfg, toks, {})
    lq = M.forward(q, cfg, toks, {})
    # logits agree in ranking for the top token most of the time
    agree = (jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean()
    assert float(agree) > 0.7


# ------------------------------------------------------- hybrid GeMV + ECC
def test_hybrid_gemv_paths_match():
    from repro.core.hw import CAMBRICON_LLM_S
    from repro.core.hybrid_gemv import hybrid_gemv, plan_and_quantize

    w = jax.random.normal(KEY, (512, 2048)) * 0.05
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2048,))
    hw = plan_and_quantize(w, CAMBRICON_LLM_S)
    y_kernel = hybrid_gemv(hw, x, use_kernel=True)
    y_ref = hybrid_gemv(hw, x, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(y_kernel), np.asarray(y_ref))
    rel = float(jnp.linalg.norm(y_ref - w @ x) / jnp.linalg.norm(w @ x))
    assert rel < 0.05  # int8 quantization noise only


def test_hybrid_gemv_ecc_recovers():
    from repro.core.hw import CAMBRICON_LLM_S
    from repro.core.hybrid_gemv import (corrupt_flash_region, hybrid_gemv,
                                        plan_and_quantize)

    w = jax.random.normal(KEY, (1024, 2048)) * 0.05
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2048,))
    ref = w @ x
    hw = plan_and_quantize(w, CAMBRICON_LLM_S, with_ecc=True)
    noisy = corrupt_flash_region(hw, 2e-4, jax.random.fold_in(KEY, 3))
    err_ecc = float(jnp.linalg.norm(hybrid_gemv(noisy, x) - ref))
    err_raw = float(jnp.linalg.norm(
        hybrid_gemv(noisy._replace(ecc=None), x) - ref))
    assert err_ecc < err_raw


# ------------------------------------------------------------- training
def test_train_step_decreases_loss():
    from repro.models import model as M
    from repro.training.optimizer import init_adamw
    from repro.training.train_loop import make_train_step
    from repro.training.data import DataState, make_batch

    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    opt = init_adamw(params)
    step = jax.jit(make_train_step(cfg, microbatches=1, lr=1e-3, remat=False),
                   static_argnames=())
    ds = DataState(seed=0, step=0)
    losses = []
    for i in range(8):
        toks, ds = make_batch(ds, 4, 32, cfg.vocab_size)
        params, opt, loss = step(params, opt, toks, None)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_train_step_microbatched_equivalence():
    from repro.models import model as M
    from repro.training.optimizer import init_adamw
    from repro.training.train_loop import make_train_step

    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    toks = jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size)
    p1, _, l1 = make_train_step(cfg, microbatches=1, remat=False)(
        params, init_adamw(params), toks)
    p2, _, l2 = make_train_step(cfg, microbatches=2, remat=False)(
        params, init_adamw(params), toks)
    assert abs(float(l1) - float(l2)) < 1e-4
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 1e-4


def test_remat_matches_no_remat():
    from repro.distributed import ctx
    from repro.training.train_loop import loss_fn
    from repro.models import model as M

    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    g1 = jax.grad(loss_fn)(params, cfg, toks)
    with ctx.lowering_ctx(remat=True):
        g2 = jax.grad(loss_fn)(params, cfg, toks)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert d < 1e-5


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.distributed.checkpoint import (latest_step, restore_checkpoint,
                                              save_checkpoint)

    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,), jnp.int8)}}
    save_checkpoint(str(tmp_path), 3, tree, extra={"data_step": 17})
    save_checkpoint(str(tmp_path), 7, tree, extra={"data_step": 42})
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, extra = restore_checkpoint(str(tmp_path), like)
    assert extra["data_step"] == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last_k(tmp_path):
    from repro.distributed.checkpoint import save_checkpoint, latest_step

    tree = {"a": jnp.zeros((2,))}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2 and latest_step(str(tmp_path)) == 5


def test_checkpoint_crash_leaves_no_partial(tmp_path):
    """A failed save must not corrupt the latest checkpoint (atomicity)."""
    from repro.distributed.checkpoint import (restore_checkpoint,
                                              save_checkpoint)

    tree = {"a": jnp.ones((2,))}
    save_checkpoint(str(tmp_path), 1, tree)

    class Boom(Exception):
        pass

    bad = {"a": _Exploding()}
    with pytest.raises(Exception):
        save_checkpoint(str(tmp_path), 2, bad)
    restored, _ = restore_checkpoint(str(tmp_path), jax.tree.map(
        jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.ones((2,), np.float32))
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".tmp_")]
    assert not leftovers


class _Exploding:
    shape = (2,)
    dtype = "float32"

    def __array__(self, *a, **k):
        raise RuntimeError("disk died mid-save")


def test_checkpoint_shape_mismatch_detected(tmp_path):
    from repro.distributed.checkpoint import restore_checkpoint, save_checkpoint

    save_checkpoint(str(tmp_path), 1, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"a": jnp.ones((3,))})


def test_checkpoint_bf16_restore_bit_identical(tmp_path):
    """Saved-then-restored bf16 payloads (incl. strided views, the shape
    fleet SlotSnapshot page payloads arrive in) are bit-identical — the
    uint16 round-trip must not touch a single bit pattern."""
    import ml_dtypes

    from repro.distributed.checkpoint import restore_checkpoint, save_checkpoint

    rng = np.random.default_rng(0)
    base = rng.standard_normal((4, 8)).astype(ml_dtypes.bfloat16)
    tree = {"page_k": base, "page_v": base[:, ::-1],      # strided view
            "blob": rng.integers(0, 256, 64).astype(np.uint8),
            "special": np.array([np.inf, -np.inf, np.nan, -0.0, 1e-38],
                                dtype=ml_dtypes.bfloat16)}
    save_checkpoint(str(tmp_path), 1, tree)
    like = jax.tree.map(np.zeros_like, tree)
    restored, _ = restore_checkpoint(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        # bitwise, not value-wise: NaN payloads and -0.0 must survive too
        np.testing.assert_array_equal(
            np.ascontiguousarray(a).view(np.uint8),
            np.ascontiguousarray(b).view(np.uint8))


def test_checkpoint_dtype_and_treedef_guards(tmp_path):
    """Restore refuses silent reinterpretation: a like_tree whose dtype
    or structure disagrees with the manifest raises instead of viewing
    the stored bytes into the wrong meaning."""
    import ml_dtypes

    from repro.distributed.checkpoint import restore_checkpoint, save_checkpoint

    tree = {"a": np.ones((2, 2), ml_dtypes.bfloat16),
            "b": np.zeros(3, np.int32)}
    save_checkpoint(str(tmp_path), 1, tree)
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(str(tmp_path),
                           {"a": np.ones((2, 2), np.uint16),   # same bytes!
                            "b": np.zeros(3, np.int32)})
    with pytest.raises(ValueError, match="treedef"):
        restore_checkpoint(str(tmp_path),
                           {"x": np.ones((2, 2), ml_dtypes.bfloat16),
                            "b": np.zeros(3, np.int32)})


def test_plan_remesh_shapes():
    from repro.distributed.elastic import plan_remesh

    # small survivor counts: 2-axis mesh, model axis = gcd with prefer
    assert plan_remesh(8, prefer_model=4) == ((2, 4), ("data", "model"))
    assert plan_remesh(6, prefer_model=4) == ((3, 2), ("data", "model"))
    # pod-scale with an even data axis splits out a pod axis of 2
    shape, names = plan_remesh(1024, prefer_model=16)
    assert names == ("pod", "data", "model")
    assert shape == (2, 32, 16)
    assert shape[0] * shape[1] * shape[2] == 1024
    # odd data axis at pod scale stays 2-axis
    shape, names = plan_remesh(528, prefer_model=16)
    assert names == ("data", "model") and shape == (33, 16)


def test_data_pipeline_resumable():
    from repro.training.data import DataState, make_batch

    s = DataState(seed=5, step=2)
    b1, s1 = make_batch(s, 2, 8, 100)
    b2, _ = make_batch(DataState(seed=5, step=2), 2, 8, 100)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    assert s1.step == 3


# --------------------------------------------------------------- serving
def test_serving_engine_end_to_end():
    from repro.models import model as M
    from repro.serving.engine import Request, ServingEngine

    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1)
    reqs = [Request(rid=i, prompt=[3, 5, 7][: i + 1], max_new_tokens=5)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)
    assert stats.tokens_out >= 3 * 4


def test_serving_straggler_redispatch():
    from repro.models import model as M
    from repro.serving.engine import Request, ServingEngine

    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    fired = []

    def watchdog(step, dt):
        if step == 1 and not fired:
            fired.append(step)
            return True
        return False

    eng = ServingEngine(cfg, params, max_batch=1, max_seq=48, eos_id=-1,
                        watchdog=watchdog)
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4)
    eng.submit(r)
    stats = eng.run()
    assert r.done and stats.straggler_events == 1


# -------------------------------------------------------- grad compression
def test_grad_compress_error_feedback_unbiased():
    from repro.distributed.grad_compress import make_error_feedback_transform

    init_state, transform = make_error_feedback_transform()
    params = {"w": jnp.zeros((64,))}
    g_true = {"w": jax.random.normal(KEY, (64,)) * 0.1}
    err = init_state(params)
    acc = jnp.zeros((64,))
    for i in range(50):
        g_c, err = transform(g_true, err)
        acc = acc + g_c["w"]
    # error feedback: accumulated compressed grads converge to the truth
    rel = float(jnp.linalg.norm(acc / 50 - g_true["w"])
                / jnp.linalg.norm(g_true["w"]))
    assert rel < 0.02


# ----------------------------------------------------------- partition plan
def test_tpu_alpha_plan_regimes():
    from repro.core.partition_plan import alpha_tpu

    # decode (tokens=1): ship-activations strictly wins
    p = alpha_tpu(4096, 4096, tokens=1, n_shards=16)
    assert p.schedule == "ship_activations"
    # huge-batch training: gathering weights beats shipping activations
    p2 = alpha_tpu(4096, 4096, tokens=1_000_000, n_shards=16)
    assert p2.t_ship_weights < p2.t_ship_act
    assert p2.alpha <= 0.5


def test_planner_streams_match_matrices():
    """decode_execution_stream totals == model_matrices active params."""
    from repro.core import planner

    for name in ("llama2-70b", "deepseek-v2-lite-16b", "zamba2-7b",
                 "whisper-small", "qwen2-moe-a2.7b", "mamba2-130m"):
        cfg = ARCHS[name]
        stream_params = sum(h * w for kind, *dims in
                            planner.decode_execution_stream(cfg)
                            if kind == "gemv" for h, w in [dims])
        mat_params = sum(m.active_params for m in
                         planner.model_matrices(cfg))
        assert stream_params == mat_params, name
