"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

The property-test modules do::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

The fallback draws examples from a seeded ``random.Random`` keyed on the
test name and example index, so runs are reproducible and failures can be
replayed.  Only the strategy surface these tests use is implemented
(integers, floats, sampled_from, lists, one_of, builds).  ``max_examples``
is capped — the fallback is a smoke tier, the real fuzzing happens where
hypothesis is available.
"""

from __future__ import annotations

import random

MAX_EXAMPLES_CAP = 25


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> Strategy:
        return Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> Strategy:
        seq = list(seq)
        return Strategy(lambda r: r.choice(seq))

    @staticmethod
    def one_of(*strategies) -> Strategy:
        return Strategy(lambda r: r.choice(strategies).example(r))

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int = 10) -> Strategy:
        def draw(r):
            n = r.randint(min_size, max_size)
            return [elements.example(r) for _ in range(n)]
        return Strategy(draw)

    @staticmethod
    def builds(target, *arg_strategies, **kw_strategies) -> Strategy:
        def draw(r):
            args = [s.example(r) for s in arg_strategies]
            kw = {k: s.example(r) for k, s in kw_strategies.items()}
            return target(*args, **kw)
        return Strategy(draw)


strategies = _Strategies()
st = strategies


def settings(max_examples: int = 100, deadline=None, **_kw):
    """Attach example-count settings; works above or below @given."""
    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*arg_strategies):
    def deco(fn):
        # NOTE: no functools.wraps — copying the signature would make pytest
        # treat the drawn parameters as fixtures; the wrapper must look
        # zero-argument
        def wrapper():
            cfg = getattr(wrapper, "_fallback_settings",
                          getattr(fn, "_fallback_settings", {}))
            n = min(cfg.get("max_examples", 100), MAX_EXAMPLES_CAP)
            for i in range(n):
                rnd = random.Random(f"{fn.__module__}.{fn.__name__}#{i}")
                drawn = [s.example(rnd) for s in arg_strategies]
                try:
                    fn(*drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {drawn!r}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
