"""Overlapped decode loop conformance: bit-identity with the synchronous
two-dispatch loop, across every paged family (the ``fam`` fixture).

The overlapped engine (``overlap=True``) fuses decode + sampling into one
jitted dispatch, keeps sampled tokens on device, and reads them back one
step late.  None of that may change a single emitted token: these tests run
the SAME request set through a synchronous and an overlapped engine and
require identical ``out_tokens`` / ``finish_reason`` per request — greedy
and seed-pinned stochastic, through tiered preempt/resume, requeue
restarts, chunked prefill, migration, lagged-eos discard, and wave mode.
The dispatch accounting is pinned too: the synchronous loop pays 2 jitted
dispatches per decode step, the overlapped loop exactly 1.
"""

import numpy as np
import pytest

from repro.serving.core import EngineCore, Request
from repro.serving.scheduler import SamplingParams, make_scheduler

from conftest import load_family


def _reqs(n=5, stochastic=False, max_new=8, plen=4):
    reqs = []
    for i in range(n):
        sp = (SamplingParams(temperature=0.8, seed=40 + i, top_k=20,
                             top_p=0.9)
              if stochastic and i % 2 else SamplingParams(temperature=0.0))
        reqs.append(Request(rid=i, prompt=[3 + i, 5, 7 + i, 2][:plen],
                            max_new_tokens=max_new, sampling=sp))
    return reqs


def _run_pair(cfg, params, make_reqs, eos_id=-1, **kw):
    """Run the same workload sync and overlapped; return both (reqs, stats)."""
    out = []
    for overlap in (False, True):
        eng = EngineCore(cfg, params, eos_id=eos_id, overlap=overlap, **kw)
        reqs = make_reqs()
        for r in reqs:
            eng.add_request(r)
        stats = eng.run()
        assert stats.tokens_out == sum(len(r.out_tokens) for r in reqs)
        out.append((reqs, stats))
    return out


def _assert_identical(sync, olap):
    (rs_s, st_s), (rs_o, st_o) = sync, olap
    for a, b in zip(rs_s, rs_o):
        assert a.out_tokens == b.out_tokens, \
            (a.rid, a.out_tokens, b.out_tokens)
        assert a.finish_reason == b.finish_reason, \
            (a.rid, a.finish_reason, b.finish_reason)
    # the tentpole metric: dispatches per decoded token drop from 2 to 1
    assert st_s.decode_dispatches == 2 * st_s.decode_steps
    assert st_o.decode_dispatches == st_o.decode_steps


# ------------------------------------------------------------- conformance
@pytest.mark.parametrize("sampling", ["greedy", "stochastic"])
def test_overlap_bit_identical(fam, sampling):
    family, cfg, params = fam
    pair = _run_pair(cfg, params,
                     lambda: _reqs(stochastic=(sampling == "stochastic")),
                     max_batch=2, max_seq=32, page_size=4)
    _assert_identical(*pair)


def test_overlap_tiered_preempt_resume(fam):
    """Pool pressure: suspension, lazy async spill, prefetch, resume — all
    while one step is in flight — must not perturb a single token."""
    family, cfg, params = fam
    pair = _run_pair(cfg, params,
                     lambda: _reqs(n=6, stochastic=True, max_new=10),
                     max_batch=3, max_seq=32, page_size=4, num_pages=8,
                     kv_tier="flash")
    _assert_identical(*pair)
    assert pair[1][1].kv_spill_pages > 0  # pressure actually happened


def test_overlap_migration(fam):
    """snapshot_slot drains the in-flight step first, so a migrated slot's
    continuation on the peer is bit-identical to the unmigrated run."""
    family, cfg, params = fam

    def run(overlap):
        e1 = EngineCore(cfg, params, max_batch=2, max_seq=32, page_size=4,
                        eos_id=-1, overlap=overlap)
        e2 = EngineCore(cfg, params, max_batch=2, max_seq=32, page_size=4,
                        eos_id=-1, overlap=overlap)
        reqs = _reqs(n=2, stochastic=True, max_new=10)
        for r in reqs:
            e1.add_request(r)
        for _ in range(4):
            e1.step()
        e2.inject_slot(e1.snapshot_slot(reqs[1].rid))
        for _ in range(40):
            e1.step()
            e2.step()
            if all(r.done for r in reqs):
                break
        assert all(r.done for r in reqs)
        return reqs

    rs_s, rs_o = run(False), run(True)
    for a, b in zip(rs_s, rs_o):
        assert a.out_tokens == b.out_tokens, \
            (a.rid, a.out_tokens, b.out_tokens)
        assert a.n_migrated == b.n_migrated == (1 if a.rid == 1 else 0)


# ----------------------------------------------------- single-family edges
def _dense():
    return load_family("dense")


def test_overlap_eos_lag_identity():
    """An eos token is only discovered at the lagged drain; the speculative
    extra step the slot ran in between must be fully discarded."""
    cfg, params = _dense()
    # find a token the greedy run actually emits, then make it the eos
    probe = _run_pair(cfg, params, _reqs, max_batch=2, max_seq=32,
                      page_size=4)[0][0]
    eos = probe[0].out_tokens[len(probe[0].out_tokens) // 2]
    pair = _run_pair(cfg, params, lambda: _reqs(max_new=12), eos_id=eos,
                     max_batch=2, max_seq=32, page_size=4)
    _assert_identical(*pair)
    assert any(r.finish_reason == "eos" for r in pair[1][0])


def _px_page_bits(eng):
    """Gathered (k, v) payloads of every HOT cached prefix page, by key."""
    return {key: eng._gather_pages([ent.pid])[0]
            for key, ent in eng._px._pages.items() if not ent.cold}


def test_overlap_prefix_eos_lag_never_dirties_shared_pages():
    """Prefix cache x overlap: the speculative extra step a slot runs past
    a lagged eos is discarded — it must never COW-dirty (or write in place
    into) a shared page that OUTLIVES the discarded epoch.  Oracle: token
    streams match the sync engine's, the surviving cached page payloads are
    bit-equal between the sync and overlapped engines, and a warm
    resubmission on the overlapped engine still replays the cold stream."""
    cfg, params = _dense()
    prompt = [3, 5, 7, 2, 9, 4, 6, 8, 1]  # 2 full pages + a tail at ps=4
    probe = EngineCore(cfg, params, eos_id=-1, max_batch=2, max_seq=48,
                       page_size=4)
    pr = Request(rid=0, prompt=list(prompt), max_new_tokens=10)
    probe.add_request(pr)
    probe.run()
    eos = pr.out_tokens[len(pr.out_tokens) // 2]

    engines, runs = [], []
    for overlap in (False, True):
        eng = EngineCore(cfg, params, eos_id=eos, overlap=overlap,
                         max_batch=2, max_seq=48, page_size=4,
                         prefix_cache=True)
        rs = [Request(rid=i, prompt=list(prompt), max_new_tokens=10)
              for i in range(3)]
        for r in rs:
            eng.add_request(r)
        eng.run()
        engines.append(eng)
        runs.append(rs)
    for a, b in zip(*runs):
        assert a.out_tokens == b.out_tokens, (a.out_tokens, b.out_tokens)
        assert a.finish_reason == b.finish_reason
    assert any(r.finish_reason == "eos" for r in runs[1])
    assert engines[1].stats.prefix_hits >= 1   # warm admissions happened
    bits_s, bits_o = (_px_page_bits(e) for e in engines)
    assert bits_s.keys() == bits_o.keys()
    for key in bits_s:
        for x, y in zip(bits_s[key], bits_o[key]):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    again = Request(rid=9, prompt=list(prompt), max_new_tokens=10)
    engines[1].add_request(again)
    engines[1].run()
    assert again.out_tokens == runs[0][0].out_tokens


def test_overlap_requeue_identity():
    """Requeue preemption under pool exhaustion: an undrained pending token
    is dropped with the slot and regenerated deterministically after the
    folded-prefix restart."""
    cfg, params = _dense()
    pair = _run_pair(cfg, params,
                     lambda: _reqs(n=6, stochastic=True, max_new=10),
                     max_batch=3, max_seq=32, page_size=4, num_pages=9,
                     exhaust_policy="requeue")
    _assert_identical(*pair)
    assert pair[1][1].preemptions > 0


def test_overlap_chunked_prefill_identity():
    cfg, params = _dense()

    def reqs():
        return [Request(rid=i, prompt=list(range(3, 23 + i)),
                        max_new_tokens=6) for i in range(4)]

    pair = _run_pair(cfg, params, reqs, max_batch=2, max_seq=48, page_size=4,
                     scheduler=make_scheduler("fcfs", chunk_tokens=6))
    _assert_identical(*pair)
    assert pair[1][1].prefill_chunks > 0


def test_overlap_wave_identity():
    cfg, params = _dense()
    pair = _run_pair(cfg, params, lambda: _reqs(stochastic=True),
                     mode="wave", max_batch=2, max_seq=32)
    _assert_identical(*pair)


def test_overlap_rejects_watchdog():
    """No retained pre-step cache in the overlapped loop, so the watchdog's
    replay contract cannot hold — constructing both must fail loudly."""
    cfg, params = _dense()
    with pytest.raises(ValueError, match="overlap"):
        EngineCore(cfg, params, overlap=True,
                   watchdog=lambda step, dt: False)
