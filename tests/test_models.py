"""Per-arch smoke tests (reduced configs, 1 CPU device) + consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED_ARCHS, ARCHS
from repro.configs.base import ALL_SHAPES, shape_applicable
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _extras(cfg, batch, key=KEY):
    if cfg.family == "vlm":
        return {"vision_embeds": jax.random.normal(
            key, (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "audio":
        return {"frames": jax.random.normal(
            key, (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)}
    return {}


@pytest.mark.parametrize("arch", sorted(ASSIGNED_ARCHS))
def test_smoke_forward_prefill_decode(arch):
    """One forward + one train-shaped step + prefill + decode on the reduced
    config: output shapes correct, no NaNs."""
    cfg = ASSIGNED_ARCHS[arch].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    extras = _extras(cfg, B)
    logits = M.forward(params, cfg, toks, extras)
    exp_s = S + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    cache = M.init_cache(cfg, B, 32)
    last, cache = M.prefill(params, cfg, toks, cache, extras)
    assert last.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    lg, cache = M.decode_step(params, cfg, tok, cache)
    assert lg.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any())
    assert int(cache["len"]) == exp_s + 1


@pytest.mark.parametrize("arch", ["smollm-360m", "chatglm3-6b",
                                  "mamba2-130m", "qwen2-moe-a2.7b",
                                  "deepseek-v2-lite-16b"])
def test_decode_matches_forward(arch):
    """prefill(s[:n]) + decode(s[n]) logits == forward(s) at f32."""
    cfg = ASSIGNED_ARCHS[arch].reduced()
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    toks = jax.random.randint(KEY, (1, 9), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, 1, 32, dtype=jnp.float32)
    last, cache = M.prefill(params, cfg, toks[:, :8], cache, {})
    lg, cache = M.decode_step(params, cfg, toks[:, 8], cache)
    full = M.forward(params, cfg, toks, {})
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, 7]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 8]),
                               rtol=2e-3, atol=2e-3)


def test_multi_token_greedy_determinism():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    toks = jax.random.randint(KEY, (1, 4), 0, cfg.vocab_size)

    def rollout():
        cache = M.init_cache(cfg, 1, 32)
        last, cache = M.prefill(params, cfg, toks, cache, {})
        out = []
        tok = jnp.argmax(last, -1).astype(jnp.int32)
        for _ in range(6):
            out.append(int(tok[0]))
            lg, cache = M.decode_step(params, cfg, tok, cache)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
        return out

    assert rollout() == rollout()


def test_chatglm_partial_rope():
    """rope_fraction=0.5 must leave the non-rotary half untouched."""
    from repro.models.layers import apply_rope

    x = jax.random.normal(KEY, (1, 4, 2, 64))
    pos = jnp.broadcast_to(jnp.arange(4), (1, 4))
    y = apply_rope(x, pos, 1e4, fraction=0.5)
    np.testing.assert_array_equal(np.asarray(y[..., 32:]),
                                  np.asarray(x[..., 32:]))
    assert not np.allclose(np.asarray(y[..., :32]), np.asarray(x[..., :32]))


def test_mrope_positions_shapes():
    from repro.models.layers import mrope_positions

    pos = mrope_positions(2, 20, 16)
    assert pos.shape == (3, 2, 20)
    # vision tokens: t=0; text positions strictly increasing
    assert int(pos[0, 0, 0]) == 0
    assert bool((jnp.diff(pos[0, 0, 16:]) > 0).all())


def test_shape_skip_rules():
    skips = []
    runnable = 0
    for cfg in ASSIGNED_ARCHS.values():
        for shape in ALL_SHAPES:
            ok, why = shape_applicable(cfg, shape)
            if ok:
                runnable += 1
            else:
                skips.append((cfg.name, shape.name))
    # 10 archs x 4 shapes = 40 cells; long_500k runs only for ssm+hybrid:
    # 8 archs x 3 + 2 archs x 4 = 32 runnable
    assert runnable == 32
    assert all(s == "long_500k" for _, s in skips)
    assert {a for a, _ in skips} == {
        "deepseek-v2-lite-16b", "qwen2-moe-a2.7b", "qwen2-vl-72b",
        "smollm-360m", "command-r-plus-104b", "internlm2-20b",
        "chatglm3-6b", "whisper-small"}


def test_param_counts_sane():
    expected = {
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "qwen2-moe-a2.7b": (13e9, 15.5e9),
        "qwen2-vl-72b": (70e9, 75e9),
        "smollm-360m": (0.3e9, 0.42e9),
        "command-r-plus-104b": (100e9, 108e9),
        "internlm2-20b": (18e9, 22e9),
        "chatglm3-6b": (5.5e9, 7e9),
        "whisper-small": (0.2e9, 0.35e9),
        "zamba2-7b": (6e9, 8e9),
        "mamba2-130m": (0.1e9, 0.16e9),
        "llama2-70b": (66e9, 71e9),
        "opt-66b": (63e9, 68e9),
    }
    for name, (lo, hi) in expected.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_chunked_attention_matches_naive():
    from repro.models.attention import chunked_attention

    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 40, 4, 16))
    k = jax.random.normal(k2, (2, 40, 2, 16))
    v = jax.random.normal(k3, (2, 40, 2, 16))
    out = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=8)
    kk = jnp.repeat(k, 2, axis=2)
    vv = jnp.repeat(v, 2, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * (16 ** -0.5)
    mask = jnp.tril(jnp.ones((40, 40), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
