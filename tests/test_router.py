"""Router / EngineCore / ServingClient: the multi-replica serving split.

Load-bearing checks, per the serving redesign contract:

* A Router with ONE replica reproduces the legacy ServingEngine outputs
  token-for-token (the compatibility shim really is a shim).
* Slot migration is bit-identical for EVERY paged family: a request
  snapshotted mid-decode on one replica and injected into another emits
  exactly the token stream of the unmigrated run — KV pages, per-slot
  length, sampler cursor, and recurrent SSM state all travel in the
  SlotSnapshot wire format.
* Terminal RequestOutput events stay globally unique across replicas
  (exactly one finished event per rid, fleet-wide).
* Routing policies follow their oracles (least-loaded picks the lighter
  replica, session affinity is sticky, round robin cycles), and the
  client is the single place global rids / sampling seeds come from.
"""

import jax
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED_ARCHS
from repro.models import model as M
from repro.serving.client import ServingClient
from repro.serving.core import EngineCore, Request, SlotSnapshot
from repro.serving.engine import ServingEngine
from repro.serving.router import Router
from repro.serving.scheduler import SamplingParams

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    return cfg, params


def _reqs(n, max_new=5):
    return [Request(rid=i, prompt=[1 + i] * (2 + i), max_new_tokens=max_new)
            for i in range(n)]


ENG_KW = dict(max_batch=2, max_seq=48, eos_id=-1, page_size=8)


# ------------------------------------------------------------- shim parity
def test_single_replica_router_matches_serving_engine(smollm):
    """Acceptance: Router(1 replica) == ServingEngine, token-for-token,
    with identical terminal-event streams."""
    cfg, params = smollm
    legacy = _reqs(4)
    eng = ServingEngine(cfg, params, **ENG_KW)
    for r in legacy:
        eng.submit(r)
    legacy_events = list(eng.stream())

    routed = _reqs(4)
    rt = Router.build(cfg, params, replicas=1, **ENG_KW)
    for r in routed:
        rt.submit(r)
    routed_events = []
    while rt.has_work:
        routed_events.extend(rt.step())

    for a, b in zip(legacy, routed):
        assert a.out_tokens == b.out_tokens
        assert a.finish_reason == b.finish_reason
    assert ([(e.rid, e.token, e.finished) for e in legacy_events]
            == [(e.rid, e.token, e.finished) for e in routed_events])


def test_engine_core_step_returns_events(smollm):
    """EngineCore.step() is the router-facing command: it returns the
    events of that round (the shim's bool step + drain stays equivalent)."""
    cfg, params = smollm
    core = EngineCore(cfg, params, **ENG_KW)
    core.add_request(Request(rid=0, prompt=[1, 2], max_new_tokens=3))
    seen = []
    while core.has_work:
        seen.extend(core.step())
    assert sum(1 for e in seen if e.token is not None) == 3
    assert sum(1 for e in seen if e.finished) == 1
    assert seen[-1].finished and seen[-1].n_out == 3


# ---------------------------------------------------------------- routing
def test_least_loaded_routing_oracle(smollm):
    """Before any stepping, load = queue depth: submissions alternate
    replicas; a pre-loaded replica is avoided until loads equalize."""
    cfg, params = smollm
    rt = Router.build(cfg, params, replicas=2, policy="least_loaded",
                      **ENG_KW)
    homes = [rt.cores.index(rt.submit(r)) for r in _reqs(4)]
    assert homes == [0, 1, 0, 1]
    # replica 0 now also holds the heavier queue: next goes to 1
    rt.cores[0].add_request(Request(rid=90, prompt=[7], max_new_tokens=2))
    assert rt.submit(Request(rid=5, prompt=[9], max_new_tokens=2)) \
        is rt.cores[1]


def test_round_robin_and_affinity_routing(smollm):
    cfg, params = smollm
    rt = Router.build(cfg, params, replicas=3, policy="round_robin",
                      **ENG_KW)
    homes = [rt.cores.index(rt.submit(r)) for r in _reqs(6, max_new=2)]
    assert homes == [0, 1, 2, 0, 1, 2]

    af = Router.build(cfg, params, replicas=3, policy="session_affinity",
                      **ENG_KW)
    a = [af.cores.index(af.submit(Request(
        rid=i, prompt=[1], max_new_tokens=2, session="alice")))
        for i in range(3)]
    b = [af.cores.index(af.submit(Request(
        rid=10 + i, prompt=[1], max_new_tokens=2, session="bob")))
        for i in range(3)]
    assert len(set(a)) == 1 and len(set(b)) == 1  # sticky per session


def test_router_build_gives_each_replica_its_own_scheduler(smollm):
    """A stateful policy instance (DRR's deficit ring) must be cloned per
    replica — interleaved admits from two queues would corrupt shared
    bookkeeping."""
    from repro.serving.scheduler import DRRScheduler
    cfg, params = smollm
    rt = Router.build(cfg, params, replicas=2,
                      scheduler=DRRScheduler(quantum=8), **ENG_KW)
    s0, s1 = (c.scheduler for c in rt.cores)
    assert s0 is not s1
    assert s0.quantum == s1.quantum == 8 and s0.name == s1.name == "drr"


def test_serving_engine_shim_works_as_replica(smollm):
    """The legacy shim's bool step() must not break a Router that adopts
    an existing engine as a replica."""
    cfg, params = smollm
    eng = ServingEngine(cfg, params, **ENG_KW)
    rt = Router([eng])
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4)
    rt.submit(r)
    events = []
    while rt.has_work:
        events.extend(rt.step())
    assert r.done and len(r.out_tokens) == 4
    assert sum(1 for e in events if e.finished) == 1


def test_router_rejects_duplicate_rid(smollm):
    cfg, params = smollm
    rt = Router.build(cfg, params, replicas=2, **ENG_KW)
    rt.submit(Request(rid=7, prompt=[1], max_new_tokens=2))
    with pytest.raises(ValueError):
        rt.submit(Request(rid=7, prompt=[2], max_new_tokens=2))


def test_router_rejects_heterogeneous_replicas(smollm):
    cfg, params = smollm
    a = EngineCore(cfg, params, **ENG_KW)
    b = EngineCore(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                   page_size=16)  # different page size
    with pytest.raises(ValueError):
        Router([a, b])
    with pytest.raises(ValueError):
        Router([a], policy="lifo")


# ------------------------------------------------- fleet-wide event stream
def test_terminal_events_globally_unique_across_replicas(smollm):
    """Exactly one finished=True event per rid across the whole fleet,
    even with capacity pressure forcing restarts on each replica."""
    cfg, params = smollm
    client = ServingClient(cfg, params, replicas=2, route="least_loaded",
                           max_batch=3, max_seq=48, eos_id=-1, page_size=8,
                           num_pages=6)
    for i in range(6):
        client.submit([2 + i] * (3 + i), max_new_tokens=12)
    events = list(client.stream())
    finals = [e for e in events if e.finished]
    assert sorted(e.rid for e in finals) == list(range(6))
    assert all(e.finish_reason in ("eos", "length", "capacity")
               for e in finals)
    # both replicas actually served traffic
    assert all(s.completed > 0 for s in client.router.stats)


def test_client_handles_and_seed_derivation(smollm):
    """The client is the single seed authority: stochastic requests get
    seed_base + global rid (unique fleet-wide); pinned seeds pass through;
    handle.tokens() streams exactly the request's own tokens."""
    cfg, params = smollm
    client = ServingClient(cfg, params, replicas=2, seed_base=100,
                           **ENG_KW)
    h0 = client.submit([1, 2], max_new_tokens=4,
                       sampling=SamplingParams(temperature=0.7))
    h1 = client.submit([3, 4], max_new_tokens=4,
                       sampling=SamplingParams(temperature=0.7))
    h2 = client.submit([5, 6], max_new_tokens=4,
                       sampling=SamplingParams(temperature=0.7, seed=9))
    h3 = client.submit([7, 8], max_new_tokens=4)  # greedy: no seed needed
    assert (h0.request.sampling.seed, h1.request.sampling.seed) == (100, 101)
    assert h2.request.sampling.seed == 9
    assert h3.request.sampling.seed is None
    toks = list(h1.tokens())
    assert toks == h1.request.out_tokens and len(toks) == 4
    for h in (h0, h2, h3):
        assert h.result().done


def test_abort_emits_single_terminal(smollm):
    """Abort of a queued AND of a running request each produce exactly one
    terminal event with finish_reason='aborted', free their pages, and
    leave the survivor unaffected."""
    cfg, params = smollm
    client = ServingClient(cfg, params, replicas=1, **ENG_KW)
    survivor = client.submit([1, 2, 3], max_new_tokens=6)
    running = client.submit([4, 5], max_new_tokens=30)
    queued = client.submit([6, 7], max_new_tokens=30)  # batch is full
    client.pump()  # admits survivor + running; `queued` stays queued
    assert client.abort(queued.rid) and client.abort(running.rid)
    assert not client.abort(999)
    events = list(client.stream())
    finals = {}
    for e in events:
        if e.finished:
            assert e.rid not in finals, "duplicate terminal event"
            finals[e.rid] = e
    assert set(finals) == {survivor.rid, running.rid, queued.rid}
    assert finals[running.rid].finish_reason == "aborted"
    assert finals[queued.rid].finish_reason == "aborted"
    assert finals[survivor.rid].finish_reason == "length"
    assert len(survivor.request.out_tokens) == 6
    core = client.router.cores[0]
    assert core.allocator.available == core.num_pages - 1  # pages freed
    assert core.stats.aborted == 2


# ------------------------------------------------------------ migration
def _mk_cores(cfg, params, n=2, **kw):
    base = dict(max_batch=2, max_seq=48, eos_id=-1, page_size=8)
    base.update(kw)
    return [EngineCore(cfg, params, **base) for _ in range(n)]


def test_slot_migration_bit_identity(fam):
    """Conformance (every paged family): snapshot a request mid-decode on
    replica A, inject it into replica B — the token stream is EXACTLY the
    single-replica run's, whether the pages carry full K/V, compressed
    ckv+krope, or shared-attn KV beside the checkpointed Mamba state."""
    family, cfg, params = fam
    prompt = [11, 12, 13, 14]

    solo = Request(rid=0, prompt=list(prompt), max_new_tokens=8)
    eng = ServingEngine(cfg, params, **ENG_KW)
    eng.submit(solo)
    eng.run()

    a, b = _mk_cores(cfg, params)
    mig = Request(rid=0, prompt=list(prompt), max_new_tokens=8)
    a.add_request(mig)
    for _ in range(3):  # prefill + 2 decode steps: genuinely mid-decode
        a.step()
    assert 0 < len(mig.out_tokens) < 8
    snap = a.snapshot_slot(0)
    assert isinstance(snap, SlotSnapshot) and snap.n_pages > 0
    assert not a.has_work  # drained, nothing left behind
    assert a.allocator.available == a.num_pages - 1
    b.inject_slot(snap)
    while b.has_work:
        b.step()
    assert mig.out_tokens == solo.out_tokens
    assert mig.finish_reason == solo.finish_reason
    assert mig.n_migrated == 1
    assert a.stats.migrated_out == 1 and b.stats.migrated_in == 1
    # donor's pool fully recycles after completion
    assert b.allocator.available == b.num_pages - 1


def test_migration_roundtrip_and_wire_format(smollm):
    """A -> B -> A double migration of a STOCHASTIC request still matches
    (seed-pinned sample streams depend only on (seed, output index), never
    on which replica draws them); the snapshot is plain host data (numpy
    pages + python scalars) — the cross-host wire format must never
    capture device arrays."""
    cfg, params = smollm
    sp = SamplingParams(temperature=0.8, top_k=20, seed=7)
    solo = Request(rid=0, prompt=[5, 6, 7], max_new_tokens=9, sampling=sp)
    eng = ServingEngine(cfg, params, **ENG_KW)
    eng.submit(solo)
    eng.run()

    a, b = _mk_cores(cfg, params)
    mig = Request(rid=0, prompt=[5, 6, 7], max_new_tokens=9, sampling=sp)
    a.add_request(mig)
    a.step()
    snap = a.snapshot_slot(0)
    assert all(isinstance(p[0], np.ndarray) and isinstance(p[1], np.ndarray)
               for p in snap.pages)
    assert isinstance(snap.slot_len, int) and isinstance(snap.last_token, int)
    b.inject_slot(snap)
    for _ in range(3):
        b.step()
    back = b.snapshot_slot(0)
    a.inject_slot(back)
    while a.has_work:
        a.step()
    assert mig.out_tokens == solo.out_tokens
    assert mig.n_migrated == 2


def test_migration_mid_chunked_prefill(smollm):
    """A slot snapshotted while its prompt is still chunk-prefilling
    resumes on the donor, finishes the remaining chunks there, and decodes
    bit-identical (prefilling/prefill_pos travel in the snapshot)."""
    from repro.serving.scheduler import make_scheduler
    cfg, params = smollm
    prompt = list(range(1, 21))  # 20 tokens, budget 4 -> 5 chunks

    solo = Request(rid=0, prompt=list(prompt), max_new_tokens=6)
    eng = ServingEngine(cfg, params,
                        scheduler=make_scheduler("fcfs", chunk_tokens=4),
                        **ENG_KW)
    eng.submit(solo)
    eng.run()

    a, b = [EngineCore(cfg, params,
                       scheduler=make_scheduler("fcfs", chunk_tokens=4),
                       **ENG_KW) for _ in range(2)]
    mig = Request(rid=0, prompt=list(prompt), max_new_tokens=6)
    a.add_request(mig)
    a.step()  # claim slot + first chunk
    a.step()  # second chunk
    assert a.prefilling[0] and 0 < a.prefill_pos[0] < len(prompt)
    snap = a.snapshot_slot(0)
    assert snap.prefilling and snap.slot_len == snap.prefill_pos
    b.inject_slot(snap)
    while b.has_work:
        b.step()
    assert mig.out_tokens == solo.out_tokens
    assert mig.n_chunks == solo.n_chunks  # no chunk lost or repeated


def test_migration_of_suspended_slot(smollm):
    """A partially spilled (suspended) slot snapshots straight from the
    cold store — no prefetch needed — and resumes bit-identical."""
    cfg, params = smollm
    base = [Request(rid=i, prompt=[2 + i] * (3 + i), max_new_tokens=12)
            for i in range(3)]
    eng = ServingEngine(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                        page_size=8)
    for r in base:
        eng.submit(r)
    eng.run()
    ref = {r.rid: list(r.out_tokens) for r in base}

    a = EngineCore(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                   page_size=8, num_pages=6, kv_tier="flash")
    b = EngineCore(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                   page_size=8)
    reqs = [Request(rid=i, prompt=[2 + i] * (3 + i), max_new_tokens=12)
            for i in range(3)]
    for r in reqs:
        a.add_request(r)

    def cold_suspended():
        """A suspended slot with at least one page ACTUALLY spilled (marks
        become cold pages lazily, when someone else needs the pids)."""
        return [i for i in range(a.max_batch)
                if a.suspended[i] and 0 in a.slot_pages[i]]

    for _ in range(200):
        if cold_suspended():
            break
        a.step()
    assert cold_suspended(), "pool pressure never spilled a suspended slot"
    i = cold_suspended()[0]
    rid = a.slots[i].rid
    snap = a.snapshot_slot(rid)
    b.inject_slot(snap)
    while a.has_work or b.has_work:
        if a.has_work:
            a.step()
        if b.has_work:
            b.step()
    for r in reqs:
        assert r.out_tokens == ref[r.rid], r.rid


def test_router_migrates_off_starved_replica(smollm):
    """End-to-end: all requests piled on one tiered replica (affinity), a
    second idle replica as donor — the router drains starved slots into it
    and every output matches the unconstrained single-replica reference."""
    cfg, params = smollm
    base = [Request(rid=i, prompt=[2 + i] * (3 + i), max_new_tokens=12)
            for i in range(4)]
    eng = ServingEngine(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                        page_size=8)
    for r in base:
        eng.submit(r)
    eng.run()
    ref = {r.rid: list(r.out_tokens) for r in base}

    import zlib
    starved = EngineCore(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                         page_size=8, num_pages=6, kv_tier="flash")
    donor = EngineCore(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                       page_size=8)
    # place the constrained replica where session "hot" hashes, so every
    # request deterministically piles onto it
    cores = [None, None]
    hot_idx = zlib.crc32(b"hot") % 2
    cores[hot_idx] = starved
    cores[1 - hot_idx] = donor
    rt = Router(cores, policy="session_affinity")
    reqs = [Request(rid=i, prompt=[2 + i] * (3 + i), max_new_tokens=12,
                    session="hot") for i in range(4)]
    for r in reqs:
        assert rt.submit(r) is starved
    steps = 0
    while rt.has_work and steps < 500:
        rt.step()
        steps += 1
    assert all(r.done for r in reqs)
    assert rt.migrations > 0
    assert donor.stats.migrated_in == rt.migrations
    for r in reqs:
        assert r.out_tokens == ref[r.rid], r.rid
    # fleet-wide leak check: both pools fully recycled
    for c in (starved, donor):
        assert c.allocator.available == c.num_pages - 1


def test_inject_guards(smollm):
    """inject_slot refuses mismatched geometry and full replicas;
    snapshot_slot refuses unknown rids."""
    cfg, params = smollm
    a, b = _mk_cores(cfg, params)
    wrong = EngineCore(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                       page_size=16)
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=6)
    a.add_request(r)
    a.step()
    with pytest.raises(KeyError):
        a.snapshot_slot(42)
    snap = a.snapshot_slot(0)
    with pytest.raises(ValueError):
        wrong.inject_slot(snap)  # page_size mismatch
    b.add_request(Request(rid=10, prompt=[1], max_new_tokens=6))
    b.add_request(Request(rid=11, prompt=[2], max_new_tokens=6))
    b.step()  # both slots claimed
    from repro.serving.kv_cache import OutOfPages
    with pytest.raises(OutOfPages):
        b.inject_slot(snap)  # no free slot
    assert not b.can_accept(snap.n_pages)
    a.inject_slot(snap)  # home replica always fits its own snapshot back
    while a.has_work:
        a.step()
    assert r.done and len(r.out_tokens) == 6
