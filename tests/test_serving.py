"""Paged per-slot KV cache + continuous batching engine tests.

The load-bearing check is the greedy oracle: a request admitted mid-stream
(while other slots are decoding someone else's tokens) must produce exactly
the tokens it produces when served alone.  That only holds if the paged
cache gives every slot position-independent storage (block table), per-slot
positions (length vector), and leak-free page recycling.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED_ARCHS
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import (OutOfPages, PageAllocator, pages_needed,
                                    prefill_bucket)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    return cfg, params


def _run(cfg, params, reqs, max_batch=2, max_seq=48, **kw):
    eng = ServingEngine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                        eos_id=kw.pop("eos_id", -1), **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng


# ---------------------------------------------------------------- allocator
def test_page_allocator_reserves_null_page():
    a = PageAllocator(9)
    got = a.alloc(8)
    assert 0 not in got and sorted(got) == list(range(1, 9))
    with pytest.raises(OutOfPages):
        a.alloc(1)
    a.free(got[:3])
    assert a.available == 3
    with pytest.raises(ValueError):
        a.free([0])


def test_page_allocator_double_free_raises():
    """A page id freed twice would be handed out to two slots and silently
    corrupt both KV streams — the guard set must catch it."""
    a = PageAllocator(6)
    got = a.alloc(3)
    a.free(got[:2])
    with pytest.raises(ValueError):
        a.free([got[0]])  # already free
    with pytest.raises(ValueError):
        a.free([got[2], got[2]])  # duplicate inside one call
    with pytest.raises(ValueError):
        a.free([99])  # never allocated (out of range)
    # a failed batch is atomic: got[2] is still held, nothing leaked
    assert a.available == 4
    a.free([got[2]])
    assert a.available == 5
    assert sorted(a.alloc(5)) == list(range(1, 6))


def test_page_math_helpers():
    assert pages_needed(1, 16) == 1 and pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2
    assert prefill_bucket(3) == 8 and prefill_bucket(8) == 8
    assert prefill_bucket(9) == 16


def test_page_math_edge_cases():
    # zero tokens: no pages, bucket stays at the floor
    assert pages_needed(0, 16) == 0
    assert prefill_bucket(0) == 8
    # exact page multiples never round up an extra page
    for mult in (1, 2, 7):
        assert pages_needed(mult * 16, 16) == mult
        assert pages_needed(mult * 16 + 1, 16) == mult + 1
    # bucket floor above the prompt length wins
    assert prefill_bucket(3, floor=32) == 32
    assert prefill_bucket(33, floor=32) == 64
    # buckets are powers of two times the floor and always cover the prompt
    for n in range(1, 130):
        b = prefill_bucket(n)
        assert b >= n and b % 8 == 0


def test_allocator_invariants_property():
    """Exhaustion/recycle invariants under random alloc/free interleavings
    (hypothesis when available, the deterministic fallback otherwise)."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

    @given(st.integers(2, 40), st.lists(st.integers(0, 6), max_size=40))
    @settings(max_examples=40, deadline=None)
    def check(num_pages, ops):
        a = PageAllocator(num_pages)
        held: list[int] = []
        for op in ops:
            if op == 0 and held:  # free one page
                a.free([held.pop()])
            else:
                n = op % 3 + 1
                if n <= a.available:
                    got = a.alloc(n)
                    assert 0 not in got
                    assert len(set(got)) == len(got)
                    assert not set(got) & set(held)  # no double hand-out
                    held += got
                else:
                    with pytest.raises(OutOfPages):
                        a.alloc(n)
            assert a.available + len(held) == num_pages - 1
        # full recycle: everything handed back is allocatable again
        a.free(held)
        assert a.available == num_pages - 1
        assert sorted(a.alloc(num_pages - 1)) == list(range(1, num_pages))

    check()


# ------------------------------------------------------------- model layer
def test_paged_cache_shapes(smollm):
    cfg, _ = smollm
    cache = M.init_paged_cache(cfg, 3, 40, page_size=16)
    assert cache["k"].shape[1] == 3 * 3 + 1      # ceil(40/16)=3 pages/slot
    assert cache["block"].shape == (3, 3)
    assert cache["lens"].shape == (3,)
    assert M.paged_slot_capacity(cache) == 48
    with pytest.raises(ValueError):
        M.init_paged_cache(ASSIGNED_ARCHS["mamba2-130m"].reduced(), 2, 32)


def test_decode_step_paged_matches_legacy(smollm):
    """Single request through paged prefill+decode == legacy shared-cursor
    path, bit-for-bit greedy, regardless of which slot and pages it lands
    on."""
    cfg, _ = smollm
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    toks = jax.random.randint(KEY, (1, 7), 0, cfg.vocab_size)

    cache = M.init_cache(cfg, 1, 32, dtype=jnp.float32)
    last, cache = M.prefill(params, cfg, toks, cache, {})
    legacy = [int(jnp.argmax(last, -1)[0])]
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    for _ in range(5):
        lg, cache = M.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        legacy.append(int(tok[0]))

    pc = M.init_paged_cache(cfg, 3, 32, dtype=jnp.float32, page_size=8)
    pps = pc["block"].shape[1]
    pc["block"] = pc["block"].at[1, :].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    padded = jnp.pad(toks, ((0, 0), (0, 9)))  # right-pad to a bucket
    lg1, pc = M.prefill_into_slot(params, cfg, padded, jnp.int32(7), pc,
                                  jnp.int32(1), {})
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(last[0]),
                               rtol=1e-5, atol=1e-5)
    paged = [int(jnp.argmax(lg1))]
    tokb = jnp.zeros((3,), jnp.int32).at[1].set(paged[0])
    active = jnp.array([False, True, False])
    for _ in range(5):
        lg, pc = M.decode_step_paged(params, cfg, tokb, pc, active)
        t = int(jnp.argmax(lg[1]))
        paged.append(t)
        tokb = tokb.at[1].set(t)
    assert paged == legacy
    assert int(pc["lens"][1]) == 12
    assert int(pc["lens"][0]) == 0 and int(pc["lens"][2]) == 0


def test_decode_step_paged_slot_at_capacity_is_inert(smollm):
    """A slot whose length reached capacity must not decode: the write would
    clamp into its own last page and corrupt it.  The lane deactivates (lens
    frozen) and other slots are untouched."""
    cfg, _ = smollm
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    pc = M.init_paged_cache(cfg, 2, 16, dtype=jnp.float32, page_size=8)
    pps = pc["block"].shape[1]
    pc["block"] = pc["block"].at[0].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    pc["block"] = pc["block"].at[1].set(
        jnp.arange(pps + 1, 2 * pps + 1, dtype=jnp.int32))
    cap = M.paged_slot_capacity(pc)
    pc["lens"] = jnp.asarray([cap, 3], jnp.int32)  # slot 0 full, slot 1 live
    before = pc["k"]
    tok = jnp.asarray([5, 6], jnp.int32)
    _, pc2 = M.decode_step_paged(params, cfg, tok, pc,
                                 jnp.array([True, True]))
    assert int(pc2["lens"][0]) == cap      # frozen, not advanced past cap
    assert int(pc2["lens"][1]) == 4        # live slot decoded normally
    # slot 0's pages are bit-identical: nothing was overwritten
    np.testing.assert_array_equal(np.asarray(pc2["k"][:, 1:pps + 1]),
                                  np.asarray(before[:, 1:pps + 1]))


def test_vlm_mrope_decode_matches_forward():
    """Decode must continue the M-RoPE text stream (idx - n_vision + side),
    not the raw cache index — checked against teacher-forced forward on both
    the legacy and the paged path."""
    cfg = ASSIGNED_ARCHS["qwen2-vl-72b"].reduced()
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    nvt = cfg.n_vision_tokens
    extras = {"vision_embeds": jax.random.normal(
        KEY, (1, nvt, cfg.d_model), jnp.float32)}
    toks = jax.random.randint(KEY, (1, 9), 0, cfg.vocab_size)
    full = M.forward(params, cfg, toks, extras)

    cache = M.init_cache(cfg, 1, 48, dtype=jnp.float32)
    last, cache = M.prefill(params, cfg, toks[:, :8], cache, extras)
    lg, cache = M.decode_step(params, cfg, toks[:, 8], cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, nvt + 8]),
                               rtol=2e-3, atol=2e-3)

    pc = M.init_paged_cache(cfg, 2, 48, dtype=jnp.float32, page_size=8)
    pps = pc["block"].shape[1]
    pc["block"] = pc["block"].at[0, :].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    padded = jnp.pad(toks[:, :8], ((0, 0), (0, 8)))
    lg0, pc = M.prefill_into_slot(params, cfg, padded, jnp.int32(8 + nvt),
                                  pc, jnp.int32(0), extras)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(full[0, nvt + 7]),
                               rtol=2e-3, atol=2e-3)
    tokb = jnp.zeros((2,), jnp.int32).at[0].set(int(toks[0, 8]))
    lgp, pc = M.decode_step_paged(params, cfg, tokb, pc,
                                  jnp.array([True, False]))
    np.testing.assert_allclose(np.asarray(lgp[0]), np.asarray(full[0, nvt + 8]),
                               rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------------ engine
def test_engine_mixed_length_prompts(smollm):
    cfg, params = smollm
    reqs = [Request(rid=i, prompt=list(range(1, 2 + i)), max_new_tokens=5)
            for i in range(5)]  # prompt lengths 1..5, 5 requests on 2 slots
    eng = _run(cfg, params, reqs)
    assert eng.mode == "continuous"
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)
    assert eng.stats.admitted == 5 and eng.stats.completed == 5


def test_mid_stream_admission_matches_solo_decode(smollm):
    """Acceptance check: a request admitted mid-stream (other slots busy
    decoding) produces greedy output identical to running it alone."""
    cfg, params = smollm
    target_prompt = [11, 12, 13, 14]

    solo = Request(rid=0, prompt=list(target_prompt), max_new_tokens=7)
    _run(cfg, params, [solo])

    # three front-runners with staggered lifetimes keep the two slots busy;
    # the target enters the queue last and is admitted only when a slot
    # frees, mid-decode of the surviving front-runner
    others = [Request(rid=i, prompt=[5 + i] * (2 + i), max_new_tokens=9 + i)
              for i in range(3)]
    target = Request(rid=99, prompt=list(target_prompt), max_new_tokens=7)
    eng = _run(cfg, params, others + [target])
    assert all(r.done for r in others)
    # the target was admitted in a later prefill pass than the first two
    assert eng.stats.prefills >= 2
    assert target.t_admit > min(o.t_first_token for o in others)
    assert target.out_tokens == solo.out_tokens


def test_eos_termination(smollm):
    cfg, params = smollm
    probe = Request(rid=0, prompt=[3, 1, 4], max_new_tokens=8)
    _run(cfg, params, [probe])
    assert len(probe.out_tokens) == 8
    eos = probe.out_tokens[2]  # make the 3rd emitted token the stop token

    r = Request(rid=1, prompt=[3, 1, 4], max_new_tokens=8)
    _run(cfg, params, [r], eos_id=eos)
    assert r.done
    assert r.out_tokens == probe.out_tokens[:3]
    assert r.out_tokens[-1] == eos


def test_max_token_termination_and_page_recycling(smollm):
    cfg, params = smollm
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                        page_size=8)
    first = [Request(rid=i, prompt=[2 + i], max_new_tokens=3)
             for i in range(2)]
    for r in first:
        eng.submit(r)
    eng.run()
    pool = eng.max_batch * eng.pages_per_slot
    assert eng.allocator.available == pool  # everything freed
    # a second generation must reuse the freed pages, not leak new ones
    second = [Request(rid=10 + i, prompt=[9] * 9, max_new_tokens=20)
              for i in range(3)]
    for r in second:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in second)
    assert all(len(r.out_tokens) == 20 for r in second)
    assert eng.allocator.available == pool
    assert np.asarray(eng.cache["lens"]).sum() == 0
    assert eng.block.sum() == 0


def test_wave_mode_still_serves(smollm):
    cfg, params = smollm
    reqs = [Request(rid=i, prompt=[3, 5, 7][: i + 1], max_new_tokens=5)
            for i in range(3)]
    eng = _run(cfg, params, reqs, mode="wave")
    assert eng.mode == "wave"
    assert all(r.done and len(r.out_tokens) == 5 for r in reqs)


def test_wave_forced_for_recurrent_families():
    cfg = ASSIGNED_ARCHS["mamba2-130m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=32, eos_id=-1)
    assert eng.mode == "wave"  # auto falls back: no attention KV to page
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, max_batch=2, max_seq=32,
                      mode="continuous")
    # prompt must cover the conv window (ssm_conv - 1) for mamba decode
    r = Request(rid=0, prompt=[1, 2, 3, 4], max_new_tokens=4)
    eng.submit(r)
    eng.run()
    assert r.done and len(r.out_tokens) == 4


def test_latency_percentiles_populated(smollm):
    cfg, params = smollm
    reqs = [Request(rid=i, prompt=[1 + i], max_new_tokens=4)
            for i in range(4)]
    eng = _run(cfg, params, reqs)
    s = eng.stats
    assert len(s.latency_s) == 4 and len(s.ttft_s) == 4
    p = s.percentiles("latency_s")
    assert p["p50"] <= p["p90"] <= p["p99"]
    assert all(x >= 0 for x in s.admission_wait_s)
    assert s.summary().startswith("[continuous]")
