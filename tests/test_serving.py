"""Paged per-slot KV cache + continuous batching engine tests.

The load-bearing check is the greedy oracle: a request admitted mid-stream
(while other slots are decoding someone else's tokens) must produce exactly
the tokens it produces when served alone.  That only holds if the paged
cache gives every slot position-independent storage (block table), per-slot
positions (length vector), and leak-free page recycling.

The serving contract is pinned as a CROSS-FAMILY conformance suite: every
test parametrized over ``fam`` runs for every family where
``supports_paged`` is true (dense, moe, vlm, mla_moe, hybrid — ids
``fam_<family>``, so ``pytest -k fam_hybrid`` / ``make test-families``
selects one family).  A new family cannot claim paged serving without
passing the whole suite.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED_ARCHS
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import (OutOfPages, PageAllocator, pages_needed,
                                    prefill_bucket)

KEY = jax.random.PRNGKey(0)

# the cross-family ``fam`` fixture lives in the repo-root conftest.py so the
# conformance suite here and in test_tiered_kv.py share one session-scoped
# params copy per family


@pytest.fixture(scope="module")
def smollm():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    return cfg, params


def _run(cfg, params, reqs, max_batch=2, max_seq=48, **kw):
    eng = ServingEngine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                        eos_id=kw.pop("eos_id", -1), **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    # accounting invariant: every token appended to any request's
    # out_tokens — prefill-sampled first tokens included — is counted
    # exactly once (requeue restarts regenerate tokens only AFTER folding,
    # so folded prefixes never double-count)
    assert eng.stats.tokens_out == sum(len(r.out_tokens) for r in reqs), \
        (eng.stats.tokens_out, [len(r.out_tokens) for r in reqs])
    return eng


# ---------------------------------------------------------------- allocator
def test_page_allocator_reserves_null_page():
    a = PageAllocator(9)
    got = a.alloc(8)
    assert 0 not in got and sorted(got) == list(range(1, 9))
    with pytest.raises(OutOfPages):
        a.alloc(1)
    a.free(got[:3])
    assert a.available == 3
    with pytest.raises(ValueError):
        a.free([0])


def test_page_allocator_double_free_raises():
    """A page id freed twice would be handed out to two slots and silently
    corrupt both KV streams — the guard set must catch it."""
    a = PageAllocator(6)
    got = a.alloc(3)
    a.free(got[:2])
    with pytest.raises(ValueError):
        a.free([got[0]])  # already free
    with pytest.raises(ValueError):
        a.free([got[2], got[2]])  # duplicate inside one call
    with pytest.raises(ValueError):
        a.free([99])  # never allocated (out of range)
    # a failed batch is atomic: got[2] is still held, nothing leaked
    assert a.available == 4
    a.free([got[2]])
    assert a.available == 5
    assert sorted(a.alloc(5)) == list(range(1, 6))


def test_page_allocator_refcount_guards():
    """Prefix sharing extends the double-free guard to refcounts: a page
    with sharers can never be freed, refcounts never go negative, and
    refcount 0 means idle-but-allocated — NOT free."""
    a = PageAllocator(6)
    p, q = a.alloc(2)
    assert a.refcount(p) == 1
    assert a.incref(p) == 2
    with pytest.raises(ValueError):
        a.free([p])  # a sharer remains
    with pytest.raises(ValueError):
        a.free([q, p])  # batch validation catches it before any free
    assert a.available == 3  # the failed batch freed nothing
    assert a.decref(p) == 1
    assert a.decref(p) == 0  # idle cached: still allocated
    with pytest.raises(ValueError):
        a.decref(p)  # below zero
    assert a.available == 3
    a.free([p])  # refcount 0 is freeable (reclaiming an idle cached page)
    with pytest.raises(ValueError):
        a.refcount(p)  # free pages have no refcount
    with pytest.raises(ValueError):
        a.incref(99)  # never allocated
    a.free([q])
    assert a.available == 5


def test_prefix_full_hit_skips_prefill_dispatches(fam):
    """Satellite of the prefix-cache PR, pinned per family: an exact-prompt
    hit must admit with ZERO prefill dispatches — neither the group-prefill
    nor the chunked-prefill counter may move — and still emit the cold
    run's exact tokens."""
    family, cfg, params = fam
    prompt = list(range(1, 19))
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                        page_size=8, prefix_cache=True)
    r0 = Request(rid=0, prompt=list(prompt), max_new_tokens=4)
    eng.submit(r0)
    eng.run()
    prefills, chunks = eng.stats.prefills, eng.stats.prefill_chunks
    r1 = Request(rid=1, prompt=list(prompt), max_new_tokens=4)
    eng.submit(r1)
    eng.run()
    assert eng.stats.prefills == prefills
    assert eng.stats.prefill_chunks == chunks
    assert r1.out_tokens == r0.out_tokens
    assert eng.stats.prefix_hits == 1 and eng.stats.prefix_tokens_reused > 0


def test_page_math_helpers():
    assert pages_needed(1, 16) == 1 and pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2
    assert prefill_bucket(3) == 8 and prefill_bucket(8) == 8
    assert prefill_bucket(9) == 16


def test_page_math_edge_cases():
    # zero tokens: no pages, bucket stays at the floor
    assert pages_needed(0, 16) == 0
    assert prefill_bucket(0) == 8
    # exact page multiples never round up an extra page
    for mult in (1, 2, 7):
        assert pages_needed(mult * 16, 16) == mult
        assert pages_needed(mult * 16 + 1, 16) == mult + 1
    # bucket floor above the prompt length wins
    assert prefill_bucket(3, floor=32) == 32
    assert prefill_bucket(33, floor=32) == 64
    # buckets are powers of two times the floor and always cover the prompt
    for n in range(1, 130):
        b = prefill_bucket(n)
        assert b >= n and b % 8 == 0


def test_allocator_invariants_property():
    """Exhaustion/recycle invariants under random alloc/free interleavings
    (hypothesis when available, the deterministic fallback otherwise)."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

    @given(st.integers(2, 40), st.lists(st.integers(0, 6), max_size=40))
    @settings(max_examples=40, deadline=None)
    def check(num_pages, ops):
        a = PageAllocator(num_pages)
        held: list[int] = []
        for op in ops:
            if op == 0 and held:  # free one page
                a.free([held.pop()])
            else:
                n = op % 3 + 1
                if n <= a.available:
                    got = a.alloc(n)
                    assert 0 not in got
                    assert len(set(got)) == len(got)
                    assert not set(got) & set(held)  # no double hand-out
                    held += got
                else:
                    with pytest.raises(OutOfPages):
                        a.alloc(n)
            assert a.available + len(held) == num_pages - 1
        # full recycle: everything handed back is allocatable again
        a.free(held)
        assert a.available == num_pages - 1
        assert sorted(a.alloc(num_pages - 1)) == list(range(1, num_pages))

    check()


# ------------------------------------------------------------- model layer
def test_paged_cache_shapes(smollm):
    cfg, _ = smollm
    cache = M.init_paged_cache(cfg, 3, 40, page_size=16)
    assert cache["k"].shape[1] == 3 * 3 + 1      # ceil(40/16)=3 pages/slot
    assert cache["block"].shape == (3, 3)
    assert cache["lens"].shape == (3,)
    assert M.paged_slot_capacity(cache) == 48
    with pytest.raises(ValueError):
        M.init_paged_cache(ASSIGNED_ARCHS["mamba2-130m"].reduced(), 2, 32)


def test_paged_cache_shapes_new_families():
    """mla_moe pages compressed [page, R]+[page, Dr] rows; hybrid pages only
    the shared-attn groups and carries a slot-indexed Mamba state pool."""
    cfg = ASSIGNED_ARCHS["deepseek-v2-lite-16b"].reduced()
    cache = M.init_paged_cache(cfg, 3, 40, page_size=16)
    assert cache["ckv"].shape == (cfg.n_layers, 10, 16, cfg.kv_lora_rank)
    assert cache["krope"].shape == (cfg.n_layers, 10, 16, cfg.qk_rope_dim)
    assert "k" not in cache and M.paged_slot_capacity(cache) == 48
    assert M.has_slot_state(cfg) is False

    hcfg = ASSIGNED_ARCHS["zamba2-7b"].reduced()
    hcache = M.init_paged_cache(hcfg, 3, 40, page_size=16)
    n_groups = hcfg.n_layers // hcfg.shared_attn_every
    tail = hcfg.n_layers - n_groups * hcfg.shared_attn_every
    assert hcache["k"].shape == (n_groups, 10, 16, hcfg.n_kv_heads,
                                 hcfg.d_head)
    assert hcache["mamba"]["state"].shape[:3] == (
        n_groups, hcfg.shared_attn_every, 3)   # slot-indexed state pool
    if tail:
        assert hcache["tail"]["state"].shape[:2] == (tail, 3)
    assert M.has_slot_state(hcfg) is True


def test_decode_step_paged_matches_legacy(fam):
    """Conformance (every paged family): a single request through paged
    prefill+decode == the legacy shared-cursor reference path — per-step
    logits within float32 tolerance and greedy tokens EXACTLY equal —
    regardless of which slot and pages it lands on.  This is the check
    against the wave/full-forward reference (the engine-level oracles only
    compare continuous-mode runs with each other)."""
    family, cfg, _ = fam
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    toks = jax.random.randint(KEY, (1, 7), 0, cfg.vocab_size)
    extras = {}
    if family == "vlm":
        extras = {"vision_embeds": jax.random.normal(
            KEY, (1, cfg.n_vision_tokens, cfg.d_model), jnp.float32)}
    len0 = 7 + (cfg.n_vision_tokens if family == "vlm" else 0)

    cache = M.init_cache(cfg, 1, 32, dtype=jnp.float32)
    last, cache = M.prefill(params, cfg, toks, cache, extras)
    legacy = [int(jnp.argmax(last, -1)[0])]
    legacy_logits = [np.asarray(last[0])]
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    for _ in range(5):
        lg, cache = M.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        legacy.append(int(tok[0]))
        legacy_logits.append(np.asarray(lg[0]))

    pc = M.init_paged_cache(cfg, 3, 32, dtype=jnp.float32, page_size=8)
    pps = pc["block"].shape[1]
    pc["block"] = pc["block"].at[1, :].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    padded = jnp.pad(toks, ((0, 0), (0, 9)))  # right-pad to a bucket
    lg1, pc = M.prefill_into_slot(params, cfg, padded, jnp.int32(len0), pc,
                                  jnp.int32(1), extras)
    np.testing.assert_allclose(np.asarray(lg1), legacy_logits[0],
                               rtol=1e-5, atol=1e-5)
    paged = [int(jnp.argmax(lg1))]
    tokb = jnp.zeros((3,), jnp.int32).at[1].set(paged[0])
    active = jnp.array([False, True, False])
    for step in range(5):
        lg, pc = M.decode_step_paged(params, cfg, tokb, pc, active)
        np.testing.assert_allclose(np.asarray(lg[1]),
                                   legacy_logits[step + 1],
                                   rtol=1e-5, atol=1e-5)
        t = int(jnp.argmax(lg[1]))
        paged.append(t)
        tokb = tokb.at[1].set(t)
    assert paged == legacy
    assert int(pc["lens"][1]) == len0 + 5
    assert int(pc["lens"][0]) == 0 and int(pc["lens"][2]) == 0


def test_decode_step_paged_slot_at_capacity_is_inert(smollm):
    """A slot whose length reached capacity must not decode: the write would
    clamp into its own last page and corrupt it.  The lane deactivates (lens
    frozen) and other slots are untouched."""
    cfg, _ = smollm
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    pc = M.init_paged_cache(cfg, 2, 16, dtype=jnp.float32, page_size=8)
    pps = pc["block"].shape[1]
    pc["block"] = pc["block"].at[0].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    pc["block"] = pc["block"].at[1].set(
        jnp.arange(pps + 1, 2 * pps + 1, dtype=jnp.int32))
    cap = M.paged_slot_capacity(pc)
    pc["lens"] = jnp.asarray([cap, 3], jnp.int32)  # slot 0 full, slot 1 live
    before = pc["k"]
    tok = jnp.asarray([5, 6], jnp.int32)
    _, pc2 = M.decode_step_paged(params, cfg, tok, pc,
                                 jnp.array([True, True]))
    assert int(pc2["lens"][0]) == cap      # frozen, not advanced past cap
    assert int(pc2["lens"][1]) == 4        # live slot decoded normally
    # slot 0's pages are bit-identical: nothing was overwritten
    np.testing.assert_array_equal(np.asarray(pc2["k"][:, 1:pps + 1]),
                                  np.asarray(before[:, 1:pps + 1]))


def test_vlm_mrope_decode_matches_forward():
    """Decode must continue the M-RoPE text stream (idx - n_vision + side),
    not the raw cache index — checked against teacher-forced forward on both
    the legacy and the paged path."""
    cfg = ASSIGNED_ARCHS["qwen2-vl-72b"].reduced()
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    nvt = cfg.n_vision_tokens
    extras = {"vision_embeds": jax.random.normal(
        KEY, (1, nvt, cfg.d_model), jnp.float32)}
    toks = jax.random.randint(KEY, (1, 9), 0, cfg.vocab_size)
    full = M.forward(params, cfg, toks, extras)

    cache = M.init_cache(cfg, 1, 48, dtype=jnp.float32)
    last, cache = M.prefill(params, cfg, toks[:, :8], cache, extras)
    lg, cache = M.decode_step(params, cfg, toks[:, 8], cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, nvt + 8]),
                               rtol=2e-3, atol=2e-3)

    pc = M.init_paged_cache(cfg, 2, 48, dtype=jnp.float32, page_size=8)
    pps = pc["block"].shape[1]
    pc["block"] = pc["block"].at[0, :].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    padded = jnp.pad(toks[:, :8], ((0, 0), (0, 8)))
    lg0, pc = M.prefill_into_slot(params, cfg, padded, jnp.int32(8 + nvt),
                                  pc, jnp.int32(0), extras)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(full[0, nvt + 7]),
                               rtol=2e-3, atol=2e-3)
    tokb = jnp.zeros((2,), jnp.int32).at[0].set(int(toks[0, 8]))
    lgp, pc = M.decode_step_paged(params, cfg, tokb, pc,
                                  jnp.array([True, False]))
    np.testing.assert_allclose(np.asarray(lgp[0]), np.asarray(full[0, nvt + 8]),
                               rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------------ engine
def test_engine_mixed_length_prompts(smollm):
    cfg, params = smollm
    reqs = [Request(rid=i, prompt=list(range(1, 2 + i)), max_new_tokens=5)
            for i in range(5)]  # prompt lengths 1..5, 5 requests on 2 slots
    eng = _run(cfg, params, reqs)
    assert eng.mode == "continuous"
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)
    assert eng.stats.admitted == 5 and eng.stats.completed == 5


def test_mid_stream_admission_matches_solo_decode(fam):
    """Conformance (every paged family): a request admitted mid-stream
    (other slots busy decoding) produces greedy output identical to running
    it alone."""
    family, cfg, params = fam
    target_prompt = [11, 12, 13, 14]

    solo = Request(rid=0, prompt=list(target_prompt), max_new_tokens=7)
    _run(cfg, params, [solo])

    # three front-runners with staggered lifetimes keep the two slots busy;
    # the target enters the queue last and is admitted only when a slot
    # frees, mid-decode of the surviving front-runner
    others = [Request(rid=i, prompt=[5 + i] * (2 + i), max_new_tokens=9 + i)
              for i in range(3)]
    target = Request(rid=99, prompt=list(target_prompt), max_new_tokens=7)
    eng = _run(cfg, params, others + [target])
    assert eng.mode == "continuous"
    assert all(r.done for r in others)
    # the target was admitted in a later prefill pass than the first two
    assert eng.stats.prefills >= 2
    assert target.t_admit > min(o.t_first_token for o in others)
    assert target.out_tokens == solo.out_tokens


def test_eos_termination(fam):
    family, cfg, params = fam
    probe = Request(rid=0, prompt=[3, 1, 4], max_new_tokens=8)
    _run(cfg, params, [probe])
    assert len(probe.out_tokens) == 8
    eos = probe.out_tokens[2]  # make the 3rd emitted token the stop token

    r = Request(rid=1, prompt=[3, 1, 4], max_new_tokens=8)
    _run(cfg, params, [r], eos_id=eos)
    assert r.done and r.finish_reason == "eos"
    assert r.out_tokens == probe.out_tokens[:3]
    assert r.out_tokens[-1] == eos


def test_max_token_termination_and_page_recycling(fam):
    family, cfg, params = fam
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                        page_size=8)
    first = [Request(rid=i, prompt=[2 + i], max_new_tokens=3)
             for i in range(2)]
    for r in first:
        eng.submit(r)
    eng.run()
    pool = eng.max_batch * eng.pages_per_slot
    assert eng.allocator.available == pool  # everything freed
    # a second generation must reuse the freed pages, not leak new ones
    second = [Request(rid=10 + i, prompt=[9] * 9, max_new_tokens=20)
              for i in range(3)]
    for r in second:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in second)
    assert all(len(r.out_tokens) == 20 for r in second)
    assert all(r.finish_reason == "length" for r in second)
    assert eng.allocator.available == pool
    assert np.asarray(eng.cache["lens"]).sum() == 0
    assert eng.block.sum() == 0


# ------------------------------------------------- streaming contract
def _terminal_events(events):
    return [e for e in events if e.finished]


def test_streaming_terminals_unique_under_reject(fam):
    """``exhaust_policy="reject"`` must still emit exactly ONE terminal
    RequestOutput per request — rejected ones with finish_reason="rejected"
    and token=None, completed ones with their real reason."""
    family, cfg, params = fam
    reqs = [Request(rid=i, prompt=[2 + i] * (3 + i), max_new_tokens=12)
            for i in range(5)]
    eng = ServingEngine(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                        page_size=8, num_pages=6, exhaust_policy="reject")
    for r in reqs:
        eng.submit(r)
    events = list(eng.stream())
    finals = _terminal_events(events)
    assert sorted(e.rid for e in finals) == [r.rid for r in reqs]
    assert eng.stats.rejected > 0  # pressure actually rejected someone
    by_rid = {e.rid: e for e in finals}
    for r in reqs:
        e = by_rid[r.rid]
        if r.rejected:
            assert e.finish_reason == "rejected" and e.token is None
        else:
            assert e.finish_reason in ("eos", "length", "capacity")


def test_streaming_terminal_on_capacity(fam):
    """A request that runs into the sequence capacity wall ends with
    finish_reason="capacity", exactly once, even mid-stream."""
    family, cfg, params = fam
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=10_000)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=24, eos_id=-1,
                        page_size=8)
    eng.submit(r)
    events = list(eng.stream())
    finals = _terminal_events(events)
    assert len(finals) == 1 and finals[0].rid == 0
    assert finals[0].finish_reason == "capacity"
    # token events + the terminal: n_out on the terminal equals the total
    assert finals[0].n_out == len(r.out_tokens)


def test_streaming_terminals_unique_under_requeue_preemption(fam):
    """Capacity preemption (requeue restarts) must not duplicate or drop
    terminal events: one per request, after however many restarts."""
    family, cfg, params = fam
    reqs = [Request(rid=i, prompt=[2 + i] * (3 + i), max_new_tokens=12)
            for i in range(5)]
    eng = ServingEngine(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                        page_size=8, num_pages=6, exhaust_policy="requeue")
    for r in reqs:
        eng.submit(r)
    events = list(eng.stream())
    finals = _terminal_events(events)
    assert sorted(e.rid for e in finals) == [r.rid for r in reqs]
    assert eng.stats.pool_exhausted > 0  # restarts actually happened
    assert all(r.done and not r.rejected for r in reqs)


def test_wave_mode_still_serves(smollm):
    cfg, params = smollm
    reqs = [Request(rid=i, prompt=[3, 5, 7][: i + 1], max_new_tokens=5)
            for i in range(3)]
    eng = _run(cfg, params, reqs, mode="wave")
    assert eng.mode == "wave"
    assert all(r.done and len(r.out_tokens) == 5 for r in reqs)


def test_wave_forced_for_recurrent_families():
    cfg = ASSIGNED_ARCHS["mamba2-130m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=32, eos_id=-1)
    assert eng.mode == "wave"  # auto falls back: no attention KV to page
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, max_batch=2, max_seq=32,
                      mode="continuous")
    # prompt must cover the conv window (ssm_conv - 1) for mamba decode
    r = Request(rid=0, prompt=[1, 2, 3, 4], max_new_tokens=4)
    eng.submit(r)
    eng.run()
    assert r.done and len(r.out_tokens) == 4


def test_latency_percentiles_populated(smollm):
    cfg, params = smollm
    reqs = [Request(rid=i, prompt=[1 + i], max_new_tokens=4)
            for i in range(4)]
    eng = _run(cfg, params, reqs)
    s = eng.stats
    assert len(s.latency_s) == 4 and len(s.ttft_s) == 4
    p = s.percentiles("latency_s")
    assert p["p50"] <= p["p90"] <= p["p99"]
    assert all(x >= 0 for x in s.admission_wait_s)
    assert s.summary().startswith("[continuous]")
