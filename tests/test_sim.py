"""Simulator vs the paper's reported numbers (Figs 9/12/13/14) + engine
invariants."""

import pytest

from repro.configs.registry import ARCHS
from repro.core.hw import CAMBRICON_LLM_L, CAMBRICON_LLM_S, FLASH_CONFIGS
from repro.core.schedule import ChannelWorkload, Policy
from repro.core import tiling
from repro.sim import baselines
from repro.sim.engine import simulate_channel
from repro.sim.llm_perf import decode_token_time, flash_only_token_time, \
    prefill_ttft_s


# --- paper Fig. 9 end-to-end numbers (tok/s), tolerance ±20% --------------
PAPER_POINTS = [
    ("opt-6.7b", "S", 3.56), ("opt-6.7b", "M", 10.96), ("opt-6.7b", "L", 36.34),
    ("opt-13b", "M", 4.68), ("opt-30b", "M", 2.50), ("opt-66b", "M", 1.15),
    ("llama2-7b", "S", 3.55), ("llama2-70b", "L", 3.44),
]


@pytest.mark.parametrize("model,cfg_name,target", PAPER_POINTS)
def test_end_to_end_vs_paper(model, cfg_name, target):
    tt = decode_token_time(ARCHS[model], FLASH_CONFIGS[cfg_name], seq_len=1000)
    assert tt.tokens_per_s == pytest.approx(target, rel=0.25), \
        f"{model}@{cfg_name}: {tt.tokens_per_s:.2f} vs paper {target}"


def test_min_interactive_rate_70b():
    """Headline claim: 70B runs at ≥3 tok/s on -L (interactive threshold)."""
    tt = decode_token_time(ARCHS["llama2-70b"], CAMBRICON_LLM_L, seq_len=1000)
    assert tt.tokens_per_s >= 3.0


def test_host_dispatch_gap_pricing():
    """The serving-loop dispatch-gap model: a synchronous loop pays every
    host dispatch gap serially; the overlapped loop hides the gap behind
    compute, so only max(0, gap - compute) can surface.  Defaults price
    an ideal (zero-gap) host, leaving every historical number unchanged."""
    cfg, flash = ARCHS["opt-6.7b"], CAMBRICON_LLM_S
    base = decode_token_time(cfg, flash, seq_len=1000)
    assert base.host_gap_s == 0.0
    gap = 1e-3
    sync = decode_token_time(cfg, flash, seq_len=1000,
                             host_dispatch_s=gap, n_dispatches=2)
    assert sync.total == pytest.approx(base.total + 2 * gap)
    olap = decode_token_time(cfg, flash, seq_len=1000, host_dispatch_s=gap,
                             n_dispatches=1, overlap_dispatch=True)
    # decode compute for 6.7B dwarfs a 1ms dispatch gap: fully hidden
    assert olap.total == pytest.approx(base.total)
    assert olap.host_gap_s == 0.0
    # a gap larger than the whole token's compute can't hide entirely
    huge = decode_token_time(cfg, flash, seq_len=1000,
                             host_dispatch_s=base.total + 0.5,
                             n_dispatches=1, overlap_dispatch=True)
    assert huge.total == pytest.approx(base.total + 0.5)


def test_prefill_ttft_prefix_cache_pricing():
    """TTFT model for prefix-cached prefill: monotone non-increasing in the
    cached token count, a full hit collapses to one decode-step time (the
    engine's zero-dispatch resume admission), and the cached count clamps
    to the prompt (at least one position must always prefill)."""
    cfg, flash = ARCHS["opt-6.7b"], CAMBRICON_LLM_S
    plen = 256
    ts = [prefill_ttft_s(cfg, flash, plen, cached_tokens=c)
          for c in (0, 64, 128, 255)]
    assert all(a > b for a, b in zip(ts, ts[1:]))  # every page cached helps
    # full hit == one token's time; over-reporting the cache clamps to it
    one = decode_token_time(cfg, flash, seq_len=plen).total
    assert ts[-1] == pytest.approx(one)
    assert prefill_ttft_s(cfg, flash, plen, cached_tokens=10_000) == ts[-1]
    assert prefill_ttft_s(cfg, flash, plen, cached_tokens=-5) == ts[0]
    # the cold-vs-hit gap is exactly the serialized per-position NPU phases
    t = decode_token_time(cfg, flash, seq_len=plen)
    assert ts[0] == pytest.approx(one + (plen - 1) * t.npu_phase_time)
    with pytest.raises(ValueError):
        prefill_ttft_s(cfg, flash, 0)


def test_slicing_ablation_speedup():
    """Fig. 12: sliced reads 1.6-1.8x faster than unsliced (we accept >1.25x)."""
    for model in ("opt-6.7b", "llama2-7b"):
        cfg = ARCHS[model]
        t_sliced = decode_token_time(cfg, CAMBRICON_LLM_S,
                                     policy=Policy.RC_SLICED).total
        t_unsliced = decode_token_time(cfg, CAMBRICON_LLM_S,
                                       policy=Policy.RC_UNSLICED).total
        speedup = t_unsliced / t_sliced
        assert speedup > 1.25, f"{model}: slicing speedup {speedup:.2f}"


def test_tiling_ablation_speedup():
    """Fig. 14: hybrid NPU+flash 1.3-1.4x over flash-only."""
    for model in ("opt-6.7b",):
        cfg = ARCHS[model]
        t_hybrid = decode_token_time(cfg, CAMBRICON_LLM_S).total
        t_flash = flash_only_token_time(cfg, CAMBRICON_LLM_S).total
        speedup = t_flash / t_hybrid
        assert 1.1 < speedup < 2.0, f"tiling speedup {speedup:.2f}"


def test_tile_size_sensitivity():
    """Fig. 13: the optimal 256x2048 beats 128x4096 and 4096x128 on -S."""
    cfg = ARCHS["opt-6.7b"]
    t_opt = decode_token_time(cfg, CAMBRICON_LLM_S).total
    t_flat = decode_token_time(
        cfg, CAMBRICON_LLM_S,
        tile_override=tiling.TileShape(128, 4096)).total
    t_tall = decode_token_time(
        cfg, CAMBRICON_LLM_S,
        tile_override=tiling.TileShape(4096, 128)).total
    assert t_opt <= t_flat * 1.001
    assert t_opt <= t_tall * 1.001
    assert t_tall > t_opt * 1.05  # 4096x128 clearly worse (paper: 24.7%)


def test_w4a16_speedup():
    """Fig. 11: W4A16 faster than W8A8; bigger gains on bigger models."""
    s_small = decode_token_time(ARCHS["opt-6.7b"], CAMBRICON_LLM_S)
    s_small4 = decode_token_time(ARCHS["opt-6.7b"], CAMBRICON_LLM_S,
                                 bytes_per_elem=0.5)
    gain_small = s_small.total / s_small4.total
    assert gain_small > 1.3
    s_big = decode_token_time(ARCHS["opt-66b"], CAMBRICON_LLM_S)
    s_big4 = decode_token_time(ARCHS["opt-66b"], CAMBRICON_LLM_S,
                               bytes_per_elem=0.5)
    assert s_big.total / s_big4.total >= gain_small * 0.9


def test_scalability_monotone_channels():
    """Fig. 15: more channels -> faster."""
    import dataclasses

    base = CAMBRICON_LLM_S
    prev = None
    for ch in (4, 8, 16, 32):
        f = dataclasses.replace(base, channels=ch)
        t = decode_token_time(ARCHS["opt-6.7b"], f).total
        if prev is not None:
            assert t < prev * 1.02
        prev = t


def test_chip_scaling_saturates():
    """Fig. 15: chips-per-channel growth saturates (channel bus bound)."""
    import dataclasses

    t8 = decode_token_time(ARCHS["opt-6.7b"], dataclasses.replace(
        CAMBRICON_LLM_S, chips_per_channel=8)).total
    t64 = decode_token_time(ARCHS["opt-6.7b"], dataclasses.replace(
        CAMBRICON_LLM_S, chips_per_channel=64)).total
    assert t64 < t8  # still faster
    assert t8 / t64 < 8  # but far from linear in chips


def test_channel_sim_conservation():
    """Event sim: bus-busy time == sum of scheduled transfer durations and
    completion covers all reads."""
    w = ChannelWorkload(n_tiles=10, rc_input_bytes=256, rc_result_bytes=256,
                        n_reads=16, page_bytes=16384, t_r=30e-6, bw=1e9)
    for pol in Policy:
        res = simulate_channel(w, pol)
        assert res.time >= res.rc_done - 1e-12
        expected_rc = 10 * (512) / 1e9
        expected_reads = 0 if pol == Policy.RC_ONLY else 16 * 16384 / 1e9
        assert res.bus_busy == pytest.approx(expected_rc + expected_reads,
                                             rel=1e-6)
        assert 0 <= res.util <= 1.0


def test_baselines_match_paper_calibration():
    assert baselines.flexgen_ssd_tokens_per_s(ARCHS["opt-6.7b"]) == \
        pytest.approx(0.81, rel=0.2)
    assert baselines.flexgen_dram_tokens_per_s(ARCHS["opt-6.7b"]) == \
        pytest.approx(3.52, rel=0.2)
    assert baselines.mlc_llm_tokens_per_s(ARCHS["llama2-7b"]) == \
        pytest.approx(7.58, rel=0.25)
    assert baselines.mlc_llm_fits_dram(ARCHS["llama2-7b"])
    assert not baselines.mlc_llm_fits_dram(ARCHS["llama2-70b"])


def test_speedup_vs_flexgen_ssd():
    """Headline: 22-45x faster than Flexgen-SSD on -L."""
    for model, lo in [("opt-66b", 15.0), ("opt-6.7b", 25.0)]:
        ours = decode_token_time(ARCHS[model], CAMBRICON_LLM_L).tokens_per_s
        theirs = baselines.flexgen_ssd_tokens_per_s(ARCHS[model])
        assert ours / theirs > lo, f"{model}: {ours/theirs:.1f}x"
