"""Scheduler policy layer: FCFS / priority / SJF / DRR, per-request
sampling, and the streaming RequestOutput contract.

Policy decisions are pinned against small greedy oracles (explicit
expected orders), and the two preemption seams are exercised end-to-end:
priority inversion (a high-priority arrival preempts a running
low-priority slot via ``AdmitPlan.preempt``) and pool-pressure victim
selection (``scheduler.victim`` picks the low-priority slot to suspend
under ``kv_tier="flash"``).
"""

import jax
import pytest

from repro.configs.registry import ASSIGNED_ARCHS
from repro.models import model as M
from repro.serving.engine import Request, RequestOutput, ServingEngine
from repro.serving.scheduler import (DRRScheduler, EDFScheduler,
                                     FCFSScheduler, PriorityScheduler,
                                     SJFScheduler, SamplingParams, SlotView,
                                     make_scheduler)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    return cfg, params


def _req(rid, cost=8, priority=0, arrival=0.0):
    """cost tokens split evenly between prompt and decode budget."""
    return Request(rid=rid, prompt=[1] * (cost // 2),
                   max_new_tokens=cost - cost // 2, priority=priority,
                   arrival_s=arrival)


def _view(index, priority=0, seq_len=8, rid=None):
    return SlotView(index=index, rid=rid if rid is not None else index,
                    priority=priority, arrival_s=0.0, seq_len=seq_len,
                    n_out=2, remaining=4, prefilling=False, suspended=False)


# ---------------------------------------------------------------- registry
def test_make_scheduler_registry():
    assert isinstance(make_scheduler(None), FCFSScheduler)
    assert isinstance(make_scheduler("priority"), PriorityScheduler)
    assert make_scheduler("drr", quantum=16).quantum == 16
    sched = SJFScheduler(chunk_tokens=4)
    assert make_scheduler(sched) is sched
    with pytest.raises(ValueError):
        make_scheduler("lifo")
    for name in ("fcfs", "priority", "sjf", "drr", "edf"):
        assert make_scheduler(name).name == name


def test_prefill_budget_default_and_chunked():
    assert FCFSScheduler().prefill_budget(_view(0)) >= 1 << 30
    assert FCFSScheduler(chunk_tokens=16).prefill_budget(_view(0)) == 16


# ---------------------------------------------------------------- policies
def test_fcfs_admit_keeps_queue_order_and_longest_victim():
    sched = FCFSScheduler()
    q = [_req(3, arrival=3.0), _req(1, arrival=1.0), _req(2, arrival=2.0)]
    plan = sched.admit(q, [None, None], free_pages=100)
    assert [r.rid for r in plan.order] == [3, 1, 2]  # engine order, as-is
    assert plan.preempt == []
    views = [_view(0, seq_len=5), _view(1, seq_len=20), _view(2, seq_len=9)]
    assert sched.victim(views) == 1  # longest frees the most pages


def test_priority_admit_order_and_preempt_decision():
    sched = PriorityScheduler()
    q = [_req(1, priority=0, arrival=1.0), _req(2, priority=5, arrival=2.0),
         _req(3, priority=5, arrival=0.5)]
    # free slot available: no preemption, order by (prio desc, arrival)
    plan = sched.admit(q, [None, _view(9, priority=1)], free_pages=100)
    assert [r.rid for r in plan.order] == [3, 2, 1]
    assert plan.preempt == []
    # full batch, head outranks the lowest-priority slot: preempt it
    slots = [_view(0, priority=1, seq_len=4), _view(1, priority=0, seq_len=6)]
    plan = sched.admit(q, slots, free_pages=100)
    assert plan.preempt == [1]
    # full batch but nothing outranked: no preemption
    slots = [_view(0, priority=9), _view(1, priority=9)]
    assert sched.admit(q, slots, free_pages=100).preempt == []
    # victim under page pressure: lowest priority first, then longest
    views = [_view(0, priority=2, seq_len=30), _view(1, priority=0, seq_len=4),
             _view(2, priority=0, seq_len=12)]
    assert sched.victim(views) == 2


def test_sjf_admit_order_oracle():
    sched = SJFScheduler()
    q = [_req(1, cost=20), _req(2, cost=6), _req(3, cost=12), _req(4, cost=6,
         arrival=9.0)]
    plan = sched.admit(q, [None], free_pages=100)
    # shortest first; equal costs tie-break by arrival
    assert [r.rid for r in plan.order] == [2, 4, 3, 1]


def test_drr_alternates_classes_oracle():
    """quantum == cost: each class affords exactly one admission per round,
    so the admission order strictly alternates classes — the hand-computed
    DRR schedule [a1, b1, a2, b2, a3, b3]."""
    sched = DRRScheduler(quantum=8)
    a = [_req(10 + i, cost=8, priority=0, arrival=i) for i in range(3)]
    b = [_req(20 + i, cost=8, priority=1, arrival=i) for i in range(3)]
    queue = a + b
    admitted = []
    while queue:
        plan = sched.admit(list(queue), [None], free_pages=100)
        assert len(plan.order) == 1  # one free slot -> one admission
        admitted.append(plan.order[0].rid)
        queue.remove(plan.order[0])
    assert admitted == [10, 20, 11, 21, 12, 22]


def test_drr_shares_tokens_not_requests():
    """Class 0's requests cost half as much, so each quantum round admits
    TWO cheap requests against ONE costly: token bandwidth, not request
    count, is the fair-shared quantity."""
    sched = DRRScheduler(quantum=8)
    cheap = [_req(10 + i, cost=4, priority=0) for i in range(4)]
    costly = [_req(20 + i, cost=8, priority=1) for i in range(2)]
    plan = sched.admit(cheap + costly, [None] * 6, free_pages=100)
    # round 1: class0 affords 10+11, class1 affords 20; round 2: 12+13, 21
    assert [r.rid for r in plan.order] == [10, 11, 20, 12, 13, 21]


def test_drr_no_accrual_without_free_slots():
    sched = DRRScheduler(quantum=100)
    q = [_req(1, cost=8)]
    plan = sched.admit(q, [_view(0)], free_pages=100)  # batch full
    assert plan.order == [] and sched._deficit == {}


def test_edf_admit_order_oracle():
    """EDF orders by ABSOLUTE deadline (arrival + SLO budget); requests
    without a deadline sort last, FCFS among themselves."""
    sched = EDFScheduler()
    q = [_req(1, arrival=0.0), _req(2, arrival=4.0), _req(3, arrival=1.0),
         _req(4, arrival=0.5)]
    q[0].deadline_s = 10.0   # absolute 10.0
    q[1].deadline_s = 2.0    # absolute  6.0  <- most urgent
    q[2].deadline_s = 7.0    # absolute  8.0
    q[3].deadline_s = None   # no SLO: last
    plan = sched.admit(q, [None] * 4, free_pages=100)
    assert [r.rid for r in plan.order] == [2, 3, 1, 4]
    # all-deadline-free queue degenerates to FCFS by arrival
    free = [_req(1, arrival=3.0), _req(2, arrival=1.0)]
    assert [r.rid for r in sched.admit(free, [None], 100).order] == [2, 1]


def test_edf_victim_evicts_slackest_slot():
    """Under pool pressure EDF suspends the slot with the LATEST absolute
    deadline; slots without a deadline are infinitely slack and go first;
    ties break toward the longest sequence (frees the most pages)."""
    import dataclasses as dc
    sched = EDFScheduler()
    views = [dc.replace(_view(0, seq_len=30), deadline_s=5.0),
             dc.replace(_view(1, seq_len=4), deadline_s=50.0),
             dc.replace(_view(2, seq_len=12), deadline_s=20.0)]
    assert sched.victim(views) == 1  # latest deadline, despite tiny seq
    views.append(dc.replace(_view(3, seq_len=2), deadline_s=None))
    assert sched.victim(views) == 3  # no SLO at all: evicted first
    tied = [dc.replace(_view(0, seq_len=3), deadline_s=None),
            dc.replace(_view(1, seq_len=9), deadline_s=None)]
    assert sched.victim(tied) == 1  # tie -> longest


# ---------------------------------------------------- engine integration
def test_engine_sjf_completion_order(smollm):
    """1-slot engine: SJF must complete jobs in cost order regardless of
    submission order (FCFS would finish rid 1 first)."""
    cfg, params = smollm
    reqs = [Request(rid=1, prompt=[2] * 4, max_new_tokens=12),
            Request(rid=2, prompt=[3] * 2, max_new_tokens=3),
            Request(rid=3, prompt=[4] * 3, max_new_tokens=6)]
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=48, eos_id=-1,
                        page_size=8, scheduler="sjf")
    for r in reqs:
        eng.submit(r)
    finish_order = [e.rid for e in eng.stream() if e.finished]
    assert finish_order == [2, 3, 1]
    assert eng.stats.policy == "sjf"


def test_engine_drr_completion_alternates(smollm):
    cfg, params = smollm
    a = [Request(rid=10 + i, prompt=[2] * 4, max_new_tokens=4, priority=0)
         for i in range(2)]
    b = [Request(rid=20 + i, prompt=[3] * 4, max_new_tokens=4, priority=1)
         for i in range(2)]
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=48, eos_id=-1,
                        page_size=8, scheduler=DRRScheduler(quantum=8))
    for r in a + b:
        eng.submit(r)
    finish_order = [e.rid for e in eng.stream() if e.finished]
    assert finish_order == [10, 20, 11, 21]


def test_engine_edf_completion_order(smollm):
    """1-slot engine: EDF must serve in deadline order regardless of
    submission order, and the finished requests report deadline_missed
    correctly against their own SLO budgets."""
    cfg, params = smollm
    reqs = [Request(rid=1, prompt=[2] * 3, max_new_tokens=4, arrival_s=0.0,
                    deadline_s=500.0),
            Request(rid=2, prompt=[3] * 3, max_new_tokens=4, arrival_s=0.0,
                    deadline_s=100.0),
            Request(rid=3, prompt=[4] * 3, max_new_tokens=4, arrival_s=0.0,
                    deadline_s=300.0)]
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=48, eos_id=-1,
                        page_size=8, scheduler="edf")
    for r in reqs:
        eng.submit(r)
    finish_order = [e.rid for e in eng.stream() if e.finished]
    assert finish_order == [2, 3, 1]
    assert eng.stats.policy == "edf"
    assert not any(r.deadline_missed for r in reqs)  # sub-second run
    # a missed deadline is visible on the request itself
    late = Request(rid=9, prompt=[1], max_new_tokens=2, deadline_s=1e-9)
    eng.submit(late)
    eng.run()
    assert late.done and late.deadline_missed


def test_engine_priority_inversion_preempts_via_victim(smollm):
    """Pinned: a high-priority arrival at a full batch preempts the running
    low-priority slot (via the plan's victim seam) under kv_tier='flash' and
    finishes first; the preempted request still completes in full."""
    cfg, params = smollm
    lo = Request(rid=1, prompt=[7] * 4, max_new_tokens=16, priority=0)
    hi = Request(rid=2, prompt=[9] * 3, max_new_tokens=4, priority=5)
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=48, eos_id=-1,
                        page_size=8, kv_tier="flash", scheduler="priority")
    eng.submit(lo)
    for _ in range(3):
        eng.step()
    assert not lo.done
    eng.submit(hi)
    eng.run()
    assert hi.done and lo.done and not lo.rejected
    assert hi.t_done < lo.t_done  # no priority inversion
    assert lo.n_preempted >= 1 and hi.n_preempted == 0
    assert len(hi.out_tokens) == 4 and len(lo.out_tokens) == 16
    assert eng.stats.preemptions >= 1


def test_engine_priority_victim_shields_high_priority(smollm):
    """Pool pressure in a tiered 2-slot engine: scheduler.victim suspends
    the LOW-priority slot's pages, never the high-priority one's."""
    cfg, params = smollm
    lo = Request(rid=1, prompt=[2] * 6, max_new_tokens=14, priority=0)
    hi = Request(rid=2, prompt=[3] * 6, max_new_tokens=14, priority=5)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                        page_size=8, num_pages=5, kv_tier="flash",
                        scheduler="priority")
    eng.submit(lo)
    eng.submit(hi)
    eng.run()
    assert lo.done and hi.done
    assert eng.stats.preemptions >= 1
    assert hi.n_preempted == 0 and lo.n_preempted >= 1


def test_engine_policies_complete_tiered_trace(smollm):
    """All four policies drive the capacity-constrained tiered pool to 100%
    completion (the bench acceptance bar, in miniature)."""
    cfg, params = smollm
    for policy in ("fcfs", "priority", "sjf", "drr"):
        reqs = [Request(rid=i, prompt=[2 + i] * (3 + i),
                        max_new_tokens=10 + i, priority=i % 3)
                for i in range(5)]
        eng = ServingEngine(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                            page_size=8, num_pages=6, kv_tier="flash",
                            scheduler=make_scheduler(policy, chunk_tokens=4))
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done and not r.rejected for r in reqs), policy
        assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs), \
            policy


# ------------------------------------------------------- sampling contract
def test_sampling_seed_pinned_and_per_request(smollm):
    """Per-request SamplingParams: a greedy and a stochastic request share
    one batch without cross-talk, and a pinned seed reproduces the exact
    sample stream across runs."""
    cfg, params = smollm

    def serve(reqs):
        eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                            page_size=8)
        for r in reqs:
            eng.submit(r)
        eng.run()
        return reqs

    greedy_solo = Request(rid=1, prompt=[3, 1, 4], max_new_tokens=8)
    serve([greedy_solo])

    def pair(seed):
        g = Request(rid=1, prompt=[3, 1, 4], max_new_tokens=8)
        s = Request(rid=2, prompt=[5, 6], max_new_tokens=8,
                    sampling=SamplingParams(temperature=1.0, top_k=20,
                                            seed=seed))
        serve([g, s])
        return g, s

    g1, s1 = pair(seed=7)
    g2, s2 = pair(seed=7)
    # greedy row is untouched by its stochastic neighbor
    assert g1.out_tokens == greedy_solo.out_tokens == g2.out_tokens
    # seed-pinned: identical stream across runs
    assert s1.out_tokens == s2.out_tokens
    # a different seed diverges (vocab is large; 8 tokens colliding is ~0)
    _, s3 = pair(seed=8)
    assert s3.out_tokens != s1.out_tokens


def test_sampling_top_k_one_is_greedy(smollm):
    cfg, params = smollm
    g = Request(rid=1, prompt=[2, 7, 1], max_new_tokens=6)
    k1 = Request(rid=2, prompt=[2, 7, 1], max_new_tokens=6,
                 sampling=SamplingParams(temperature=0.9, top_k=1, seed=0))
    for r in (g, k1):
        eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                            page_size=8)
        eng.submit(r)
        eng.run()
    assert k1.out_tokens == g.out_tokens


def test_legacy_temperature_field_folds_into_sampling():
    r = Request(rid=0, prompt=[1], temperature=0.5)
    assert r.sampling.temperature == 0.5
    r2 = Request(rid=1, prompt=[1],
                 sampling=SamplingParams(temperature=0.9))
    assert r2.sampling.temperature == 0.9


# ------------------------------------------------------ streaming contract
def test_stream_yields_incremental_outputs(smollm):
    """RequestOutput events arrive token-by-token, interleaved across
    concurrent requests, and concatenate to exactly each request's
    out_tokens; final events carry finish_reason + scheduler stats."""
    cfg, params = smollm
    r1 = Request(rid=1, prompt=[1, 2, 3], max_new_tokens=6)
    r2 = Request(rid=2, prompt=[4, 5], max_new_tokens=6)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                        page_size=8)
    eng.submit(r1)
    eng.submit(r2)
    events = list(eng.stream())
    assert all(isinstance(e, RequestOutput) for e in events)
    toks = {1: [], 2: []}
    for e in events:
        if e.token is not None:
            toks[e.rid].append(e.token)
    assert toks[1] == r1.out_tokens and toks[2] == r2.out_tokens
    finals = [e for e in events if e.finished]
    assert len(finals) == 2
    for e in finals:
        assert e.finish_reason == "length"
        assert e.sched is not None and e.sched["preemptions"] == 0
        assert e.latency_s is not None and e.latency_s >= 0
    # incremental: both requests emit before either finishes
    first_final = min(i for i, e in enumerate(events) if e.finished)
    assert {e.rid for e in events[:first_final]} == {1, 2}
    # nothing left after the stream is drained
    assert eng.drain_outputs() == []


def test_finish_reason_eos(smollm):
    cfg, params = smollm
    probe = Request(rid=0, prompt=[3, 1, 4], max_new_tokens=8)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                        page_size=8)
    eng.submit(probe)
    eng.run()
    eos = probe.out_tokens[2]
    r = Request(rid=1, prompt=[3, 1, 4], max_new_tokens=8)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=eos,
                        page_size=8)
    eng.submit(r)
    finals = [e for e in eng.stream() if e.finished]
    assert r.finish_reason == "eos"
    assert finals[0].finish_reason == "eos" and finals[0].token == eos


def test_rejected_request_emits_final_event(smollm):
    cfg, params = smollm
    reqs = [Request(rid=i, prompt=[2 + i] * (3 + i),
                    max_new_tokens=12 + 2 * i) for i in range(5)]
    eng = ServingEngine(cfg, params, max_batch=3, max_seq=48, eos_id=-1,
                        page_size=8, num_pages=6, exhaust_policy="reject")
    for r in reqs:
        eng.submit(r)
    events = list(eng.stream())
    rejected = [r for r in reqs if r.rejected]
    assert rejected and eng.stats.rejected == len(rejected)
    for r in rejected:
        # rejection may hit at admission (no events yet) or mid-decode
        # (token events already streamed); either way the LAST event is the
        # single terminal rejected one
        assert r.finish_reason == "rejected"
        evs = [e for e in events if e.rid == r.rid]
        assert evs[-1].finished and evs[-1].finish_reason == "rejected"
        assert evs[-1].token is None
        assert sum(1 for e in evs if e.finished) == 1
        assert sum(1 for e in evs if e.token is not None) == \
            len(r.out_tokens)


def test_wave_mode_streams_and_honors_scheduler(smollm):
    """Wave mode: the scheduler orders the wave, events still stream."""
    cfg, params = smollm
    reqs = [Request(rid=1, prompt=[2] * 2, max_new_tokens=8, priority=0),
            Request(rid=2, prompt=[3] * 2, max_new_tokens=3, priority=4)]
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=48, eos_id=-1,
                        mode="wave", scheduler="priority")
    for r in reqs:
        eng.submit(r)
    finish_order = [e.rid for e in eng.stream() if e.finished]
    assert finish_order == [2, 1]  # high priority served first
    assert all(r.done for r in reqs)
