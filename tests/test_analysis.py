"""Correctness-tooling tests: reprolint rules + sanitizer rails.

Layer 1 (``tools.analysis.reprolint``) is pinned by a known-bad fixture
corpus: every rule must flag a distilled reproduction of the historical
bug it encodes AND stay silent on the fixed twin — so a rule can neither
rot (stops firing) nor creep (starts firing on the sanctioned idiom).

Layer 2 (``tools.analysis.sanitize``) is pinned from both sides: a
seeded random-op property test proves the shadow page model agrees with
a healthy allocator, and injected corruptions (double-alloc of a live
page, free-while-shared, hot+cold residency) prove divergence is caught
loudly.  The end-to-end test runs a real overlapped+tiered+prefix-cache
engine under ``REPRO_SANITIZE=1`` and asserts the rails ran clean.
"""

import random
import textwrap

import pytest

from tools.analysis import sanitize
from tools.analysis.reprolint import run as lint_run


def _lint(tmp_path, code, rule, filename="snippet.py"):
    f = tmp_path / filename
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(code))
    findings, errors = lint_run([str(f)], select=[rule])
    assert not errors, errors
    return findings


# ======================================================================
# Layer 1: the known-bad / known-good fixture corpus
# ======================================================================
def test_async_aliasing_flags_uncopied_host_buffer(tmp_path):
    bad = """
        class E:
            def round(self):
                tok, cache = self._decode_sample(
                    self.params, self.last_np, tok_dev,
                    {**self.cache, "block": self.block})
    """
    found = _lint(tmp_path, bad, "async-aliasing")
    assert {f.message.split("`")[1] for f in found} == {
        "self.last_np", "self.block"}


def test_async_aliasing_accepts_copied_buffer(tmp_path):
    good = """
        class E:
            def round(self):
                tok, cache = self._decode_sample(
                    self.params, self.last_np.copy(), tok_dev,
                    {**self.cache, "block": self.block.copy()})
    """
    assert _lint(tmp_path, good, "async-aliasing") == []


def test_pallas_raw_index_flags_raw_int(tmp_path):
    # the ecc_decode bug: raw 0 in the pl.store index tuple
    bad = """
        def kernel(out_ref, addr):
            pl.store(out_ref, (0, pl.ds(addr, 1)), val)
    """
    found = _lint(tmp_path, bad, "pallas-raw-index")
    assert len(found) == 1 and "int constant" in found[0].message


def test_pallas_raw_index_accepts_ds_everywhere(tmp_path):
    good = """
        def kernel(out_ref, addr):
            pl.store(out_ref, (pl.ds(0, 1), pl.ds(addr, 1)), val)
            x = q_ref[0]          # raw ref subscripts are fine
            y = pickle.load(f)    # non-pallas load untouched
    """
    assert _lint(tmp_path, good, "pallas-raw-index") == []


def test_boolean_select_trap_flags_numeric_and_sentinel(tmp_path):
    bad = """
        _NO_BUDGET = 1 << 30
        def f(arrival_s, chunk):
            t = (arrival_s or 0.0) + 1.0
            budget = chunk or _NO_BUDGET
            return t, budget
    """
    found = _lint(tmp_path, bad, "boolean-select-trap")
    assert len(found) == 2


def test_boolean_select_trap_flags_and_or_chain(tmp_path):
    found = _lint(tmp_path, "y = cond and a or b\n", "boolean-select-trap")
    assert len(found) == 1 and "a and b or c" in found[0].message


def test_boolean_select_trap_accepts_truth_tests_and_none_check(tmp_path):
    good = """
        def f(x, flags):
            if x or 0:              # truth test: no value escapes
                pass
            while flags or 0:
                break
            v = 0.0 if x is None else x
            d = flags or {}         # result-equivalent default: fine
            return v, d
    """
    assert _lint(tmp_path, good, "boolean-select-trap") == []


def test_boolean_select_trap_pragma_suppresses(tmp_path):
    code = """
        def f(x):
            # reprolint: ok boolean-select-trap — 0 is not a valid x here
            return x or 1000
    """
    assert _lint(tmp_path, code, "boolean-select-trap") == []


def test_donation_use_after_flags_stale_read(tmp_path):
    bad = """
        import jax
        step = jax.jit(fn, donate_argnums=(1,))
        def loop(params, cache):
            out, new_cache = step(params, cache)
            return cache["k"]   # stale: cache was donated to step()
    """
    found = _lint(tmp_path, bad, "donation-use-after")
    assert len(found) == 1 and "`cache`" in found[0].message


def test_donation_use_after_accepts_rebind(tmp_path):
    good = """
        import jax
        step = jax.jit(fn, donate_argnums=(1,))
        def loop(params, cache):
            out, cache = step(params, cache)
            return cache["k"]   # rebound: reads the NEW buffer
    """
    assert _lint(tmp_path, good, "donation-use-after") == []


def test_wire_field_drift_flags_both_directions(tmp_path):
    (tmp_path / "proj" / "fleet").mkdir(parents=True)
    (tmp_path / "proj" / "fleet" / "wire.py").write_text(textwrap.dedent("""
        WIRE_FIELDS = {"Thing": ("a", "ghost")}
    """))
    (tmp_path / "proj" / "models.py").write_text(textwrap.dedent("""
        import dataclasses
        @dataclasses.dataclass
        class Thing:
            a: int
            b: int = 0
    """))
    findings, errors = lint_run([str(tmp_path / "proj")],
                                select=["wire-field-drift"])
    assert not errors
    msgs = "\n".join(f.message for f in findings)
    assert "field `b` of Thing is missing" in msgs
    assert "`Thing.ghost`" in msgs and "stale" in msgs


def test_wire_field_drift_clean_when_in_sync(tmp_path):
    (tmp_path / "proj" / "fleet").mkdir(parents=True)
    (tmp_path / "proj" / "fleet" / "wire.py").write_text(
        'WIRE_FIELDS = {"Thing": ("a", "b")}\n')
    (tmp_path / "proj" / "models.py").write_text(textwrap.dedent("""
        import dataclasses
        @dataclasses.dataclass
        class Thing:
            a: int
            b: int = 0
    """))
    findings, _ = lint_run([str(tmp_path / "proj")],
                           select=["wire-field-drift"])
    assert findings == []


def test_wire_field_drift_flags_missing_manifest(tmp_path):
    (tmp_path / "proj" / "fleet").mkdir(parents=True)
    (tmp_path / "proj" / "fleet" / "wire.py").write_text("TAGS = {}\n")
    findings, _ = lint_run([str(tmp_path / "proj")],
                           select=["wire-field-drift"])
    assert len(findings) == 1 and "no WIRE_FIELDS manifest" in \
        findings[0].message


def test_nondeterminism_flags_hot_path_only(tmp_path):
    bad = """
        import numpy as np, time, jax
        def sample():
            noise = np.random.rand(4)
            t0 = time.time()
            key = jax.random.PRNGKey(int(time.time()))
            return noise, t0, key
    """
    # same code, hot path vs elsewhere; the PRNGKey line yields two
    # findings (the embedded time.time() call AND the tainted seed)
    hot = _lint(tmp_path, bad, "nondeterminism",
                filename="src/repro/serving/x.py")
    cold = _lint(tmp_path, bad, "nondeterminism", filename="bench/x.py")
    assert len(hot) == 4 and cold == []
    assert any("np.random" in f.message for f in hot)
    assert any("seeded from nondeterministic" in f.message for f in hot)


def test_nondeterminism_accepts_seeded_and_monotonic(tmp_path):
    good = """
        import time, jax
        def sample(seed, i):
            t0 = time.monotonic()
            key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
            return t0, key
    """
    assert _lint(tmp_path, good, "nondeterminism",
                 filename="src/repro/serving/x.py") == []


def test_jit_in_loop_flags_and_accepts_hoisted(tmp_path):
    bad = """
        import jax
        def serve(steps):
            for _ in range(steps):
                f = jax.jit(body)     # recompiles every iteration
                f(x)
    """
    good = """
        import jax
        f = jax.jit(body)
        def serve(steps):
            for _ in range(steps):
                f(x)
    """
    assert len(_lint(tmp_path, bad, "jit-in-loop")) == 1
    assert _lint(tmp_path, good, "jit-in-loop", "good.py") == []


def test_mutable_default_flags_display_and_ctor(tmp_path):
    bad = """
        import numpy as np
        def f(acc=[], buf=np.zeros(4)):
            return acc, buf
    """
    good = """
        def f(acc=None, buf=()):
            acc = [] if acc is None else acc
            return acc, buf
    """
    assert len(_lint(tmp_path, bad, "mutable-default")) == 2
    assert _lint(tmp_path, good, "mutable-default", "good.py") == []


def test_silent_except_flags_bare_and_broad_pass(tmp_path):
    bad = """
        def f():
            try:
                g()
            except:
                pass
        def h():
            try:
                g()
            except Exception:
                pass
    """
    good = """
        def f(log):
            try:
                g()
            except OSError:
                pass            # narrow best-effort close: accepted
            try:
                g()
            except Exception as e:
                log.warning(e)  # recorded: accepted
    """
    assert len(_lint(tmp_path, bad, "silent-except")) == 2
    assert _lint(tmp_path, good, "silent-except", "good.py") == []


def test_lint_reports_parse_errors(tmp_path):
    (tmp_path / "broken.py").write_text("def f(:\n")
    findings, errors = lint_run([str(tmp_path)])
    assert len(errors) == 1 and "broken.py" in errors[0]


def test_repo_tree_is_clean():
    """The merged tree lints clean — the acceptance gate CI enforces."""
    findings, errors = lint_run(["src", "tests"])
    assert errors == []
    assert findings == [], "\n".join(f.render() for f in findings)


# ======================================================================
# Layer 2: sanitizer rails
# ======================================================================
@pytest.fixture(autouse=True)
def _fresh_counters():
    sanitize.reset_counters()
    yield
    sanitize.reset_counters()


def _shadowed_alloc(num_pages):
    from repro.serving.kv_cache import PageAllocator
    a = PageAllocator(num_pages)
    sanitize.attach_page_shadow(a)
    return a


def test_shadow_model_agrees_with_healthy_allocator():
    """Property test: a contract-respecting random op sequence never trips
    the shadow, and the real/model views stay identical throughout."""
    rng = random.Random(1234)
    a = _shadowed_alloc(32)
    live = []          # pages with refcount >= 1
    parked = []        # refcount 0 (idle cached): still freeable
    for _ in range(400):
        op = rng.choice(["alloc", "free", "incref", "decref"])
        if op == "alloc" and a.available:
            live += a.alloc(rng.randint(1, min(3, a.available)))
        elif op == "free" and (live or parked):
            src = live if (live and (not parked or rng.random() < 0.7)) \
                else parked
            p = src.pop(rng.randrange(len(src)))
            if src is live and a.refcount(p) > 1:
                a.decref(p)      # drop sharers first, as the engine does
                live.append(p)
                continue
            a.free([p])
        elif op == "incref" and live:
            a.incref(rng.choice(live))
        elif op == "decref" and live:
            p = live[rng.randrange(len(live))]
            if a.decref(p) == 0:
                live.remove(p)
                parked.append(p)
    assert sanitize.report_count() == 0
    assert sanitize.check_count() > 0
    assert a.available == len(a._shadow.free)


def test_shadow_model_catches_double_alloc_of_live_page():
    """Inject the double-free bug class: the free list hands out a page
    that is still live.  The real allocator trusts its (corrupted) free
    list; the shadow does not."""
    a = _shadowed_alloc(8)
    p = a.alloc(1)[0]
    a._free.append(p)          # simulated free-list corruption
    a._free_set.add(p)
    with pytest.raises(sanitize.SanitizerError, match="already live"):
        a.alloc(8 - 1)         # pops the corrupted entry eventually
    assert sanitize.report_count() == 1


def test_shadow_model_catches_free_while_shared():
    """Inject a refcount undercount: the real allocator thinks the page
    has one owner and accepts the free; the shadow knows a sharer
    remains."""
    a = _shadowed_alloc(8)
    p = a.alloc(1)[0]
    a.incref(p)                # two sharers (model refs = 2)
    a._refs[p] = 1             # simulated refcount corruption
    with pytest.raises(sanitize.SanitizerError, match="freed while shared"):
        a.free([p])
    assert sanitize.report_count() == 1


def test_tier_shadow_catches_hot_and_cold_residency():
    """``store`` of a key that is still eviction-marked hot: the real
    tier accepts it (store does not consult the eviction queue); the
    shadow flags the double residency."""
    from repro.serving.kv_cache import TieredPageAllocator
    t = TieredPageAllocator(8, flash_pages=4)
    sanitize.attach_page_shadow(t.hot)
    sanitize.attach_tier_shadow(t)
    t.mark_evictable(("s", 0), 1)
    with pytest.raises(sanitize.SanitizerError, match="hot\\+cold"):
        t.store(("s", 0), b"payload")
    assert sanitize.report_count() == 1


def test_tier_shadow_clean_on_spill_prefetch_cycle():
    from repro.serving.kv_cache import TieredPageAllocator
    t = TieredPageAllocator(8, flash_pages=4)
    sanitize.attach_page_shadow(t.hot)
    sanitize.attach_tier_shadow(t)
    pids = t.alloc(2)
    for i, p in enumerate(pids):
        t.mark_evictable(("s", i), p)
    popped = t.pop_evictable(2)
    for (key, pid) in popped:
        t.store(key, f"blob{pid}".encode())
        t.free([pid])
    for key, _pid in popped:           # prefetch back
        assert t.fetch(key).startswith(b"blob")
    t.drop_slot(lambda k: k[0] == "s")
    assert sanitize.report_count() == 0
    assert sanitize.check_count() > 0


def test_dispatch_guard_catches_mutated_arg():
    import numpy as np
    buf = np.arange(8, dtype=np.int32)
    ok = sanitize.guard_dispatch(0, last_np=buf.copy())
    sanitize.check_drain(ok)           # untouched copy: clean
    racy = sanitize.guard_dispatch(1, last_np=buf)
    buf[3] = 99                        # host mutates while step in flight
    with pytest.raises(sanitize.SanitizerError, match="last_np"):
        sanitize.check_drain(racy)


def test_retrace_budget():
    class Fake:
        def __init__(self, n):
            self._cache_size = lambda: n
    sanitize.check_retrace(Fake(3), "ok", budget=8)
    with pytest.raises(sanitize.SanitizerError, match="retrace budget"):
        sanitize.check_retrace(Fake(9), "hot", budget=8)
    sanitize.check_retrace(object(), "no-surface", budget=1)  # no-op


def test_wire_manifest_runtime_check():
    from repro.serving.core import Request, RequestOutput, SlotSnapshot
    from repro.serving.fleet.wire import WIRE_FIELDS
    from repro.serving.scheduler import SamplingParams
    classes = {"Request": Request, "SamplingParams": SamplingParams,
               "RequestOutput": RequestOutput, "SlotSnapshot": SlotSnapshot}
    sanitize.check_wire_manifest(WIRE_FIELDS, classes)   # in sync today
    pruned = dict(WIRE_FIELDS)
    pruned["Request"] = WIRE_FIELDS["Request"][:-1]
    with pytest.raises(sanitize.SanitizerError, match="not covered"):
        sanitize.check_wire_manifest(pruned, classes)


# ======================================================================
# end-to-end: a real engine under REPRO_SANITIZE=1
# ======================================================================
def test_sanitized_engine_matches_plain_engine(monkeypatch):
    """Overlapped + tiered + prefix-cache decode with every rail armed:
    zero reports, rails demonstrably exercised, and the token streams
    bit-identical to an un-sanitized sync engine."""
    import jax
    from repro.configs.registry import ASSIGNED_ARCHS
    from repro.models import model as M
    from repro.serving.engine import Request, ServingEngine

    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), max_seq=64)

    def serve(**kw):
        reqs = [Request(rid=i, prompt=[2 + i, 5], max_new_tokens=6)
                for i in range(3)]
        eng = ServingEngine(cfg, params, max_batch=2, max_seq=48,
                            eos_id=-1, page_size=8, **kw)
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [r.out_tokens for r in reqs]

    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    baseline = serve()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sanitize.reset_counters()
    sanitized = serve(overlap=True, kv_tier="flash", num_pages=6,
                      prefix_cache=True)
    assert sanitized == baseline
    assert sanitize.report_count() == 0
    assert sanitize.check_count() > 0
