"""Prefix caching conformance: refcounted copy-on-write KV page sharing.

The load-bearing oracle is bit-identity against a cold cache: admitting a
prompt through the prefix cache — an exact-prompt resume hit (zero prefill
dispatches, the stored prefill logits replayed), a partial page-level hit
(only the uncached suffix prefills, via the chunk path), tiered
spill/prefetch of idle shared pages, cross-replica migration of a slot
mapping shared pages — must emit exactly the tokens of a
``prefix_cache=False`` run, greedy AND seed-pinned stochastic.  That holds
because only PREFILL-written pages are registered (decode-written KV bits
may differ, the requeue caveat), keyed by a sha256 chain over page-aligned
token spans, so equal keys imply bit-identical page contents.

Cross-family: every test parametrized over ``fam`` runs for all five paged
families (``make test-families`` / ``pytest -k fam_<family>``).
"""

import numpy as np
import pytest

from repro.serving.core import EngineCore, Request
from repro.serving.kv_cache import PrefixIndex, ResumeEntry
from repro.serving.router import Router
from repro.serving.scheduler import SamplingParams

from conftest import load_family

ENG_KW = dict(max_batch=2, max_seq=64, eos_id=-1, page_size=8)
PROMPT = list(range(1, 19))  # 18 tokens: 2 full pages + a 2-token tail


def _len0(cfg, prompt=None):
    """Cache length of a prompt: vlm prepends its vision tokens (the keyed
    sequence does too, so page counts shift with the family)."""
    n = len(prompt if prompt is not None else PROMPT)
    return n + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)


def _sp(stochastic, seed=7):
    return (SamplingParams(temperature=0.8, top_k=20, seed=seed)
            if stochastic else None)


def _cold_outputs(cfg, params, prompts, max_new=6, sampling=None, **kw):
    """Reference outputs with prefix caching OFF (requests independent)."""
    eng = EngineCore(cfg, params, **{**ENG_KW, **kw})
    outs = []
    for i, p in enumerate(prompts):
        r = Request(rid=i, prompt=list(p), max_new_tokens=max_new,
                    sampling=sampling)
        eng.add_request(r)
        eng.run()
        outs.append(list(r.out_tokens))
    return outs


# ---------------------------------------------------------------- index
def test_prefix_index_chain_and_resume_keys():
    """Chain keys commit to the whole prefix behind them; resume keys are
    domain-separated from page keys and sensitive to the tail."""
    px = PrefixIndex(page_size=4)
    a = px.page_keys([1, 2, 3, 4, 5, 6, 7, 8, 9])
    b = px.page_keys([1, 2, 3, 4, 5, 6, 7, 8])
    assert len(a) == 2 and a[:2] == b[:2]  # shared spans, shared keys
    c = px.page_keys([9, 2, 3, 4, 5, 6, 7, 8])
    assert c[0] != a[0] and c[1] != a[1]   # first-span change cascades
    r1 = px.resume_key([1, 2, 3, 4, 5, 6, 7, 8, 9])
    r2 = px.resume_key([1, 2, 3, 4, 5, 6, 7, 8])
    assert r1 != r2 and r2 not in (a + c)  # aligned prompt != page key
    assert px.match(a) == 0
    px.insert(a[0], 5)
    assert px.match(a) == 1 and px.match(c) == 0


def test_prefix_index_idle_lru_and_resume_cap():
    px = PrefixIndex(page_size=4, resume_cap=2)
    keys = px.page_keys(list(range(16)))
    for j, k in enumerate(keys):
        px.insert(k, j + 1)
    px.park(keys[0])
    px.park(keys[1])
    px.unpark(keys[0])          # reacquired: off the idle LRU
    assert px.n_idle == 1 and px.n_idle_hot == 1
    px.mark_cold(keys[1])
    assert px.n_idle_hot == 0 and px.cold_idle_keys(5) == [keys[1]]
    assert px.pop_idle_hot(5) == []          # cold entries never pop hot
    px.mark_hot(keys[1], 9)
    assert px.n_idle == 0                    # mark_hot unparks
    px.park(keys[2])
    assert px.pop_idle_hot(5) == [(keys[2], 3)]
    assert px.get(keys[2]) is None           # popped entries leave the index
    for i in range(3):                       # LRU cap evicts the oldest
        px.put_resume(bytes([i]) * 32, ResumeEntry(
            page_keys=[], tail=None, tail_len=0,
            logits=np.zeros(4), length=1))
    assert px.n_resume == 2
    assert px.peek_resume(bytes([0]) * 32) is None


# ------------------------------------------------------- exact-prompt hits
@pytest.mark.parametrize("sampling", ["greedy", "stochastic"])
def test_resume_hit_bit_identity(fam, sampling):
    """Conformance (every paged family): resubmitting an identical prompt
    is admitted with ZERO prefill dispatches — the first token replays the
    stored prefill logits — and the output stream is exactly the cold-cache
    run's, greedy and seed-pinned stochastic."""
    family, cfg, params = fam
    sp = _sp(sampling == "stochastic")
    cold = _cold_outputs(cfg, params, [PROMPT, PROMPT], sampling=sp)
    assert cold[0] == cold[1]  # sanity: pinned seeds replay the stream

    eng = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    r0 = Request(rid=0, prompt=list(PROMPT), max_new_tokens=6, sampling=sp)
    eng.add_request(r0)
    eng.run()
    assert r0.out_tokens == cold[0]
    prefills, chunks = eng.stats.prefills, eng.stats.prefill_chunks
    r1 = Request(rid=1, prompt=list(PROMPT), max_new_tokens=6, sampling=sp)
    eng.add_request(r1)
    eng.run()
    assert r1.out_tokens == cold[1]
    assert eng.stats.prefills == prefills          # dispatch counters pinned
    assert eng.stats.prefill_chunks == chunks
    assert eng.stats.prefix_hits == 1 and eng.stats.prefix_lookups == 2
    len0 = _len0(cfg)
    assert eng.stats.prefix_hit_pages == len0 // 8  # every full page shared
    assert eng.stats.prefix_tokens_reused == len0
    # the private tail-page copy of the resume admission is the COW copy
    assert eng.stats.cow_copies == (1 if len0 % 8 else 0)


def test_resume_hit_one_token_request(fam):
    """A request finishing ON its prefill-sampled token (max_new=1) must
    still leave a usable cache behind — registration precedes finish."""
    family, cfg, params = fam
    cold = _cold_outputs(cfg, params, [PROMPT, PROMPT], max_new=1)
    eng = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    reqs = [Request(rid=i, prompt=list(PROMPT), max_new_tokens=1)
            for i in range(2)]
    eng.add_request(reqs[0])
    eng.run()
    eng.add_request(reqs[1])
    eng.run()
    assert [list(r.out_tokens) for r in reqs] == cold
    assert eng.stats.prefix_hits == 1


# ------------------------------------------------------- partial-page hits
def test_partial_hit_prefills_only_the_suffix(fam):
    """A different continuation of a cached prefix re-maps the shared full
    pages and prefills only the suffix (dense/moe, the chunk-capable
    families — the others take a clean miss); outputs match cold either
    way."""
    family, cfg, params = fam
    pfx = list(range(1, 17))            # 2 full pages
    a, b = pfx + [20, 21], pfx + [30, 31, 32, 33]
    cold = _cold_outputs(cfg, params, [a, b])

    eng = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    ra = Request(rid=0, prompt=list(a), max_new_tokens=6)
    eng.add_request(ra)
    eng.run()
    prefills = eng.stats.prefills
    rb = Request(rid=1, prompt=list(b), max_new_tokens=6)
    eng.add_request(rb)
    eng.run()
    assert [list(ra.out_tokens), list(rb.out_tokens)] == cold
    if eng._chunk_ok:  # dense/moe: suffix went through the chunk path
        assert eng.stats.prefix_hits == 1
        assert eng.stats.prefix_hit_pages == 2
        assert eng.stats.prefix_tokens_reused == 16
        assert eng.stats.prefills == prefills      # no group prefill
        assert eng.stats.prefill_chunks > 0
    else:
        assert eng.stats.prefix_hits == 0


def test_partial_hit_page_aligned_prompt_keeps_a_suffix_token():
    """A fully page-aligned cached prompt still prefills its LAST token (the
    suffix produces the first-token logits) — the hit is capped one page
    short rather than admitting a zero-length prefill."""
    cfg, params = load_family("dense")
    aligned = list(range(1, 17))        # exactly 2 pages
    cold = _cold_outputs(cfg, params, [aligned, aligned + [5]])
    eng = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    r0 = Request(rid=0, prompt=list(aligned), max_new_tokens=6)
    eng.add_request(r0)
    eng.run()
    eng.clear_prefix_cache()
    # re-register only the PAGE entries (drop the resume shortcut) so the
    # aligned resubmission exercises the partial-hit cap
    r1 = Request(rid=1, prompt=list(aligned), max_new_tokens=6)
    eng.add_request(r1)
    eng.run()
    eng._px.clear_resume()
    r2 = Request(rid=2, prompt=list(aligned), max_new_tokens=6)
    eng.add_request(r2)
    eng.run()
    assert list(r0.out_tokens) == list(r1.out_tokens) == cold[0]
    assert list(r2.out_tokens) == cold[0]
    assert eng.stats.prefix_hit_pages >= 1         # capped at 1 of 2 pages
    r3 = Request(rid=3, prompt=aligned + [5], max_new_tokens=6)
    eng.add_request(r3)
    eng.run()
    assert list(r3.out_tokens) == cold[1]


# -------------------------------------------------- release / reclamation
def test_refcounted_release_parks_and_reclaims(fam):
    """Finished slots decref shared pages instead of freeing them: the
    cached full pages stay allocated (idle), are counted reclaimable for
    admission headroom, and ``clear_prefix_cache`` returns them to the
    pool."""
    family, cfg, params = fam
    eng = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    pool = eng.num_pages - 1
    n_full = _len0(cfg) // 8                       # the cached full pages
    r = Request(rid=0, prompt=list(PROMPT), max_new_tokens=6)
    eng.add_request(r)
    eng.run()
    assert eng.allocator.available == pool - n_full  # idle cached pages
    assert eng._px_reclaimable == n_full
    # admission headroom counts idle cached pages as free-on-demand
    assert eng.allocator.available + eng._px_reclaimable == pool
    assert eng.can_accept(eng.pages_per_slot)
    assert eng.clear_prefix_cache() == n_full
    assert eng.allocator.available == pool         # fully recycled
    assert eng.stats.prefix_hits == 0
    # cache cleared: the next identical prompt is a miss, then hits again
    r1 = Request(rid=1, prompt=list(PROMPT), max_new_tokens=6)
    eng.add_request(r1)
    eng.run()
    assert list(r1.out_tokens) == list(r.out_tokens)
    assert eng.stats.prefix_hits == 0 and eng.stats.prefix_lookups == 2


def test_idle_cached_pages_reclaimed_under_pressure():
    """A pool full of idle cached pages must not starve admission: the
    engine reclaims LRU idle entries (frees their pids) when a new request
    needs the room."""
    cfg, params = load_family("dense")
    eng = EngineCore(cfg, params, prefix_cache=True, max_batch=2, max_seq=64,
                     eos_id=-1, page_size=8, num_pages=7)  # 6 usable pages
    prompts = [[10 + i] * 18 for i in range(3)]  # 2 cached pages each
    cold = _cold_outputs(cfg, params, prompts)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
        eng.run()
    assert [list(r.out_tokens) for r in reqs] == cold
    assert all(not r.rejected for r in reqs)
    # the pool (6 pages) cannot hold 3 x 2 idle + 3 active: reclamation ran
    assert eng.allocator.available + eng._px_reclaimable == eng.num_pages - 1


def test_wave_mode_rejects_prefix_cache():
    cfg, params = load_family("dense")
    with pytest.raises(ValueError, match="prefix"):
        EngineCore(cfg, params, mode="wave", prefix_cache=True,
                   max_batch=2, max_seq=32, eos_id=-1)


# ------------------------------------------------------------ tiered pool
def test_tiered_spill_prefetch_shared_pages(fam):
    """Conformance (every paged family): idle shared pages spill to the
    flash tier under pressure and prefetch back on the next hit —
    evicted once, prefetched once, outputs bit-identical to cold."""
    family, cfg, params = fam
    fillers = [[30 + i] * 18 for i in range(3)]
    cold = _cold_outputs(cfg, params, [PROMPT] + fillers + [PROMPT])

    # hot pool = one request's worst-case demand + 2: each finished
    # request's idle cached pages crowd the next admission into spilling
    per_req = -(-min(64, _len0(cfg) + 6) // 8)
    eng = EngineCore(cfg, params, prefix_cache=True, kv_tier="flash",
                     max_batch=2, max_seq=64, eos_id=-1, page_size=8,
                     num_pages=per_req + 3)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=6)
            for i, p in enumerate([PROMPT] + fillers + [PROMPT])]
    for r in reqs:
        eng.add_request(r)
        eng.run()
    assert [list(r.out_tokens) for r in reqs] == cold
    s = eng.stats
    assert s.kv_spill_pages > 0 and s.kv_prefetch_pages > 0
    assert s.prefix_hits >= 1                      # the resubmitted PROMPT
    # the resubmission hit pages that had gone cold in between
    assert s.prefix_hit_pages >= 2


# ------------------------------------------------------------- migration
def test_migration_carries_shared_pages(fam):
    """Conformance (every paged family): a slot mapping shared pages
    snapshots and injects bit-identically; the carried chain keys seed the
    target replica's index, so the SAME prompt then hits on the target."""
    family, cfg, params = fam
    solo = _cold_outputs(cfg, params, [PROMPT, PROMPT], max_new=8)

    a = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    b = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    warm = Request(rid=0, prompt=list(PROMPT), max_new_tokens=8)
    a.add_request(warm)
    a.run()                                        # populate a's cache
    mig = Request(rid=1, prompt=list(PROMPT), max_new_tokens=8)
    a.add_request(mig)
    for _ in range(3):                             # genuinely mid-decode
        a.step()
    assert 0 < len(mig.out_tokens) < 8
    snap = a.snapshot_slot(1)
    assert snap.prefix_keys                        # shared pages annotated
    b.inject_slot(snap)
    while b.has_work:
        b.step()
    assert list(mig.out_tokens) == solo[0]
    assert len(b._px) >= 2                         # keys registered on b
    # the carried cache is live on b: an identical prompt hits there
    r2 = Request(rid=2, prompt=list(PROMPT), max_new_tokens=8)
    b.add_request(r2)
    b.run()
    assert list(r2.out_tokens) == solo[1]
    if b._chunk_ok:
        assert b.stats.prefix_hits >= 1
    # a's pool: only its own idle cached pages remain
    assert a.allocator.available == a.num_pages - 1 - a._px_reclaimable


def test_migration_reshares_on_cache_holding_target():
    """Injecting into a replica whose index already holds the carried keys
    re-SHARES its pages (increfs) instead of deep-copying them."""
    cfg, params = load_family("dense")
    a = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    b = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    for eng in (a, b):                             # both caches warm
        r = Request(rid=0, prompt=list(PROMPT), max_new_tokens=8)
        eng.add_request(r)
        eng.run()
    avail_b = b.allocator.available
    mig = Request(rid=1, prompt=list(PROMPT), max_new_tokens=8)
    a.add_request(mig)
    for _ in range(3):
        a.step()
    snap = a.snapshot_slot(1)
    shared_pages = len(snap.prefix_keys)
    total_pages = len(snap.pages)
    assert shared_pages == 2
    b.inject_slot(snap)
    # the 2 shared pages were re-SHARED (increfed on b's copies), not
    # re-allocated: only the exclusive pages cost b fresh pool pages
    assert avail_b - b.allocator.available == total_pages - shared_pages
    for ent in b._px._pages.values():
        assert b.allocator.refcount(ent.pid) == 1  # idle 0 -> mapped 1
    while b.has_work:
        b.step()
    solo = _cold_outputs(cfg, params, [PROMPT], max_new=8)[0]
    assert list(mig.out_tokens) == solo


# ---------------------------------------------------------------- routing
def test_session_affinity_follows_the_cache():
    """The replica whose prefix cache holds the session's pages wins the
    routing decision, beating the cold-session hash fallback."""
    import zlib
    cfg, params = load_family("dense")
    rt = Router.build(cfg, params, replicas=2, policy="session_affinity",
                      prefix_cache=True, **ENG_KW)
    # a session id whose hash picks replica 1 — but the session's pages
    # will live on replica 0, and the cache must override the hash
    sid = next(s for s in (f"s{i}" for i in range(64))
               if zlib.crc32(s.encode()) % 2 == 1)
    warm = Request(rid=0, prompt=list(PROMPT), max_new_tokens=4)
    rt.cores[0].add_request(warm)                  # pages land on replica 0
    while rt.cores[0].has_work:
        rt.cores[0].step()
    req = Request(rid=1, prompt=list(PROMPT), max_new_tokens=4, session=sid)
    assert rt.cores[0].prefix_hit_estimate(req) > 0
    assert rt.cores[1].prefix_hit_estimate(req) == 0
    assert rt.submit(req) is rt.cores[0]           # cache beats the hash
    cold = Request(rid=2, prompt=[7, 8, 9], max_new_tokens=4, session=sid)
    assert rt.submit(cold) is rt.cores[1]          # nothing cached: hash


def test_least_loaded_discounts_cached_prefix():
    """At equal queue load, least_loaded prefers the replica that can skip
    the prefill (the hit estimate acts as a tie-shader)."""
    cfg, params = load_family("dense")
    rt = Router.build(cfg, params, replicas=2, policy="least_loaded",
                      prefix_cache=True, **ENG_KW)
    warm = Request(rid=0, prompt=list(PROMPT), max_new_tokens=4)
    rt.cores[1].add_request(warm)                  # warm replica 1 directly
    while rt.cores[1].has_work:
        rt.cores[1].step()
    req = Request(rid=1, prompt=list(PROMPT), max_new_tokens=4)
    assert rt.submit(req) is rt.cores[1]           # loads equal, cache wins


def test_prefix_hit_estimate_is_lru_neutral():
    """Router scoring probes must not perturb resume-entry LRU order."""
    cfg, params = load_family("dense")
    eng = EngineCore(cfg, params, prefix_cache=True, **ENG_KW)
    r = Request(rid=0, prompt=list(PROMPT), max_new_tokens=4)
    eng.add_request(r)
    eng.run()
    probe = Request(rid=9, prompt=list(PROMPT), max_new_tokens=4)
    est = eng.prefix_hit_estimate(probe)
    assert est > 0
    order = list(eng._px._resume)
    for _ in range(3):
        assert eng.prefix_hit_estimate(probe) == est
    assert list(eng._px._resume) == order
    assert eng.prefix_hit_estimate(
        Request(rid=10, prompt=[99, 98], max_new_tokens=4)) == 0
