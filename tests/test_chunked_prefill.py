"""Chunked prefill: bit-identity to one-shot prefill + no decode starvation.

The load-bearing check is bit-identity: prefilling a prompt in chunks of
ANY size — including 1 token at a time — must leave every cache bit, the
first-token logits, and every subsequent decode logit exactly equal to the
single-chunk (one-shot) run.  That holds because each chunk position's K/V
is scattered into the slot's pages first and its attention reads every key
from the gathered block row (the buffer decode reads), so no position's
math depends on how the prompt was split (see
``models.model.prefill_chunk_into_slot``).

The second check is the scheduling point of chunking: a long prompt
admitted mid-stream prefills one budgeted chunk per engine step, so the
other slots keep emitting a decode token every step instead of stalling
behind a monolithic prefill pass.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED_ARCHS
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import chunk_spans, prefill_bucket
from repro.serving.scheduler import FCFSScheduler

KEY = jax.random.PRNGKey(0)
PAGE = 8


@pytest.fixture(scope="module")
def smollm():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, max_seq=64)
    return cfg, params


@pytest.fixture(scope="module")
def smollm_f32():
    cfg = ASSIGNED_ARCHS["smollm-360m"].reduced()
    params = M.init_params(cfg, KEY, dtype=jnp.float32, max_seq=64)
    return cfg, params


def test_chunk_spans_helper():
    assert chunk_spans(13, 4) == [(0, 4), (4, 4), (8, 4), (12, 1)]
    assert chunk_spans(8, 8) == [(0, 8)]
    assert chunk_spans(3, 100) == [(0, 3)]
    with pytest.raises(ValueError):
        chunk_spans(5, 0)
    # spans tile the prompt exactly, in order, each within budget
    for n in (1, 7, 16, 33):
        for b in (1, 3, 8):
            spans = chunk_spans(n, b)
            assert sum(ln for _, ln in spans) == n
            assert all(0 < ln <= b for _, ln in spans)
            assert [s for s, _ in spans] == \
                list(np.cumsum([0] + [ln for _, ln in spans[:-1]]))


def _chunked_prefill_then_decode(cfg, params, prompt, budget, n_decode=5):
    """Prefill via prefill_chunk_into_slot in ``budget``-token chunks
    (padded to the engine's power-of-two buckets, so different budgets run
    DIFFERENT trace shapes — identity must survive that), then
    greedy-decode; returns the list of logits (first token + decode)."""
    pc = M.init_paged_cache(cfg, 2, 32, dtype=jnp.float32, page_size=PAGE)
    pps = pc["block"].shape[1]
    cap = pps * PAGE
    pc["block"] = pc["block"].at[0, :].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    jf = jax.jit(lambda p, t, s, cl, c, sl: M.prefill_chunk_into_slot(
        p, cfg, t, s, cl, c, sl))
    for start, clen in chunk_spans(len(prompt), budget):
        cb = min(prefill_bucket(clen, floor=PAGE), cap)
        toks = jnp.zeros((cb,), jnp.int32).at[:clen].set(
            jnp.asarray(prompt[start:start + clen]))
        lg, pc = jf(params, toks, jnp.int32(start), jnp.int32(clen), pc,
                    jnp.int32(0))
    assert int(pc["lens"][0]) == len(prompt)
    logits = [np.asarray(lg)]
    tokb = jnp.zeros((2,), jnp.int32).at[0].set(int(jnp.argmax(lg)))
    active = jnp.array([True, False])
    for _ in range(n_decode):
        out, pc = M.decode_step_paged(params, cfg, tokb, pc, active)
        logits.append(np.asarray(out[0]))
        tokb = tokb.at[0].set(int(jnp.argmax(out[0])))
    return logits


def test_chunked_prefill_bit_identical_to_one_shot(smollm_f32):
    """Acceptance: decode logits after chunked prefill are BIT-identical to
    the one-shot (single-chunk) run, across chunk sizes {1, 7, page_size,
    len(prompt)}."""
    cfg, params = smollm_f32
    prompt = [int(t) for t in
              jax.random.randint(KEY, (13,), 0, cfg.vocab_size)]
    one_shot = _chunked_prefill_then_decode(cfg, params, prompt, len(prompt))
    for budget in (1, 7, PAGE):
        got = _chunked_prefill_then_decode(cfg, params, prompt, budget)
        for a, b in zip(one_shot, got):
            np.testing.assert_array_equal(a, b)


def test_chunked_prefill_matches_legacy_prefill(smollm_f32):
    """Cross-path: the chunked path agrees with prefill_into_slot (different
    softmax buffer arrangement, so allclose + greedy-token equality)."""
    cfg, params = smollm_f32
    prompt = [int(t) for t in
              jax.random.randint(KEY, (13,), 0, cfg.vocab_size)]
    chunked = _chunked_prefill_then_decode(cfg, params, prompt, 7)

    pc = M.init_paged_cache(cfg, 2, 32, dtype=jnp.float32, page_size=PAGE)
    pps = pc["block"].shape[1]
    pc["block"] = pc["block"].at[0, :].set(
        jnp.arange(1, pps + 1, dtype=jnp.int32))
    padded = jnp.asarray(prompt + [0] * (16 - len(prompt)))[None]
    lg, pc = M.prefill_into_slot(params, cfg, padded, jnp.int32(len(prompt)),
                                 pc, jnp.int32(0), {})
    legacy = [np.asarray(lg)]
    tokb = jnp.zeros((2,), jnp.int32).at[0].set(int(jnp.argmax(lg)))
    active = jnp.array([True, False])
    for _ in range(5):
        out, pc = M.decode_step_paged(params, cfg, tokb, pc, active)
        legacy.append(np.asarray(out[0]))
        tokb = tokb.at[0].set(int(jnp.argmax(out[0])))
    for a, b in zip(legacy, chunked):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
        assert int(np.argmax(a)) == int(np.argmax(b))


def test_chunked_prefill_rejects_unsupported_family():
    cfg = ASSIGNED_ARCHS["mamba2-130m"].reduced()
    assert not M.supports_chunked_prefill(cfg)
    with pytest.raises(ValueError):
        M.prefill_chunk_into_slot({}, cfg, jnp.zeros((8,), jnp.int32),
                                  jnp.int32(0), jnp.int32(1), {},
                                  jnp.int32(0))


def test_engine_chunked_outputs_match_one_shot(smollm):
    """Engine integration: the same request served with chunk budgets
    {1, 4, page_size} produces exactly the one-shot run's tokens, with the
    expected chunk count recorded."""
    cfg, params = smollm
    prompt = [int(t) for t in
              jax.random.randint(KEY, (20,), 1, cfg.vocab_size)]

    def serve(budget):
        req = Request(rid=0, prompt=list(prompt), max_new_tokens=6)
        sched = (FCFSScheduler(chunk_tokens=budget) if budget else None)
        eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                            page_size=PAGE, scheduler=sched)
        eng.submit(req)
        eng.run()
        assert req.done and req.finish_reason == "length"
        return req

    base = serve(None)
    for budget in (1, 4, PAGE):
        req = serve(budget)
        assert req.out_tokens == base.out_tokens
        assert req.n_chunks == -(-len(prompt) // budget)
    assert base.n_chunks == 0  # one-shot path took the group prefill


def test_chunked_prefill_does_not_starve_decode(smollm):
    """Scheduling acceptance: while a long prompt chunk-prefills, the
    already-decoding slot keeps emitting one token per engine step (decode
    TPS stays flat); an unchunked admission of the same prompt would stall
    it for the whole monolithic prefill pass."""
    cfg, params = smollm
    short = Request(rid=1, prompt=[3, 1, 4], max_new_tokens=30)
    long_prompt = [int(t) for t in
                   jax.random.randint(KEY, (24,), 1, cfg.vocab_size)]
    long = Request(rid=2, prompt=long_prompt, max_new_tokens=4)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, eos_id=-1,
                        page_size=PAGE,
                        scheduler=FCFSScheduler(chunk_tokens=4))
    eng.submit(short)
    eng.step()  # short admitted and decoding
    eng.drain_outputs()
    eng.submit(long)
    per_step_short = []
    while long.t_first_token == 0.0:
        eng.step()
        evs = eng.drain_outputs()
        per_step_short.append(
            sum(1 for e in evs if e.rid == 1 and e.token is not None))
    # the long prompt took several chunked steps to admit...
    assert long.n_chunks == -(-len(long_prompt) // 4)
    assert len(per_step_short) >= long.n_chunks
    # ...and the short request emitted a token on EVERY one of them
    assert all(n == 1 for n in per_step_short), per_step_short
    eng.run()
    assert short.done and long.done
