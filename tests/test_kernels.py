"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant.int8 import quantize_weight
from repro.quant.int4 import quantize_weight4

# the parametrized interpret-mode sweeps take minutes and carry the slow
# marker (`pytest -m "not slow"` is the fast tier); the paged/lengths decode
# attention checks added with the paged-KV PR stay in the fast tier
slow = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------- int8 GeMV
@pytest.mark.parametrize("h,w,b", [
    (256, 2048, 1),     # the paper's -S optimal tile
    (512, 4096, 4),
    (300, 1000, 1),     # ragged -> padding path
    (64, 128, 8),
    (1024, 512, 128),   # decode_32k batch
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@slow
def test_int8_pagegemv(h, w, b, dtype):
    from repro.kernels.int8_pagegemv.ops import paged_int8_gemv
    from repro.kernels.int8_pagegemv.ref import paged_int8_gemv_ref

    k1, k2 = jax.random.split(jax.random.fold_in(KEY, h * w + b))
    W = (jax.random.normal(k1, (h, w)) * 0.1).astype(dtype)
    x = jax.random.normal(k2, (w, b) if b > 1 else (w,)).astype(dtype)
    q = quantize_weight(W.astype(jnp.float32))
    y_k = paged_int8_gemv(q.w_q, q.scale, x)
    y_r = paged_int8_gemv_ref(q.w_q, q.scale, x)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,h,hkv,s,d", [
    (1, 4, 4, 256, 64),
    (2, 8, 2, 512, 64),    # GQA 4:1
    (1, 15, 5, 128, 64),   # smollm heads
    (2, 4, 1, 384, 128),   # MQA, ragged seq -> pad
])
@pytest.mark.parametrize("causal", [True, False])
@slow
def test_flash_attention(b, h, hkv, s, d, causal):
    from repro.kernels.flash_attention.ops import flash_attention_op
    from repro.kernels.flash_attention.ref import attention_ref

    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, s * h), 3)
    q = jax.random.normal(k1, (b, h, s, d), jnp.float32)
    k = jax.random.normal(k2, (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(k3, (b, hkv, s, d), jnp.float32)
    out = flash_attention_op(q, k, v, causal=causal, block_q=128, block_k=128)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@slow
def test_flash_attention_bf16():
    from repro.kernels.flash_attention.ops import flash_attention_op
    from repro.kernels.flash_attention.ref import attention_ref

    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (1, 4, 256, 64), jnp.bfloat16)
    k = jax.random.normal(k2, (1, 4, 256, 64), jnp.bfloat16)
    v = jax.random.normal(k3, (1, 4, 256, 64), jnp.bfloat16)
    out = flash_attention_op(q, k, v, block_q=128, block_k=128)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


# --------------------------------------------------------- decode attention
@pytest.mark.parametrize("b,h,hkv,smax,d,length", [
    (2, 8, 8, 512, 64, 300),
    (1, 16, 2, 1024, 64, 1000),   # GQA 8:1
    (4, 15, 5, 256, 64, 256),     # full cache
    (2, 8, 1, 300, 128, 77),      # MQA + ragged smax
])
@slow
def test_decode_attention(b, h, hkv, smax, d, length):
    from repro.kernels.decode_attention.ops import decode_attention_op
    from repro.models.attention import decode_attention as ref_fn

    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, smax + h), 3)
    q = jax.random.normal(k1, (b, h, d), jnp.float32)
    kc = jax.random.normal(k2, (b, smax, hkv, d), jnp.float32)
    vc = jax.random.normal(k3, (b, smax, hkv, d), jnp.float32)
    out = decode_attention_op(q, kc, vc, jnp.int32(length), block_k=128)
    ref = ref_fn(q, kc, vc, jnp.int32(length))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,hkv,smax,d", [
    (3, 8, 8, 512, 64),
    (2, 16, 2, 256, 64),    # GQA 8:1
    (4, 15, 5, 300, 64),    # ragged smax
])
def test_decode_attention_lengths_vector(b, h, hkv, smax, d):
    """Per-slot lengths [B] (continuous batching) vs the oracle, including a
    zero-length (inactive) slot whose output is ignored."""
    from repro.kernels.decode_attention.ops import decode_attention_op
    from repro.kernels.decode_attention.ref import decode_attention_ref

    k1, k2, k3, k4 = jax.random.split(jax.random.fold_in(KEY, smax * h), 4)
    q = jax.random.normal(k1, (b, h, d), jnp.float32)
    kc = jax.random.normal(k2, (b, smax, hkv, d), jnp.float32)
    vc = jax.random.normal(k3, (b, smax, hkv, d), jnp.float32)
    lens = jax.random.randint(k4, (b,), 1, smax + 1).astype(jnp.int32)
    lens = lens.at[0].set(0)  # inactive slot lane
    out = decode_attention_op(q, kc, vc, lens, block_k=128)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out[1:]), np.asarray(ref[1:]),
                               rtol=2e-5, atol=2e-5)
    assert not bool(jnp.isnan(out).any())  # inactive lane finite, not equal


def test_paged_decode_attention_matches_dense():
    """Block-table gather + lengths masking == dense cache with the same
    contents; slots point at scattered pages of a shared pool."""
    from repro.kernels.decode_attention.ops import paged_decode_attention_op
    from repro.kernels.decode_attention.ref import decode_attention_ref

    b, h, hkv, d, page, pps = 3, 8, 2, 64, 16, 4
    n_pages = b * pps + 1
    k1, k2, k3 = jax.random.split(KEY, 3)
    k_pages = jax.random.normal(k1, (n_pages, page, hkv, d), jnp.float32)
    v_pages = jax.random.normal(k2, (n_pages, page, hkv, d), jnp.float32)
    # interleaved page assignment exercises the indirection
    block = jnp.arange(1, b * pps + 1, dtype=jnp.int32
                       ).reshape(pps, b).T    # slot i -> pages i+1, i+1+b, ...
    q = jax.random.normal(k3, (b, h, d), jnp.float32)
    lens = jnp.asarray([page * pps, 7, 23], jnp.int32)
    out = paged_decode_attention_op(q, k_pages, v_pages, block, lens,
                                    block_k=32)
    k_dense = k_pages[block].reshape(b, pps * page, hkv, d)
    v_dense = v_pages[block].reshape(b, pps * page, hkv, d)
    ref = decode_attention_ref(q, k_dense, v_dense, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- W4A16
@pytest.mark.parametrize("h,w,b", [
    (256, 2048, 1), (128, 512, 4), (300, 1024, 1), (64, 256, 2),
])
@slow
def test_w4a16_gemv(h, w, b):
    from repro.kernels.w4a16_gemv.ops import w4a16_gemv
    from repro.kernels.w4a16_gemv.ref import w4a16_gemv_ref

    k1, k2 = jax.random.split(jax.random.fold_in(KEY, h + w))
    W = jax.random.normal(k1, (h, w)) * 0.1
    x = jax.random.normal(k2, (w, b) if b > 1 else (w,))
    q = quantize_weight4(W)
    y_k = w4a16_gemv(q, x)
    y_r = w4a16_gemv_ref(q, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------- ECC decode
@pytest.mark.parametrize("ber", [0.0, 1e-4, 5e-4])
@slow
def test_ecc_decode_kernel(ber):
    from repro.core import ecc
    from repro.kernels.ecc_decode.ops import ecc_decode_op

    key = jax.random.fold_in(KEY, int(ber * 1e6))
    pages = []
    for i in range(3):
        k0, k1, k2 = jax.random.split(jax.random.fold_in(key, i), 3)
        bulk = (jax.random.normal(k0, (16384,)) * 10).round().clip(-127, 127)
        pos = jax.random.choice(k1, 16384, (64,), replace=False)
        w = bulk.at[pos].set(115.0).astype(jnp.int8)
        pages.append(jax.lax.bitcast_convert_type(w, jnp.uint8))
    pages = jnp.stack(pages)
    e = ecc.encode_pages(pages)
    if ber > 0:
        pages_n = ecc.inject_bitflips(pages, ber, jax.random.fold_in(key, 9))
        e = ecc.inject_ecc_bitflips(e, ber, jax.random.fold_in(key, 10))
    else:
        pages_n = pages
    out_k = np.asarray(ecc_decode_op(pages_n, e))
    out_r = np.asarray(ecc.decode_pages(pages_n, e))
    # Corrupted addresses may collide post-Hamming-correction; write order at
    # collisions is implementation-defined, so exclude colliding positions.
    addr, _ = jax.vmap(ecc.hamming_correct)(e.addr, e.addr_parity)
    for b in range(pages.shape[0]):
        a = np.asarray(addr[b])
        vals, counts = np.unique(a, return_counts=True)
        collide = set(vals[counts > 1].tolist())
        mask = np.ones(pages.shape[1], bool)
        for c in collide:
            mask[int(c)] = False
        np.testing.assert_array_equal(out_k[b][mask], out_r[b][mask])


# ---------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("b,s,h,g,p,n,chunk", [
    (1, 256, 4, 1, 32, 16, 64),
    (2, 128, 8, 2, 16, 32, 32),
    (1, 64, 2, 1, 64, 128, 64),   # mamba2-130m-ish dims
])
@slow
def test_ssd_intra_chunk(b, s, h, g, p, n, chunk):
    from repro.kernels.ssd_scan.ops import ssd_intra_chunk_op
    from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref

    keys = jax.random.split(jax.random.fold_in(KEY, s * h), 4)
    x = jax.random.normal(keys[0], (b, s, h, p), jnp.float32)
    a = -jnp.abs(jax.random.normal(keys[1], (b, s, h))) * 0.1
    bm = jax.random.normal(keys[2], (b, s, g, n), jnp.float32) * 0.3
    cm = jax.random.normal(keys[3], (b, s, g, n), jnp.float32) * 0.3
    y_k = ssd_intra_chunk_op(x, a, bm, cm, chunk=chunk)
    nc = s // chunk
    ar = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2).reshape(b * h, nc, chunk)
    br = bm.reshape(b, nc, chunk, g, n).transpose(0, 3, 1, 2, 4).reshape(b * g, nc, chunk, n)
    cr = cm.reshape(b, nc, chunk, g, n).transpose(0, 3, 1, 2, 4).reshape(b * g, nc, chunk, n)
    xr = x.reshape(b, nc, chunk, h, p).transpose(0, 3, 1, 2, 4).reshape(b * h, nc, chunk, p)
    y_r = ssd_intra_chunk_ref(ar, br, cr, xr)
    y_r = y_r.reshape(b, h, nc, chunk, p).transpose(0, 2, 3, 1, 4).reshape(b, s, h, p)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


@slow
def test_ssd_kernel_matches_model_diag_plus_offdiag():
    """Kernel y_diag + jnp inter-chunk == models/ssm.ssd_chunked output."""
    from repro.kernels.ssd_scan.ops import ssd_intra_chunk_op
    from repro.models.ssm import ssd_chunked

    b, s, h, g, p, n, chunk = 1, 128, 4, 1, 16, 8, 128  # single chunk
    keys = jax.random.split(KEY, 4)
    x = jax.random.normal(keys[0], (b, s, h, p), jnp.float32)
    a = -jnp.abs(jax.random.normal(keys[1], (b, s, h))) * 0.1
    bm = jax.random.normal(keys[2], (b, s, g, n), jnp.float32) * 0.3
    cm = jax.random.normal(keys[3], (b, s, g, n), jnp.float32) * 0.3
    y_full, _ = ssd_chunked(x, a, bm, cm, chunk=chunk)
    y_diag = ssd_intra_chunk_op(x, a, bm, cm, chunk=chunk)
    # single chunk -> no inter-chunk term: y_diag must equal the full output
    np.testing.assert_allclose(np.asarray(y_diag), np.asarray(y_full, np.float32),
                               rtol=1e-4, atol=1e-4)
