"""Multi-device distribution tests (run in subprocesses with forced device
counts so the rest of the suite keeps seeing 1 CPU device)."""

import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


def _check(r):
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def test_moe_expert_parallel_equivalence():
    _check(_run("""
import jax, jax.numpy as jnp
from repro.configs.registry import ASSIGNED_ARCHS
from repro.distributed import ctx
from repro.models import moe as moe_mod
cfg = ASSIGNED_ARCHS['qwen2-moe-a2.7b'].reduced()
key = jax.random.PRNGKey(0)
p = moe_mod.init_moe(key, cfg, jnp.float32)
x = jax.random.normal(jax.random.fold_in(key,1), (4, 8, cfg.d_model), jnp.float32)
y_local = moe_mod.moe_ffn(p, x, cfg)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
with ctx.lowering_ctx(mesh=mesh):
    with mesh:
        y_s = jax.jit(lambda p, x: moe_mod.moe_ffn(p, x, cfg))(p, x)
rel = float(jnp.max(jnp.abs(y_local - y_s)) / (jnp.max(jnp.abs(y_local)) + 1e-9))
assert rel < 2e-2, rel
"""))


def test_hybrid_stream_primitives():
    _check(_run("""
import jax, jax.numpy as jnp
from repro.distributed.hybrid_stream import streamed_matmul_chain, alpha_split_matmul
mesh = jax.make_mesh((8,), ('data',))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (4, 64))
ws = [jax.random.normal(jax.random.fold_in(key, i), (64, 64)) * 0.1
      for i in range(3)]
with mesh:
    y = streamed_matmul_chain(x, ws, mesh, 'data')
ref = x
for w in ws:
    ref = ref @ w
assert float(jnp.max(jnp.abs(y - ref))) < 1e-4
with mesh:
    for alpha in (0.0, 0.25, 0.5, 1.0):
        y2 = alpha_split_matmul(x, ws[0], mesh, alpha)
        assert float(jnp.max(jnp.abs(y2 - x @ ws[0]))) < 1e-4, alpha
"""))


def test_pipeline_parallel_correctness():
    _check(_run("""
import jax, jax.numpy as jnp
from repro.distributed.pipeline import pipelined_forward
mesh = jax.make_mesh((4,), ('pod',))
key = jax.random.PRNGKey(0)
n_stages, m, mb, d = 4, 6, 2, 16
ws = jax.random.normal(key, (n_stages, d, d)) * 0.3
xs = jax.random.normal(jax.random.fold_in(key, 1), (m, mb, d))
def layer_fn(w, x):
    return jnp.tanh(x @ w)
with mesh:
    out = pipelined_forward(layer_fn, ws, xs, mesh, 'pod')
ref = xs
for s in range(n_stages):
    ref = jnp.tanh(ref @ ws[s])
assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
"""))


def test_elastic_reshard_across_device_counts():
    _check(_run("""
import jax, jax.numpy as jnp
from repro.configs.registry import ASSIGNED_ARCHS
from repro.distributed.elastic import make_elastic_mesh, reshard_params
from repro.models import model as M
cfg = ASSIGNED_ARCHS['smollm-360m'].reduced()
params = M.init_params(cfg, jax.random.PRNGKey(0), max_seq=32)
for n in (8, 6, 4):
    mesh = make_elastic_mesh(jax.devices()[:n], prefer_model=4)
    p2 = reshard_params(params, mesh)
    toks = jnp.zeros((2, 8), jnp.int32)
    with mesh:
        logits = M.forward(p2, cfg, toks, {})
    assert not bool(jnp.isnan(logits).any()), n
"""))


def test_sharding_rules_cover_all_archs():
    _check(_run("""
import jax, jax.numpy as jnp
from repro.configs.registry import ASSIGNED_ARCHS
from repro.distributed import sharding as shd
from repro.launch import specs as specs_lib
mesh = jax.make_mesh((2, 4), ('data', 'model'))
for name, cfg in ASSIGNED_ARCHS.items():
    ps = specs_lib.param_specs(cfg.reduced(), max_seq=64, quant=False)
    tree = shd.params_shardings(ps, mesh)  # must not raise
    cs = specs_lib.cache_specs(cfg.reduced(), 8, 64)
    shd.cache_shardings(cs, mesh, 8)
print('ok')
""", devices=8))


def test_grad_compress_allreduce_traffic():
    _check(_run("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.grad_compress import psum_compressed
from repro.kernels.compat import shard_map
mesh = jax.make_mesh((8,), ('data',))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 0.1
with mesh:
    out = shard_map(lambda g: psum_compressed(g, 'data'), mesh=mesh,
                    in_specs=P('data'), out_specs=P('data'),
                    check_vma=False)(g)
ref = g.mean(0)
rel = float(jnp.linalg.norm(out[0] - ref) / jnp.linalg.norm(ref))
assert rel < 0.05, rel
"""))
