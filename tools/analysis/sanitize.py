"""Runtime invariant rails ("sanitizers") for the serving stack.

Enabled with ``REPRO_SANITIZE=1`` (see ``src/repro/_sanitize.py`` for the
import bridge the serving hooks use).  Three rails, each the runtime twin
of a reprolint rule / documented hazard class:

* **Shadow-model allocator checker** — every ``alloc`` / ``free`` /
  ``incref`` / ``decref`` on a ``PageAllocator`` (and every ``store`` /
  ``fetch`` / ``mark_evictable`` / ``pop_evictable`` on the tiered cold
  store — the spill/prefetch ops) is mirrored against an independent
  pure-python model and cross-checked against the real allocator's
  observable state.  Divergence (double-alloc of a live page, free while
  shared, a page simultaneously eviction-marked hot AND stored cold)
  raises :class:`SanitizerError` with the trailing op log, at the op that
  corrupted the pool rather than N tokens later.

* **Overlapped-dispatch aliasing guard** — the numpy args handed to the
  fused decode+sample dispatch are hashed at dispatch and re-hashed at the
  lagged drain.  A mismatch is the PR 6 host-buffer race (CPU jit aliases
  numpy inputs zero-copy; the host mutated a buffer while the async step
  still read it), caught at the step that corrupted it.

* **Jit retrace budget** — the fused-step trace-cache size is asserted
  against a budget each drain, so a shape-bucketing regression (retrace
  per step instead of per bucket) fails loudly instead of slowly.

All checks raise; ``report_count()`` stays 0 on a healthy run and
``check_count()`` proves the rails actually executed (the bench smoke
asserts both).
"""

from __future__ import annotations

import hashlib
import os
from collections import deque

__all__ = [
    "SanitizerError", "enabled", "report_count", "check_count",
    "reset_counters", "attach_page_shadow", "attach_tier_shadow",
    "guard_dispatch", "check_drain", "check_retrace",
    "check_wire_manifest",
]


class SanitizerError(AssertionError):
    """An invariant the sanitizer rails pin was violated."""


_reports = 0
_checks = 0


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def report_count() -> int:
    """Violations raised so far (0 on a healthy run)."""
    return _reports


def check_count() -> int:
    """Invariant checks executed so far (> 0 proves the rails ran)."""
    return _checks


def reset_counters() -> None:
    global _reports, _checks
    _reports = _checks = 0


def _checked() -> None:
    global _checks
    _checks += 1


def _violation(msg: str, trail=None):
    global _reports
    _reports += 1
    if trail:
        msg += "\n  op trail (oldest first):\n" + "\n".join(
            f"    {op}" for op in trail)
    raise SanitizerError(msg)


# ----------------------------------------------------------------------
# shadow-model page allocator
# ----------------------------------------------------------------------
class ShadowPageModel:
    """Independent pure-python model of ``PageAllocator`` semantics: a free
    set plus per-page refcounts.  Deliberately re-derives every rule from
    the documented contract (page 0 reserved; alloc hands out refcount 1;
    refcount 0 = allocated-but-idle; free requires refcount <= 1) instead
    of reusing the allocator's own bookkeeping — agreement is the check."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self.free: set[int] = set(range(1, num_pages))
        self.refs: dict[int, int] = {}

    def on_alloc(self, pids, trail):
        for p in pids:
            if p in self.refs:
                _violation(
                    f"shadow allocator: page {p} allocated while already "
                    f"live (refcount {self.refs[p]}) — two slots now write "
                    f"the same KV page", trail)
            if p not in self.free:
                _violation(
                    f"shadow allocator: page {p} allocated but the model "
                    f"does not have it free (reserved/out-of-range id?)",
                    trail)
            self.free.discard(p)
            self.refs[p] = 1

    def on_free(self, pids, trail):
        seen = set()
        for p in pids:
            if p in self.free or p in seen:
                _violation(
                    f"shadow allocator: page {p} double-freed — its id "
                    f"would be handed to two slots and corrupt both "
                    f"KV streams", trail)
            if p not in self.refs:
                _violation(
                    f"shadow allocator: page {p} freed but never allocated",
                    trail)
            if self.refs[p] > 1:
                _violation(
                    f"shadow allocator: page {p} freed while shared "
                    f"(refcount {self.refs[p]}) — the surviving sharers "
                    f"now read a recycled page", trail)
            seen.add(p)
        for p in pids:
            self.refs.pop(p, None)
            self.free.add(p)

    def on_incref(self, pid, result, trail):
        if pid not in self.refs:
            _violation(
                f"shadow allocator: incref of unallocated page {pid}", trail)
        self.refs[pid] += 1
        if result != self.refs[pid]:
            _violation(
                f"shadow allocator: incref({pid}) returned {result}, model "
                f"says {self.refs[pid]}", trail)

    def on_decref(self, pid, result, trail):
        if self.refs.get(pid, 0) <= 0:
            _violation(
                f"shadow allocator: decref of page {pid} below zero", trail)
        self.refs[pid] -= 1
        if result != self.refs[pid]:
            _violation(
                f"shadow allocator: decref({pid}) returned {result}, model "
                f"says {self.refs[pid]}", trail)


def _cross_check(palloc, model: ShadowPageModel, trail, touched=()):
    """Compare the real allocator's observable state with the model."""
    _checked()
    if palloc.available != len(model.free):
        _violation(
            f"shadow allocator: real free count {palloc.available} != "
            f"model {len(model.free)} — the pool and its bookkeeping have "
            f"diverged", trail)
    for p in touched:
        real = palloc._refs.get(p)
        want = model.refs.get(p)
        if real != want:
            _violation(
                f"shadow allocator: page {p} refcount {real} != model "
                f"{want}", trail)


def attach_page_shadow(palloc):
    """Wrap a ``PageAllocator`` instance's mutating ops so each one is
    mirrored into a :class:`ShadowPageModel` and cross-checked.  The model
    and trail ride on the instance (``_shadow`` / ``_shadow_trail``)."""
    model = ShadowPageModel(palloc.num_pages)
    trail: deque = deque(maxlen=64)
    real_alloc, real_free = palloc.alloc, palloc.free
    real_incref, real_decref = palloc.incref, palloc.decref

    def alloc(n=1):
        pids = real_alloc(n)
        trail.append(f"alloc({n}) -> {pids}")
        model.on_alloc(pids, trail)
        _cross_check(palloc, model, trail, pids)
        return pids

    def free(pids):
        real_free(pids)
        trail.append(f"free({list(pids)})")
        model.on_free(pids, trail)
        _cross_check(palloc, model, trail)

    def incref(pid):
        n = real_incref(pid)
        trail.append(f"incref({pid}) -> {n}")
        model.on_incref(pid, n, trail)
        _cross_check(palloc, model, trail, (pid,))
        return n

    def decref(pid):
        n = real_decref(pid)
        trail.append(f"decref({pid}) -> {n}")
        model.on_decref(pid, n, trail)
        _cross_check(palloc, model, trail, (pid,))
        return n

    palloc.alloc, palloc.free = alloc, free
    palloc.incref, palloc.decref = incref, decref
    palloc._shadow = model
    palloc._shadow_trail = trail
    return model


class ShadowTierModel:
    """Model of the tiered residency rules: a key is cold XOR
    eviction-marked XOR neither — never both — and the cold tier respects
    its bound.  ``store`` over a still-eviction-marked key is the
    hot+cold violation the real allocator does not guard itself."""

    def __init__(self, flash_pages):
        self.flash_pages = flash_pages
        self.cold: set = set()
        self.evictable: dict = {}

    def on_mark_evictable(self, key, pid, trail):
        if key in self.cold:
            _violation(
                f"shadow tier: page {key!r} eviction-marked while already "
                f"cold (hot+cold residency)", trail)
        if key in self.evictable:
            _violation(
                f"shadow tier: page {key!r} eviction-marked twice", trail)
        self.evictable[key] = pid

    def on_store(self, key, trail):
        if key in self.evictable:
            _violation(
                f"shadow tier: page {key!r} stored cold while still "
                f"eviction-marked hot — the same page now has two live "
                f"residencies (hot+cold)", trail)
        if key in self.cold:
            _violation(f"shadow tier: page {key!r} stored cold twice", trail)
        if (self.flash_pages is not None
                and len(self.cold) >= self.flash_pages):
            _violation(
                f"shadow tier: cold store past the flash bound "
                f"({self.flash_pages} pages)", trail)
        self.cold.add(key)

    def on_fetch(self, key, trail):
        if key not in self.cold:
            _violation(
                f"shadow tier: fetch of page {key!r} that is not cold "
                f"(lost or double-prefetched payload)", trail)
        self.cold.discard(key)

    def on_pop_evictable(self, popped, trail):
        for key, _pid in popped:
            if key not in self.evictable:
                _violation(
                    f"shadow tier: pop_evictable returned {key!r} which "
                    f"was never eviction-marked", trail)
            del self.evictable[key]


def attach_tier_shadow(talloc):
    """Wrap a ``TieredPageAllocator``'s residency ops (its hot
    ``PageAllocator`` is expected to carry its own page shadow)."""
    model = ShadowTierModel(talloc.flash_pages)
    trail: deque = deque(maxlen=64)
    real = {name: getattr(talloc, name) for name in
            ("mark_evictable", "pop_evictable", "store", "fetch",
             "unmark_slot", "drop_slot")}

    def _cross():
        _checked()
        if len(talloc._cold) != len(model.cold):
            _violation(
                f"shadow tier: real cold count {len(talloc._cold)} != "
                f"model {len(model.cold)}", trail)
        if len(talloc._evictable) != len(model.evictable):
            _violation(
                f"shadow tier: real evictable count "
                f"{len(talloc._evictable)} != model {len(model.evictable)}",
                trail)

    def mark_evictable(key, pid):
        real["mark_evictable"](key, pid)
        trail.append(f"mark_evictable({key!r}, {pid})")
        model.on_mark_evictable(key, pid, trail)
        _cross()

    def pop_evictable(n, exclude=None):
        out = real["pop_evictable"](n, exclude)
        trail.append(f"pop_evictable({n}) -> {[k for k, _ in out]}")
        model.on_pop_evictable(out, trail)
        _cross()
        return out

    def store(key, payload):
        trail.append(f"store({key!r})")
        model.on_store(key, trail)  # checked FIRST: real impl accepts it
        real["store"](key, payload)
        _cross()

    def fetch(key):
        payload = real["fetch"](key)
        trail.append(f"fetch({key!r})")
        model.on_fetch(key, trail)
        _cross()
        return payload

    def unmark_slot(match):
        real["unmark_slot"](match)
        trail.append("unmark_slot(<match>)")
        for k in [k for k in model.evictable if match(k)]:
            del model.evictable[k]
        _cross()

    def drop_slot(match):
        real["drop_slot"](match)
        trail.append("drop_slot(<match>)")
        for k in [k for k in model.cold if match(k)]:
            model.cold.discard(k)
        for k in [k for k in model.evictable if match(k)]:
            del model.evictable[k]
        _cross()

    talloc.mark_evictable = mark_evictable
    talloc.pop_evictable = pop_evictable
    talloc.store, talloc.fetch = store, fetch
    talloc.unmark_slot, talloc.drop_slot = unmark_slot, drop_slot
    talloc._tier_shadow = model
    talloc._tier_shadow_trail = trail
    return model


# ----------------------------------------------------------------------
# overlapped-dispatch aliasing guard
# ----------------------------------------------------------------------
def _digest(arr) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()


class DispatchGuard:
    """Hashes of the host numpy buffers handed to one overlapped dispatch;
    re-checked at the lagged drain of that same step."""

    __slots__ = ("step", "entries")

    def __init__(self, step: int, named_arrays: dict):
        self.step = step
        self.entries = [(name, arr, _digest(arr))
                        for name, arr in named_arrays.items()
                        if arr is not None]


def guard_dispatch(step: int, **named_arrays) -> DispatchGuard:
    """Snapshot hashes of the numpy args at dispatch time."""
    return DispatchGuard(step, named_arrays)


def check_drain(guard: DispatchGuard) -> None:
    """Re-hash at drain; any mutation in between is the PR 6 aliasing race
    — the async step read the buffer while the host wrote it."""
    _checked()
    for name, arr, digest in guard.entries:
        if _digest(arr) != digest:
            _violation(
                f"aliasing guard: dispatch arg `{name}` of decode step "
                f"{guard.step} was mutated between dispatch and drain — "
                f"the overlapped step read it concurrently (pass a .copy() "
                f"snapshot at dispatch)")


# ----------------------------------------------------------------------
# jit retrace budget
# ----------------------------------------------------------------------
def check_retrace(fn, label: str, budget: int | None = None) -> None:
    """Assert ``fn``'s trace-cache size stays within the budget.  The
    fused step should trace once per (shape bucket, greedy flag) — a
    cache that grows with the step count is a retrace explosion."""
    if budget is None:
        budget = int(os.environ.get("REPRO_SANITIZE_RETRACE_BUDGET", "16"))
    size_fn = getattr(fn, "_cache_size", None)
    if size_fn is None:
        return  # older jax: no introspection surface
    _checked()
    n = size_fn()
    if n > budget:
        _violation(
            f"retrace budget: {label} has {n} cached traces "
            f"(budget {budget}) — a dynamic shape/static-arg is leaking "
            f"into the trace key (see the jit-in-loop lint rule)")


# ----------------------------------------------------------------------
# wire manifest (runtime twin of the wire-field-drift lint rule)
# ----------------------------------------------------------------------
def check_wire_manifest(manifest: dict, classes: dict) -> None:
    """``manifest``: name -> tuple of covered field names;``classes``:
    name -> dataclass type.  Raises on drift in either direction."""
    import dataclasses as _dc
    _checked()
    for name, cls in classes.items():
        listed = set(manifest.get(name, ()))
        actual = {f.name for f in _dc.fields(cls)}
        missing = actual - listed
        stale = listed - actual
        if missing:
            _violation(
                f"wire manifest: {name} field(s) {sorted(missing)} not "
                f"covered by WIRE_FIELDS — they would silently drop on "
                f"the fleet wire")
        if stale:
            _violation(
                f"wire manifest: WIRE_FIELDS lists {name} field(s) "
                f"{sorted(stale)} that the dataclass no longer has")
